"""AOT pipeline: lower every op variant to HLO **text** + manifest.json.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. Pattern follows
/opt/xla-example/gen_hlo.py.

Per op we emit four variants (see model.py): ref / opt / bug_scale /
bug_offset, under artifacts/<op>/<variant>.hlo.txt, plus a
manifest.json that carries everything the rust side needs: shapes,
input generators, workload metadata for the cost model, tolerances.

Usage:  cd python && python -m compile.aot --out ../artifacts
        (options: --ops substr  --jobs N)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuple_wrap(fn):
    # Lower with return_tuple semantics; rust unwraps with to_tuple1().
    def wrapped(*args):
        return (fn(*args),)

    return wrapped


def _bug_scale(fn):
    def wrapped(*args):
        # 25% so the defect clears atol even for small-magnitude outputs
        # (softmax over 256 lanes ~ 4e-3/element).
        return fn(*args) * 1.25

    return wrapped


def _bug_offset(fn):
    def wrapped(*args):
        return fn(*args) + 0.05

    return wrapped


def variants_of(op: model.OpSpec):
    return {
        "ref": op.build_ref,
        "opt": op.build_opt,
        "bug_scale": _bug_scale(op.build_ref),
        "bug_offset": _bug_offset(op.build_ref),
    }


def lower_op(op: model.OpSpec, out_dir: str) -> dict:
    """Lower all variants of one op; returns its manifest entry."""
    op_dir = os.path.join(out_dir, op.name)
    os.makedirs(op_dir, exist_ok=True)
    specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in op.args]
    artifacts = {}
    for vname, fn in variants_of(op).items():
        path = os.path.join(op_dir, f"{vname}.hlo.txt")
        lowered = jax.jit(_tuple_wrap(fn)).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        artifacts[vname] = os.path.relpath(path, out_dir)
    return {
        "name": op.name,
        "category": op.category,
        "family": op.family,
        "args": [{"shape": list(a.shape), "gen": a.gen} for a in op.args],
        "out_shape": list(op.out_shape),
        "flops": op.flops,
        "bytes_moved": op.bytes_moved,
        "pt_launches": op.pt_launches,
        "pt_passes": op.pt_passes,
        "pt_efficiency": op.pt_efficiency,
        "algo_penalty": op.algo_penalty,
        "atol": op.atol,
        "rtol": op.rtol,
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--ops", default="", help="only ops whose name contains this")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    ops = model.build_registry()
    if args.ops:
        ops = [o for o in ops if args.ops in o.name]
    t0 = time.time()
    entries = []
    for i, op in enumerate(ops):
        entries.append(lower_op(op, out_dir))
        if (i + 1) % 10 == 0 or i + 1 == len(ops):
            print(f"  [{i + 1}/{len(ops)}] {op.name}  ({time.time() - t0:.1f}s)",
                  file=sys.stderr)

    manifest = {
        "version": 1,
        "dtype": "f32",
        "categories": model.CATEGORY_NAMES,
        "ops": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} ops x 4 variants to {out_dir} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
