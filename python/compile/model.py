"""L2 — the 91-operation task registry (the paper's dataset, Table 5).

Each operation is a small JAX compute graph that calls the L1 Pallas
kernels (`build_opt`) and has a pure-jnp oracle (`build_ref`). The AOT
pipeline (aot.py) lowers four variants per op to HLO text:

  ref        — pure-jnp oracle (functional ground truth)
  opt        — Pallas kernel implementation (the optimized L1 path)
  bug_scale  — oracle with a 25% output scale defect
  bug_offset — oracle with a +0.05 output offset defect

The two bug variants give the rust evaluation pipeline *real* wrong
numerics to catch: the SimLLM's semantic-defect injection selects one of
these variants, and the functional check must fail against `ref` via
live PJRT execution — this mirrors the paper's functional testing of
LLM-generated kernels against reference PyTorch implementations.

Category counts follow the paper's Table 5 proportions. Note: Table 5's
printed counts (18/28/21/15/7/5) sum to 94, not the claimed 91; we keep
the headline total of 91 with counts 18/28/21/14/6/4 (documented in
DESIGN.md §5).

Workload metadata (flops, bytes, PyTorch launch/pass decomposition) is
exported to the manifest for the rust cost model; see
rust/src/costmodel/ for how it is priced.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Tuple

from .kernels import conv as kconv
from .kernels import elementwise as kelt
from .kernels import loss as kloss
from .kernels import matmul as kmm
from .kernels import reduce as kred
from .kernels import ref
from .kernels import scan as kscan

F32 = 4  # bytes per element


@dataclass
class ArgSpec:
    """One kernel input: static shape + the generator the rust side uses."""

    shape: Tuple[int, ...]
    gen: str = "uniform"  # uniform|positive|prob|sign|logprob|near_one


@dataclass
class OpSpec:
    """One dataset operation (a row of the paper's 91-kernel dataset)."""

    name: str
    category: int  # 1..6 (Table 5 order)
    family: str
    args: List[ArgSpec]
    build_ref: Callable
    build_opt: Callable
    out_shape: Tuple[int, ...]
    flops: float
    bytes_moved: float  # one-pass input+output traffic at f32
    pt_launches: int  # eager-PyTorch kernel launches
    pt_passes: float  # eager-PyTorch HBM passes over the data
    pt_efficiency: float  # library efficiency vs roofline per pass
    algo_penalty: float = 1.0  # extra PyTorch algorithmic inefficiency
    atol: float = 5e-4
    rtol: float = 1e-3


def _numel(shape):
    n = 1
    for d in shape:
        n *= d
    return n


CATEGORY_NAMES = {
    1: "Matrix Multiplication",
    2: "Convolution",
    3: "Activation & Pooling",
    4: "Normalization & Reduction",
    5: "Loss Functions",
    6: "Cumulative Operations",
}

# Unary-activation flop weights (transcendental ops count heavier)
_ACT_FLOPS = {
    "relu": 1,
    "leaky_relu": 2,
    "gelu": 14,
    "sigmoid": 6,
    "tanh": 8,
    "silu": 7,
    "elu": 7,
    "softplus": 8,
    "hardtanh": 2,
    "mish": 16,
}


# ---------------------------------------------------------------------------
# Family constructors
# ---------------------------------------------------------------------------


def _matmul_op(name, M, K, N, *, bias=False, act=None, residual=False):
    args = [ArgSpec((M, K)), ArgSpec((K, N))]
    if bias:
        args.append(ArgSpec((1, N)))
    if residual:
        args.append(ArgSpec((M, N)))

    if bias and act:
        rfn = lambda x, y, b: ref.matmul_bias_act(x, y, b, act)
        ofn = lambda x, y, b: kmm.matmul_bias_act(x, y, b, act)
    elif bias:
        rfn, ofn = ref.matmul_bias, kmm.matmul_bias
    elif act:
        rfn = lambda x, y: ref.matmul_act(x, y, act)
        ofn = lambda x, y: kmm.matmul_act(x, y, act)
    elif residual:
        rfn, ofn = ref.gemm_add, kmm.gemm_add
    else:
        rfn, ofn = ref.matmul, kmm.matmul

    flops = 2.0 * M * K * N
    epi = (1 if bias else 0) + (_ACT_FLOPS.get(act, 0)) + (1 if residual else 0)
    flops += epi * M * N
    bytes_moved = F32 * (M * K + K * N + M * N + (N if bias else 0) + (M * N if residual else 0))
    launches = 1 + (1 if bias else 0) + (1 if act else 0) + (1 if residual else 0)
    passes = 1.0 + 0.6 * (launches - 1)
    return OpSpec(
        name, 1, "matmul", args, rfn, ofn, (M, N),
        flops, bytes_moved, launches, passes, 0.85,
        atol=1e-3 if max(M, K, N) >= 128 else 5e-4,
    )


def _bmm_op(name, B, M, K, N):
    return OpSpec(
        name, 1, "matmul",
        [ArgSpec((B, M, K)), ArgSpec((B, K, N))],
        ref.bmm, kmm.bmm, (B, M, N),
        2.0 * B * M * K * N,
        F32 * B * (M * K + K * N + M * N),
        1, 1.0, 0.80,
    )


def _matvec_op(name, M, K):
    return OpSpec(
        name, 1, "matmul",
        [ArgSpec((M, K)), ArgSpec((K, 1))],
        ref.matvec, kmm.matvec, (M, 1),
        2.0 * M * K,
        F32 * (M * K + K + M),
        1, 1.0, 0.50,  # GEMV is bandwidth-bound; cuBLAS hits ~50%
    )


def _conv1d_op(name, B, C, L, O, K, *, act=None):
    OL = L - K + 1
    if act:
        rfn = lambda x, w, _a=act: ref.conv1d_act(x, w, _a)
        ofn = lambda x, w, _a=act: kconv.conv1d_act(x, w, _a)
    else:
        rfn, ofn = ref.conv1d, kconv.conv1d
    flops = 2.0 * B * O * C * OL * K + (_ACT_FLOPS.get(act, 0)) * B * O * OL
    return OpSpec(
        name, 2, "conv", [ArgSpec((B, C, L)), ArgSpec((O, C, K))],
        rfn, ofn, (B, O, OL),
        flops,
        F32 * (B * C * L + O * C * K + B * O * OL),
        1 + (1 if act else 0), 1.0 + (0.6 if act else 0.0), 0.60,
    )


def _conv2d_op(name, B, C, H, W, O, KH, KW, *, bias=False, act=None):
    OH, OW = H - KH + 1, W - KW + 1
    args = [ArgSpec((B, C, H, W)), ArgSpec((O, C, KH, KW))]
    if bias:
        args.append(ArgSpec((O,)))
    if bias:
        rfn, ofn = ref.conv2d_bias, kconv.conv2d_bias
    elif act:
        rfn = lambda x, w, _a=act: ref.conv2d_act(x, w, _a)
        ofn = lambda x, w, _a=act: kconv.conv2d_act(x, w, _a)
    else:
        rfn, ofn = ref.conv2d, kconv.conv2d
    flops = 2.0 * B * O * C * OH * OW * KH * KW
    flops += (_ACT_FLOPS.get(act, 0) + (1 if bias else 0)) * B * O * OH * OW
    return OpSpec(
        name, 2, "conv", args, rfn, ofn, (B, O, OH, OW),
        flops,
        F32 * (B * C * H * W + O * C * KH * KW + B * O * OH * OW),
        1 + (1 if bias else 0) + (1 if act else 0),
        1.0 + 0.6 * ((1 if bias else 0) + (1 if act else 0)),
        0.75,
    )


def _dwconv2d_op(name, B, C, H, W, K):
    OH, OW = H - K + 1, W - K + 1
    return OpSpec(
        name, 2, "conv", [ArgSpec((B, C, H, W)), ArgSpec((C, K, K))],
        ref.dwconv2d, kconv.dwconv2d, (B, C, OH, OW),
        2.0 * B * C * OH * OW * K * K,
        F32 * (B * C * H * W + C * K * K + B * C * OH * OW),
        1, 1.0, 0.50,  # depthwise: low arithmetic intensity, cuDNN weak spot
        algo_penalty=2.5,
    )


def _pwconv_op(name, B, C, H, W, O):
    return OpSpec(
        name, 2, "conv", [ArgSpec((B, C, H, W)), ArgSpec((O, C))],
        ref.pwconv, kconv.pwconv, (B, O, H, W),
        2.0 * B * O * C * H * W,
        F32 * (B * C * H * W + O * C + B * O * H * W),
        1, 1.0, 0.80,
    )


def _unary_op(name, fam_fn, opt_fn, M, N, act_key):
    return OpSpec(
        name, 3, "elementwise", [ArgSpec((M, N))],
        fam_fn, opt_fn, (M, N),
        _ACT_FLOPS[act_key] * M * N,
        F32 * 2 * M * N,
        1, 1.0, 0.85,
    )


def _fused2_op(name, rfn, ofn, M, N, flops_per, launches, gen2="uniform", shape2=None):
    shape2 = shape2 or (M, N)
    return OpSpec(
        name, 3, "elementwise", [ArgSpec((M, N)), ArgSpec(shape2, gen2)],
        rfn, ofn, (M, N),
        flops_per * M * N,
        F32 * (M * N + _numel(shape2) + M * N),
        launches, 1.0 + 0.8 * (launches - 1), 0.85,
    )


def _pool2d_op(name, rfn, ofn, B, C, H, W, k):
    return OpSpec(
        name, 3, "pool", [ArgSpec((B, C, H, W))],
        lambda x, _rfn=rfn, _k=k: _rfn(x, _k),
        lambda x, _ofn=ofn, _k=k: _ofn(x, _k),
        (B, C, H // k, W // k),
        k * k * B * C * (H // k) * (W // k),
        F32 * (B * C * H * W + B * C * (H // k) * (W // k)),
        1, 1.0, 0.70,
    )


def _rowwise_op(name, cat, rfn, ofn, M, N, out_cols, flops_per, launches, passes, eff,
                extra_args=(), algo=1.0):
    return OpSpec(
        name, cat, "reduce", [ArgSpec((M, N)), *extra_args],
        rfn, ofn, (M, out_cols),
        flops_per * M * N,
        F32 * (M * N + sum(_numel(a.shape) for a in extra_args) + M * out_cols),
        launches, passes, eff, algo_penalty=algo,
    )


def _loss_op(name, rfn, ofn, M, N, flops_per, launches, gens=("uniform", "uniform"), algo=1.0):
    return OpSpec(
        name, 5, "loss",
        [ArgSpec((M, N), gens[0]), ArgSpec((M, N), gens[1])],
        rfn, ofn, (1, 1),
        flops_per * M * N,
        F32 * (2 * M * N + 1),
        launches, 1.0 + 0.7 * (launches - 1), 0.75, algo_penalty=algo,
    )


def _scan_op(name, rfn, ofn, M, N, gen="uniform", launches=1, algo=1.0):
    return OpSpec(
        name, 6, "scan", [ArgSpec((M, N), gen)],
        rfn, ofn, (M, N),
        2.0 * M * N,
        F32 * 2 * M * N,
        launches, 1.0 + 0.6 * (launches - 1), 0.55, algo_penalty=algo,
    )


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------


def build_registry() -> List[OpSpec]:
    ops: List[OpSpec] = []

    # -- Category 1: Matrix Multiplication (18) ---------------------------
    ops += [
        _matmul_op("matmul_32", 32, 32, 32),
        _matmul_op("matmul_64", 64, 64, 64),
        _matmul_op("matmul_128", 128, 128, 128),
        _matmul_op("matmul_rect_64x32x128", 64, 32, 128),
        _matmul_op("matmul_rect_128x64x32", 128, 64, 32),
        _matmul_op("matmul_bias_32", 32, 32, 32, bias=True),
        _matmul_op("matmul_bias_64", 64, 64, 64, bias=True),
        _matmul_op("matmul_bias_128", 128, 128, 128, bias=True),
        _matmul_op("matmul_relu_64", 64, 64, 64, act="relu"),
        _matmul_op("matmul_relu_128", 128, 128, 128, act="relu"),
        _matmul_op("matmul_gelu_64", 64, 64, 64, act="gelu"),
        _matmul_op("matmul_tanh_32", 32, 32, 32, act="tanh"),
        _matmul_op("linear_silu_64", 64, 64, 64, bias=True, act="silu"),
        _matmul_op("gemm_add_64", 64, 64, 64, residual=True),
        _bmm_op("bmm_2x32", 2, 32, 32, 32),
        _bmm_op("bmm_4x64", 4, 64, 64, 64),
        _matvec_op("matvec_64", 64, 64),
        _matvec_op("matvec_128", 128, 128),
    ]

    # -- Category 2: Convolution (28) --------------------------------------
    ops += [
        _conv1d_op("conv1d_k3_c8", 2, 8, 32, 8, 3),
        _conv1d_op("conv1d_k5_c8", 2, 8, 32, 8, 5),
        _conv1d_op("conv1d_k7_c8", 2, 8, 32, 8, 7),
        _conv1d_op("conv1d_k3_c16", 2, 16, 64, 16, 3),
        _conv1d_op("conv1d_k5_c16", 2, 16, 64, 16, 5),
        _conv1d_op("conv1d_relu_k3", 2, 8, 32, 8, 3, act="relu"),
        _conv1d_op("conv1d_tanh_k3", 2, 8, 32, 8, 3, act="tanh"),
        _conv1d_op("conv1d_k3_wide", 1, 8, 128, 8, 3),
        _conv2d_op("conv2d_k3_c8", 1, 8, 16, 16, 8, 3, 3),
        _conv2d_op("conv2d_k5_c8", 1, 8, 16, 16, 8, 5, 5),
        _conv2d_op("conv2d_k3_c16", 1, 16, 16, 16, 16, 3, 3),
        _conv2d_op("conv2d_k3_b2", 2, 8, 16, 16, 8, 3, 3),
        _conv2d_op("conv2d_k3_hd", 1, 8, 24, 24, 16, 3, 3),
        _conv2d_op("conv2d_k1x3", 1, 8, 16, 16, 8, 1, 3),
        _conv2d_op("conv2d_k3x1", 1, 8, 16, 16, 8, 3, 1),
        _conv2d_op("conv2d_k7_c4", 1, 4, 24, 24, 4, 7, 7),
        _conv2d_op("conv2d_relu_k3", 1, 8, 16, 16, 8, 3, 3, act="relu"),
        _conv2d_op("conv2d_sigmoid_k3", 1, 8, 16, 16, 8, 3, 3, act="sigmoid"),
        _conv2d_op("conv2d_bias_k3", 1, 8, 16, 16, 8, 3, 3, bias=True),
        _conv2d_op("conv2d_bias_k5", 1, 8, 16, 16, 8, 5, 5, bias=True),
        _dwconv2d_op("dwconv2d_k3_c8", 2, 8, 16, 16, 3),
        _dwconv2d_op("dwconv2d_k5_c8", 2, 8, 16, 16, 5),
        _dwconv2d_op("dwconv2d_k3_c16", 1, 16, 24, 24, 3),
        _dwconv2d_op("dwconv2d_k3_b4", 4, 8, 16, 16, 3),
        _pwconv_op("pwconv_8to16", 2, 8, 16, 16, 16),
        _pwconv_op("pwconv_16to32", 1, 16, 16, 16, 32),
        _pwconv_op("pwconv_16to8", 2, 16, 16, 16, 8),
        _pwconv_op("pwconv_32to32", 1, 32, 8, 8, 32),
    ]

    # -- Category 3: Activation & Pooling (21) -----------------------------
    ops += [
        _unary_op("relu_64", ref.relu, kelt.relu, 64, 64, "relu"),
        _unary_op("relu_big", ref.relu, kelt.relu, 128, 256, "relu"),
        _unary_op("leaky_relu_64", ref.leaky_relu, kelt.leaky_relu, 64, 64, "leaky_relu"),
        _unary_op("gelu_64", ref.gelu, kelt.gelu, 64, 64, "gelu"),
        _unary_op("gelu_big", ref.gelu, kelt.gelu, 128, 256, "gelu"),
        _unary_op("sigmoid_64", ref.sigmoid, kelt.sigmoid, 64, 64, "sigmoid"),
        _unary_op("tanh_64", ref.tanh, kelt.tanh, 64, 64, "tanh"),
        _unary_op("silu_64", ref.silu, kelt.silu, 64, 64, "silu"),
        _unary_op("silu_big", ref.silu, kelt.silu, 128, 256, "silu"),
        _unary_op("elu_64", ref.elu, kelt.elu, 64, 64, "elu"),
        _unary_op("softplus_64", ref.softplus, kelt.softplus, 64, 64, "softplus"),
        _unary_op("hardtanh_64", ref.hardtanh, kelt.hardtanh, 64, 64, "hardtanh"),
        _unary_op("mish_64", ref.mish, kelt.mish, 64, 64, "mish"),
        _fused2_op("bias_relu_64", ref.bias_relu, kelt.bias_relu, 64, 64, 2, 2,
                   shape2=(1, 64)),
        _fused2_op("add_gelu_64", ref.add_gelu, kelt.add_gelu, 64, 64, 15, 2),
        _fused2_op("mul_sigmoid_64", ref.mul_sigmoid, kelt.mul_sigmoid, 64, 64, 7, 2),
        _fused2_op("scale_tanh_64", ref.scale_tanh, kelt.scale_tanh, 64, 64, 9, 2,
                   shape2=(1, 1)),
        _pool2d_op("maxpool2d_k2", ref.maxpool2d, kelt.maxpool2d, 2, 8, 16, 16, 2),
        _pool2d_op("avgpool2d_k2", ref.avgpool2d, kelt.avgpool2d, 2, 8, 16, 16, 2),
        _pool2d_op("maxpool2d_k4", ref.maxpool2d, kelt.maxpool2d, 1, 8, 32, 32, 4),
        OpSpec(
            "avgpool1d_k2", 3, "pool", [ArgSpec((2, 8, 64))],
            lambda x: ref.avgpool1d(x, 2), lambda x: kelt.avgpool1d(x, 2),
            (2, 8, 32),
            2 * 2 * 8 * 32,
            F32 * (2 * 8 * 64 + 2 * 8 * 32),
            1, 1.0, 0.70,
        ),
    ]

    # -- Category 4: Normalization & Reduction (14) ------------------------
    g64 = (ArgSpec((1, 64)), ArgSpec((1, 64)))
    g256 = (ArgSpec((1, 256)), ArgSpec((1, 256)))
    ops += [
        _rowwise_op("softmax_64", 4, ref.softmax, kred.softmax, 32, 64, 64, 8, 1, 1.0, 0.80),
        _rowwise_op("softmax_256", 4, ref.softmax, kred.softmax, 32, 256, 256, 8, 1, 1.0, 0.80),
        _rowwise_op("log_softmax_64", 4, ref.log_softmax, kred.log_softmax, 32, 64, 64, 9, 1, 1.0, 0.80),
        _rowwise_op("layernorm_64", 4, ref.layernorm, kred.layernorm, 32, 64, 64, 10, 1, 1.0, 0.80,
                    extra_args=g64),
        _rowwise_op("layernorm_256", 4, ref.layernorm, kred.layernorm, 32, 256, 256, 10, 1, 1.0, 0.80,
                    extra_args=g256),
        _rowwise_op("rmsnorm_64", 4, ref.rmsnorm, kred.rmsnorm, 32, 64, 64, 6, 4, 3.0, 0.85,
                    extra_args=(ArgSpec((1, 64)),), algo=1.3),
        _rowwise_op("rmsnorm_256", 4, ref.rmsnorm, kred.rmsnorm, 32, 256, 256, 6, 4, 3.0, 0.85,
                    extra_args=(ArgSpec((1, 256)),), algo=1.3),
        OpSpec(
            "instancenorm_8", 4, "reduce", [ArgSpec((2, 8, 16, 16))],
            ref.instancenorm, kred.instancenorm, (2, 8, 16, 16),
            10.0 * 2 * 8 * 16 * 16,
            F32 * 2 * (2 * 8 * 16 * 16),
            2, 2.0, 0.70, algo_penalty=1.4,
        ),
        _rowwise_op("l2norm_64", 4, ref.l2norm, kred.l2norm, 64, 64, 64, 4, 3, 2.4, 0.85, algo=1.2),
        _rowwise_op("sum_rows_128", 4, ref.sum_rows, kred.sum_rows, 64, 128, 1, 1, 1, 1.0, 0.80),
        _rowwise_op("mean_rows_128", 4, ref.mean_rows, kred.mean_rows, 64, 128, 1, 1, 1, 1.0, 0.80),
        _rowwise_op("max_rows_128", 4, ref.max_rows, kred.max_rows, 64, 128, 1, 1, 1, 1.0, 0.80),
        _rowwise_op("var_rows_128", 4, ref.var_rows, kred.var_rows, 64, 128, 1, 4, 2, 2.0, 0.80),
        _rowwise_op("frobenius_64", 4, ref.frobenius_norm, kred.frobenius_norm, 64, 64, 1, 2, 2, 2.0, 0.70),
    ]
    # frobenius reduces the whole matrix to (1,1)
    ops[-1].out_shape = (1, 1)

    # -- Category 5: Loss Functions (6) ------------------------------------
    ops += [
        _loss_op("mse_64", ref.mse_loss, kloss.mse_loss, 64, 64, 3, 3),
        _loss_op("mae_64", ref.mae_loss, kloss.mae_loss, 64, 64, 2, 3),
        _loss_op("huber_64", ref.huber_loss, kloss.huber_loss, 64, 64, 6, 5, algo=2.5),
        _loss_op("cross_entropy_64", ref.cross_entropy_soft, kloss.cross_entropy_soft,
                 32, 64, 12, 4, gens=("uniform", "prob"), algo=1.3),
        _loss_op("kl_div_64", ref.kl_div_loss, kloss.kl_div_loss, 32, 64, 8, 4,
                 gens=("logprob", "prob"), algo=1.3),
        _loss_op("hinge_64", ref.hinge_loss, kloss.hinge_loss, 64, 64, 4, 4,
                 gens=("uniform", "sign"), algo=3.0),
    ]

    # -- Category 6: Cumulative Operations (4) ------------------------------
    # algo penalties model eager PyTorch's poor small-scan behaviour
    # (serial thread-per-row kernels; cumprod additionally via the
    # log-exp fallback; reverse_cumsum as flip+cumsum+flip). These are
    # the heavy-tail ops behind the paper's >10x Figure-5 entries.
    ops += [
        _scan_op("cumsum_rows_64", ref.cumsum_rows, kscan.cumsum_rows, 32, 64, algo=3.0),
        _scan_op("cumprod_rows_64", ref.cumprod_rows, kscan.cumprod_rows, 32, 64,
                 gen="near_one", algo=12.0),
        _scan_op("reverse_cumsum_64", ref.reverse_cumsum_rows, kscan.reverse_cumsum_rows,
                 32, 64, launches=3, algo=6.0),
        _scan_op("cummax_64", ref.cummax_rows, kscan.cummax_rows, 32, 64, algo=4.0),
    ]

    assert len(ops) == 91, len(ops)
    counts = {}
    for o in ops:
        counts[o.category] = counts.get(o.category, 0) + 1
    assert counts == {1: 18, 2: 28, 3: 21, 4: 14, 5: 6, 6: 4}, counts
    names = [o.name for o in ops]
    assert len(set(names)) == len(names), "duplicate op names"
    return ops


def get_op(name: str) -> OpSpec:
    for op in build_registry():
        if op.name == name:
            return op
    raise KeyError(name)
