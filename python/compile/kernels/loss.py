"""L1 Pallas kernels — loss family (category 5).

TPU adaptation: CUDA loss kernels are two-stage (per-block partial
reduction + atomics / second launch). Here the whole operand pair is
VMEM-resident (dataset shapes are small) and the reduction happens in a
single kernel instance producing a (1,1) scalar — the analogue of a
single-block fused reduction, avoiding the multi-launch eager PyTorch
pattern (pointwise op, then mean, each a separate kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _scalar(fn, *xs):
    def kernel(*refs):
        o_ref = refs[-1]
        o_ref[...] = fn(*[r[...] for r in refs[:-1]])

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), xs[0].dtype),
        interpret=True,
    )(*xs)


def mse_loss(p, t):
    return _scalar(ref.mse_loss, p, t)


def mae_loss(p, t):
    return _scalar(ref.mae_loss, p, t)


def huber_loss(p, t):
    return _scalar(ref.huber_loss, p, t)


def cross_entropy_soft(logits, labels):
    return _scalar(ref.cross_entropy_soft, logits, labels)


def kl_div_loss(logp, q):
    return _scalar(ref.kl_div_loss, logp, q)


def hinge_loss(p, y):
    return _scalar(ref.hinge_loss, p, y)
