"""Pure-jnp reference oracle for every kernel family.

This is the CORE correctness signal of the reproduction: each Pallas
kernel in this package is checked against the function of the same name
here (pytest + hypothesis on the python side; live PJRT execution of the
AOT-lowered pair on the rust side).

Everything here is deliberately written in the most obvious possible
jnp style — no tiling, no fusion tricks — so that it serves as a
semantic specification, mirroring the paper's "reference Python
implementation" used for functional-correctness verification.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Category 1 — Matrix multiplication
# ---------------------------------------------------------------------------


def matmul(x, y):
    """Plain GEMM: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(x, y)


def matmul_bias(x, y, b):
    """GEMM + broadcast bias over rows."""
    return jnp.matmul(x, y) + b


def matmul_act(x, y, act):
    """GEMM with a fused activation epilogue."""
    return _ACT[act](jnp.matmul(x, y))


def matmul_bias_act(x, y, b, act):
    """GEMM + bias + activation epilogue."""
    return _ACT[act](jnp.matmul(x, y) + b)


def gemm_add(x, y, c):
    """GEMM + element-wise residual add."""
    return jnp.matmul(x, y) + c


def bmm(x, y):
    """Batched GEMM: (B,M,K) @ (B,K,N) -> (B,M,N)."""
    return jnp.einsum("bmk,bkn->bmn", x, y)


def matvec(a, x):
    """(M,K) @ (K,1) -> (M,1). Kept 2-D for uniform artifacts."""
    return jnp.matmul(a, x)


# ---------------------------------------------------------------------------
# Category 2 — Convolution  (NCHW / NCL layouts, VALID padding, stride 1)
# ---------------------------------------------------------------------------


def conv1d(x, w):
    """x: (B,C,L), w: (O,C,K) -> (B,O,L-K+1)."""
    B, C, L = x.shape
    O, _, K = w.shape
    OL = L - K + 1
    acc = jnp.zeros((B, O, OL), dtype=x.dtype)
    for k in range(K):
        acc = acc + jnp.einsum("bcl,oc->bol", x[:, :, k : k + OL], w[:, :, k])
    return acc


def conv1d_act(x, w, act):
    return _ACT[act](conv1d(x, w))


def conv2d(x, w):
    """x: (B,C,H,W), w: (O,C,KH,KW) -> (B,O,H-KH+1,W-KW+1)."""
    B, C, H, W = x.shape
    O, _, KH, KW = w.shape
    OH, OW = H - KH + 1, W - KW + 1
    acc = jnp.zeros((B, O, OH, OW), dtype=x.dtype)
    for kh in range(KH):
        for kw in range(KW):
            patch = x[:, :, kh : kh + OH, kw : kw + OW]
            acc = acc + jnp.einsum("bchw,oc->bohw", patch, w[:, :, kh, kw])
    return acc


def conv2d_bias(x, w, b):
    """conv2d + per-output-channel bias."""
    return conv2d(x, w) + b[None, :, None, None]


def conv2d_act(x, w, act):
    return _ACT[act](conv2d(x, w))


def dwconv2d(x, w):
    """Depthwise conv2d. x: (B,C,H,W), w: (C,KH,KW)."""
    B, C, H, W = x.shape
    _, KH, KW = w.shape
    OH, OW = H - KH + 1, W - KW + 1
    acc = jnp.zeros((B, C, OH, OW), dtype=x.dtype)
    for kh in range(KH):
        for kw in range(KW):
            patch = x[:, :, kh : kh + OH, kw : kw + OW]
            acc = acc + patch * w[None, :, kh, kw, None, None]
    return acc


def pwconv(x, w):
    """Pointwise (1x1) conv: x (B,C,H,W), w (O,C) -> (B,O,H,W)."""
    return jnp.einsum("bchw,oc->bohw", x, w)


# ---------------------------------------------------------------------------
# Category 3 — Activation & pooling (element-wise / window)
# ---------------------------------------------------------------------------


def relu(x):
    return jnp.maximum(x, 0.0)


def leaky_relu(x, alpha=0.01):
    return jnp.where(x >= 0, x, alpha * x)


def gelu(x):
    # tanh approximation — matches the Pallas kernel exactly.
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def tanh(x):
    return jnp.tanh(x)


def silu(x):
    return x * sigmoid(x)


def elu(x, alpha=1.0):
    return jnp.where(x >= 0, x, alpha * (jnp.exp(x) - 1.0))


def softplus(x):
    # numerically-stable softplus
    return jnp.logaddexp(x, 0.0)


def hardtanh(x, lo=-1.0, hi=1.0):
    return jnp.clip(x, lo, hi)


def mish(x):
    return x * jnp.tanh(softplus(x))


def bias_relu(x, b):
    """Fused bias-add + relu (row-broadcast bias)."""
    return relu(x + b)


def add_gelu(x, y):
    """Fused residual-add + gelu."""
    return gelu(x + y)


def mul_sigmoid(x, y):
    """GLU-style gate: x * sigmoid(y)."""
    return x * sigmoid(y)


def scale_tanh(x, s):
    """Fused scale + tanh (s is a (1,1) scalar tensor)."""
    return jnp.tanh(x * s)


def maxpool2d(x, k):
    """Stride == kernel pooling. x: (B,C,H,W), H % k == 0, W % k == 0."""
    B, C, H, W = x.shape
    return x.reshape(B, C, H // k, k, W // k, k).max(axis=(3, 5))


def avgpool2d(x, k):
    B, C, H, W = x.shape
    return x.reshape(B, C, H // k, k, W // k, k).mean(axis=(3, 5))


def avgpool1d(x, k):
    """x: (B,C,L), L % k == 0."""
    B, C, L = x.shape
    return x.reshape(B, C, L // k, k).mean(axis=3)


# ---------------------------------------------------------------------------
# Category 4 — Normalization & reduction (row-wise over last axis)
# ---------------------------------------------------------------------------


def softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def log_softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def rmsnorm(x, g, eps=1e-5):
    ms = jnp.mean(x**2, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def instancenorm(x, eps=1e-5):
    """x: (B,C,H,W), normalize over (H,W) per (B,C)."""
    mu = jnp.mean(x, axis=(2, 3), keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=(2, 3), keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def l2norm(x, eps=1e-12):
    n = jnp.sqrt(jnp.sum(x**2, axis=-1, keepdims=True) + eps)
    return x / n


def sum_rows(x):
    return jnp.sum(x, axis=-1, keepdims=True)


def mean_rows(x):
    return jnp.mean(x, axis=-1, keepdims=True)


def max_rows(x):
    return jnp.max(x, axis=-1, keepdims=True)


def var_rows(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    return jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)


def frobenius_norm(x):
    """Whole-matrix Frobenius norm, returned as (1,1)."""
    return jnp.sqrt(jnp.sum(x**2)).reshape(1, 1)


# ---------------------------------------------------------------------------
# Category 5 — Losses (reduced to a (1,1) tensor for uniform artifacts)
# ---------------------------------------------------------------------------


def mse_loss(p, t):
    return jnp.mean((p - t) ** 2).reshape(1, 1)


def mae_loss(p, t):
    return jnp.mean(jnp.abs(p - t)).reshape(1, 1)


def huber_loss(p, t, delta=1.0):
    d = jnp.abs(p - t)
    quad = 0.5 * d**2
    lin = delta * (d - 0.5 * delta)
    return jnp.mean(jnp.where(d <= delta, quad, lin)).reshape(1, 1)


def cross_entropy_soft(logits, labels):
    """Soft-label cross-entropy: labels are a probability distribution."""
    return jnp.mean(-jnp.sum(labels * log_softmax(logits), axis=-1)).reshape(1, 1)


def kl_div_loss(logp, q):
    """KL in torch's kl_div convention: mean(q*(log q - logp))."""
    return jnp.mean(q * (jnp.log(jnp.clip(q, 1e-12, None)) - logp)).reshape(1, 1)


def hinge_loss(p, y):
    """y in {-1, +1}. mean(max(0, 1 - y*p))."""
    return jnp.mean(jnp.maximum(0.0, 1.0 - y * p)).reshape(1, 1)


# ---------------------------------------------------------------------------
# Category 6 — Cumulative (sequence-dependent)
# ---------------------------------------------------------------------------


def cumsum_rows(x):
    return jnp.cumsum(x, axis=-1)


def cumprod_rows(x):
    return jnp.cumprod(x, axis=-1)


def reverse_cumsum_rows(x):
    return jnp.flip(jnp.cumsum(jnp.flip(x, axis=-1), axis=-1), axis=-1)


def cummax_rows(x):
    return jax.lax.cummax(x, axis=x.ndim - 1)


# Shared activation table (used by fused-epilogue kernels)
_ACT = {
    "relu": relu,
    "gelu": gelu,
    "tanh": tanh,
    "silu": silu,
    "sigmoid": sigmoid,
}
