# L1: Pallas kernels for the paper's compute families (interpret=True).
# One module per dataset category; `ref` is the pure-jnp oracle.
from . import conv, elementwise, loss, matmul, reduce, ref, scan  # noqa: F401
