"""L1 Pallas kernels — matrix-multiplication family (category 1).

TPU adaptation of the paper's CUDA-threadblock GEMM (DESIGN.md
§Hardware-Adaptation): the CUDA (blockDim, smem tile) schedule becomes a
Pallas BlockSpec HBM→VMEM schedule. The grid is (M/bm, N/bn, K/bk); each
step streams one (bm,bk) x-tile and one (bk,bn) y-tile into VMEM and
accumulates into the resident (bm,bn) output tile — the K axis is the
innermost (sequential) grid dimension, so the output block stays hot in
VMEM across the K loop, exactly like a CUDA smem-accumulator tile.

Epilogues (bias / residual / activation) are fused into the final K step
— this is the fusion the paper's >10× vs-PyTorch wins come from (one
kernel instead of a GEMM launch plus N element-wise launches).

All kernels run with interpret=True (CPU-PJRT cannot execute Mosaic
custom-calls); on a real TPU the same BlockSpecs drive the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM budget bookkeeping (bytes), used by DESIGN.md §9 estimates:
#   footprint = 4 * (bm*bk + bk*bn + bm*bn) + epilogue operands.
DEFAULT_BM = 32
DEFAULT_BN = 32
DEFAULT_BK = 32


def _pick(block, dim):
    """Largest divisor of `dim` that is <= `block` (keeps specs legal)."""
    b = min(block, dim)
    while dim % b != 0:
        b -= 1
    return b


def tiled_matmul(
    x,
    y,
    *,
    bias=None,
    residual=None,
    act=None,
    bm=DEFAULT_BM,
    bn=DEFAULT_BN,
    bk=DEFAULT_BK,
):
    """Tiled GEMM with optionally fused epilogue.

    x: (M,K), y: (K,N), bias: (1,N) or None, residual: (M,N) or None,
    act: name in ref._ACT or None.
    """
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    bm, bn, bk = _pick(bm, M), _pick(bn, N), _pick(bk, K)
    nk = K // bk

    def kernel(*refs):
        i = 0
        x_ref, y_ref = refs[0], refs[1]
        i = 2
        b_ref = r_ref = None
        if bias is not None:
            b_ref = refs[i]
            i += 1
        if residual is not None:
            r_ref = refs[i]
            i += 1
        o_ref = refs[-1]

        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(x_ref[...], y_ref[...])

        @pl.when(pl.program_id(2) == nk - 1)
        def _epilogue():
            acc = o_ref[...]
            if b_ref is not None:
                acc = acc + b_ref[...]
            if r_ref is not None:
                acc = acc + r_ref[...]
            if act is not None:
                acc = ref._ACT[act](acc)
            o_ref[...] = acc

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [x, y]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias)
    if residual is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.append(residual)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(*operands)


def matmul(x, y, **blocks):
    return tiled_matmul(x, y, **blocks)


def matmul_bias(x, y, b, **blocks):
    return tiled_matmul(x, y, bias=b, **blocks)


def matmul_act(x, y, act, **blocks):
    return tiled_matmul(x, y, act=act, **blocks)


def matmul_bias_act(x, y, b, act, **blocks):
    return tiled_matmul(x, y, bias=b, act=act, **blocks)


def gemm_add(x, y, c, **blocks):
    return tiled_matmul(x, y, residual=c, **blocks)


def matvec(a, x, **blocks):
    """(M,K) @ (K,1): GEMM with N=1 (bn clamps to 1)."""
    return tiled_matmul(a, x, **blocks)


def bmm(x, y, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Batched GEMM: grid (B, M/bm, N/bn) with a K-resident kernel.

    The batch axis maps to the outermost grid dimension (the CUDA
    blockIdx.z analogue); K is kept whole in VMEM because the batched
    ops in the dataset are small.
    """
    B, M, K = x.shape
    _, _, N = y.shape
    bm, bn = _pick(bm, M), _pick(bn, N)

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = jnp.einsum(
            "bmk,bkn->bmn", x_ref[...], y_ref[...], preferred_element_type=x_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(B, M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, K, bn), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), x.dtype),
        interpret=True,
    )(x, y)
