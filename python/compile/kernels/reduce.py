"""L1 Pallas kernels — normalization & reduction family (category 4).

TPU adaptation: the paper's CUDA warp-shuffle / shared-memory tree
reductions become whole-row VMEM reductions: each grid step holds a
(br, N) slab in VMEM and performs the full statistical reduction on the
VPU (max/sum across the lane dimension), then the normalization in the
same kernel — one HBM round-trip, the direct analogue of a one-pass
fused CUDA rowwise kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _row_blocks(M, br):
    br = max(1, min(br, M))
    while M % br != 0:
        br -= 1
    return br


def _rowwise(fn, x, out_cols, br=8):
    """Row-tiled kernel: fn maps a (br,N) slab to (br,out_cols)."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, o_ref):
        o_ref[...] = fn(x_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[pl.BlockSpec((br, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, out_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, out_cols), x.dtype),
        interpret=True,
    )(x)


def softmax(x, br=8):
    return _rowwise(ref.softmax, x, x.shape[1], br)


def log_softmax(x, br=8):
    return _rowwise(ref.log_softmax, x, x.shape[1], br)


def l2norm(x, br=8):
    return _rowwise(ref.l2norm, x, x.shape[1], br)


def sum_rows(x, br=8):
    return _rowwise(ref.sum_rows, x, 1, br)


def mean_rows(x, br=8):
    return _rowwise(ref.mean_rows, x, 1, br)


def max_rows(x, br=8):
    return _rowwise(ref.max_rows, x, 1, br)


def var_rows(x, br=8):
    return _rowwise(ref.var_rows, x, 1, br)


def layernorm(x, g, b, br=8):
    """One-pass fused layernorm: stats + affine in one VMEM visit."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, g_ref, b_ref, o_ref):
        o_ref[...] = ref.layernorm(x_ref[...], g_ref[...], b_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x, g, b)


def rmsnorm(x, g, br=8):
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, g_ref, o_ref):
        o_ref[...] = ref.rmsnorm(x_ref[...], g_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x, g)


def instancenorm(x, bb=1):
    """Per-(B,C) spatial normalization; batch-tiled grid."""
    B, C, H, W = x.shape
    bb = _row_blocks(B, bb)

    def kernel(x_ref, o_ref):
        o_ref[...] = ref.instancenorm(x_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, H, W), x.dtype),
        interpret=True,
    )(x)


def frobenius_norm(x):
    """Whole-matrix reduction to (1,1): single-step grid, all in VMEM."""
    M, N = x.shape

    def kernel(x_ref, o_ref):
        o_ref[...] = ref.frobenius_norm(x_ref[...])

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=True,
    )(x)
