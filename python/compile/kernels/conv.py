"""L1 Pallas kernels — convolution family (category 2).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA direct
convolutions stage input halos through shared memory per threadblock.
Here the (small) activations are VMEM-resident and the kernel performs a
shifted-window accumulation: for each (kh,kw) tap it contracts the
shifted input patch against the weight slice on the MXU (an einsum over
channels). For the dataset's shapes a whole image fits in VMEM, so the
grid tiles only the batch axis; the per-tap loop is unrolled at trace
time (K is static), mirroring #pragma unroll over the filter window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def conv1d(x, w, *, act=None, bb=1):
    """x: (B,C,L), w: (O,C,K) -> (B,O,OL). Batch-tiled grid."""
    B, C, L = x.shape
    O, _, K = w.shape
    OL = L - K + 1

    def kernel(x_ref, w_ref, o_ref):
        xv = x_ref[...]
        wv = w_ref[...]
        acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
        for k in range(K):  # unrolled filter taps
            acc = acc + jnp.einsum("bcl,oc->bol", xv[:, :, k : k + OL], wv[:, :, k])
        if act is not None:
            acc = ref._ACT[act](acc)
        o_ref[...] = acc

    bb = max(1, min(bb, B))
    while B % bb != 0:
        bb -= 1
    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C, L), lambda b: (b, 0, 0)),
            pl.BlockSpec((O, C, K), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, O, OL), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, O, OL), x.dtype),
        interpret=True,
    )(x, w)


def conv1d_act(x, w, act, **kw):
    return conv1d(x, w, act=act, **kw)


def conv2d(x, w, *, bias=None, act=None, bb=1):
    """x: (B,C,H,W), w: (O,C,KH,KW) -> (B,O,OH,OW). Batch-tiled grid."""
    B, C, H, W = x.shape
    O, _, KH, KW = w.shape
    OH, OW = H - KH + 1, W - KW + 1

    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        b_ref = refs[2] if bias is not None else None
        o_ref = refs[-1]
        xv = x_ref[...]
        wv = w_ref[...]
        acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
        for kh in range(KH):  # unrolled window
            for kw_ in range(KW):
                patch = xv[:, :, kh : kh + OH, kw_ : kw_ + OW]
                acc = acc + jnp.einsum("bchw,oc->bohw", patch, wv[:, :, kh, kw_])
        if b_ref is not None:
            acc = acc + b_ref[...][None, :, None, None]
        if act is not None:
            acc = ref._ACT[act](acc)
        o_ref[...] = acc

    bb = max(1, min(bb, B))
    while B % bb != 0:
        bb -= 1
    in_specs = [
        pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0)),
        pl.BlockSpec((O, C, KH, KW), lambda b: (0, 0, 0, 0)),
    ]
    operands = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((O,), lambda b: (0,)))
        operands.append(bias)
    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, O, OH, OW), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, O, OH, OW), x.dtype),
        interpret=True,
    )(*operands)


def conv2d_bias(x, w, b, **kw):
    return conv2d(x, w, bias=b, **kw)


def conv2d_act(x, w, act, **kw):
    return conv2d(x, w, act=act, **kw)


def dwconv2d(x, w, *, bb=1):
    """Depthwise conv2d: x (B,C,H,W), w (C,KH,KW). VPU-bound (no MXU)."""
    B, C, H, W = x.shape
    _, KH, KW = w.shape
    OH, OW = H - KH + 1, W - KW + 1

    def kernel(x_ref, w_ref, o_ref):
        xv = x_ref[...]
        wv = w_ref[...]
        acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
        for kh in range(KH):
            for kw_ in range(KW):
                patch = xv[:, :, kh : kh + OH, kw_ : kw_ + OW]
                acc = acc + patch * wv[None, :, kh, kw_, None, None]
        o_ref[...] = acc

    bb = max(1, min(bb, B))
    while B % bb != 0:
        bb -= 1
    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((C, KH, KW), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, C, OH, OW), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, OH, OW), x.dtype),
        interpret=True,
    )(x, w)


def pwconv(x, w, *, bb=1):
    """Pointwise conv = channel contraction on the MXU."""
    B, C, H, W = x.shape
    O, _ = w.shape

    def kernel(x_ref, w_ref, o_ref):
        o_ref[...] = jnp.einsum("bchw,oc->bohw", x_ref[...], w_ref[...])

    bb = max(1, min(bb, B))
    while B % bb != 0:
        bb -= 1
    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((O, C), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, O, H, W), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, O, H, W), x.dtype),
        interpret=True,
    )(x, w)
