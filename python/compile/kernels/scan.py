"""L1 Pallas kernels — cumulative family (category 6).

TPU adaptation: the paper's category-6 kernels (cumsum etc.) are the
"sequence dependent, hard to parallelize" group. The CUDA approach is a
Blelloch/Hillis-Steele block scan with inter-block carry propagation;
on TPU the row fits in VMEM, so each grid step performs the whole-row
scan on the VPU (log-depth under XLA's scan lowering). The serial
dependency is what the cost model charges for — matching the paper's
observation that this category sees the smallest speedups.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _row_blocks(M, br):
    br = max(1, min(br, M))
    while M % br != 0:
        br -= 1
    return br


def _rowscan(fn, x, br=8):
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, o_ref):
        o_ref[...] = fn(x_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[pl.BlockSpec((br, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x)


def cumsum_rows(x, br=8):
    return _rowscan(ref.cumsum_rows, x, br)


def cumprod_rows(x, br=8):
    return _rowscan(ref.cumprod_rows, x, br)


def reverse_cumsum_rows(x, br=8):
    return _rowscan(ref.reverse_cumsum_rows, x, br)


def cummax_rows(x, br=8):
    return _rowscan(ref.cummax_rows, x, br)
