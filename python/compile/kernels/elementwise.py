"""L1 Pallas kernels — activation & pooling family (category 3).

TPU adaptation: the paper's CUDA element-wise kernels are pure
bandwidth-bound grid-stride loops. On TPU these become VPU kernels with
row-tiled BlockSpecs: each grid step streams a (br, N) slab HBM→VMEM,
applies the (possibly fused) element-wise chain, and streams it back.
Fusion (bias_relu / add_gelu / mul_sigmoid / scale_tanh) is the paper's
key lever against eager PyTorch's one-launch-per-primitive behaviour.

Pooling uses the stride==kernel reshape trick inside the kernel: the
window reduction happens entirely in VMEM registers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _row_blocks(M, br):
    br = max(1, min(br, M))
    while M % br != 0:
        br -= 1
    return br


def _unary(fn, x, br=8):
    """Row-tiled element-wise kernel over a 2-D tensor."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, o_ref):
        o_ref[...] = fn(x_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[pl.BlockSpec((br, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x)


def _binary(fn, x, y, br=8):
    """Row-tiled fused binary element-wise kernel (same-shape operands)."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = fn(x_ref[...], y_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((br, N), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x, y)


def relu(x, **kw):
    return _unary(ref.relu, x, **kw)


def leaky_relu(x, **kw):
    return _unary(ref.leaky_relu, x, **kw)


def gelu(x, **kw):
    return _unary(ref.gelu, x, **kw)


def sigmoid(x, **kw):
    return _unary(ref.sigmoid, x, **kw)


def tanh(x, **kw):
    return _unary(ref.tanh, x, **kw)


def silu(x, **kw):
    return _unary(ref.silu, x, **kw)


def elu(x, **kw):
    return _unary(ref.elu, x, **kw)


def softplus(x, **kw):
    return _unary(ref.softplus, x, **kw)


def hardtanh(x, **kw):
    return _unary(ref.hardtanh, x, **kw)


def mish(x, **kw):
    return _unary(ref.mish, x, **kw)


def bias_relu(x, b, br=8):
    """x (M,N) + b (1,N) broadcast, then relu — single fused kernel."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, b_ref, o_ref):
        o_ref[...] = ref.relu(x_ref[...] + b_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x, b)


def add_gelu(x, y, **kw):
    return _binary(ref.add_gelu, x, y, **kw)


def mul_sigmoid(x, y, **kw):
    return _binary(ref.mul_sigmoid, x, y, **kw)


def scale_tanh(x, s, br=8):
    """Fused scale (scalar tensor (1,1)) + tanh."""
    M, N = x.shape
    br = _row_blocks(M, br)

    def kernel(x_ref, s_ref, o_ref):
        o_ref[...] = jnp.tanh(x_ref[...] * s_ref[0, 0])

    return pl.pallas_call(
        kernel,
        grid=(M // br,),
        in_specs=[
            pl.BlockSpec((br, N), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=True,
    )(x, s)


def maxpool2d(x, k, bb=1):
    """Window max with stride==k; reduction in-VMEM via reshape."""
    B, C, H, W = x.shape
    bb = _row_blocks(B, bb)

    def kernel(x_ref, o_ref):
        xv = x_ref[...]
        b, c = xv.shape[0], xv.shape[1]
        o_ref[...] = xv.reshape(b, c, H // k, k, W // k, k).max(axis=(3, 5))

    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, C, H // k, W // k), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, H // k, W // k), x.dtype),
        interpret=True,
    )(x)


def avgpool2d(x, k, bb=1):
    B, C, H, W = x.shape
    bb = _row_blocks(B, bb)

    def kernel(x_ref, o_ref):
        xv = x_ref[...]
        b, c = xv.shape[0], xv.shape[1]
        o_ref[...] = xv.reshape(b, c, H // k, k, W // k, k).mean(axis=(3, 5))

    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, C, H, W), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, C, H // k, W // k), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, H // k, W // k), x.dtype),
        interpret=True,
    )(x)


def avgpool1d(x, k, bb=1):
    B, C, L = x.shape
    bb = _row_blocks(B, bb)

    def kernel(x_ref, o_ref):
        xv = x_ref[...]
        b, c = xv.shape[0], xv.shape[1]
        o_ref[...] = xv.reshape(b, c, L // k, k).mean(axis=3)

    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, C, L), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((bb, C, L // k), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, L // k), x.dtype),
        interpret=True,
    )(x)
