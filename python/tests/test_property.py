"""Hypothesis property sweeps over the Pallas kernels' shape/block space.

The pytest suite pins the dataset shapes; here hypothesis varies shapes,
block sizes and dtypes and asserts the kernels still match the oracle —
the paper's "syntactic validity + functional correctness" constraint
g(p)=0, checked over the *schedule* dimension the evolution explores.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import elementwise as kelt
from compile.kernels import matmul as kmm
from compile.kernels import reduce as kred
from compile.kernels import ref
from compile.kernels import scan as kscan

DTYPES = [jnp.float32]
SET = settings(max_examples=25, deadline=None)


def arr(rng, shape, dtype=jnp.float32, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, shape), dtype)


dims = st.sampled_from([4, 8, 16, 24, 32, 48, 64])
blocks = st.sampled_from([1, 2, 4, 8, 16, 32, 64])


@SET
@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, bk=blocks, seed=st.integers(0, 2**16))
def test_matmul_any_blocks(m, k, n, bm, bn, bk, seed):
    """tiled_matmul is correct for ANY (bm,bn,bk) — illegal blocks are
    clamped to divisors, so every schedule the DSL can express is safe."""
    rng = np.random.default_rng(seed)
    x, y = arr(rng, (m, k)), arr(rng, (k, n))
    got = kmm.tiled_matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, y)),
                               atol=1e-3, rtol=1e-3)


@SET
@given(m=dims, k=dims, n=dims, bm=blocks, seed=st.integers(0, 2**16),
       act=st.sampled_from(["relu", "gelu", "tanh", "silu", "sigmoid"]))
def test_matmul_epilogue(m, k, n, bm, seed, act):
    rng = np.random.default_rng(seed)
    x, y, b = arr(rng, (m, k)), arr(rng, (k, n)), arr(rng, (1, n))
    got = kmm.matmul_bias_act(x, y, b, act, bm=bm)
    want = ref.matmul_bias_act(x, y, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


@SET
@given(b=st.integers(1, 4), c=st.integers(1, 8), l=st.integers(8, 48),
       o=st.integers(1, 8), k=st.sampled_from([1, 3, 5, 7]), seed=st.integers(0, 2**16))
def test_conv1d_shapes(b, c, l, o, k, seed):
    if l <= k:
        return
    rng = np.random.default_rng(seed)
    x, w = arr(rng, (b, c, l)), arr(rng, (o, c, k))
    np.testing.assert_allclose(np.asarray(kconv.conv1d(x, w)),
                               np.asarray(ref.conv1d(x, w)), atol=1e-4, rtol=1e-3)


@SET
@given(b=st.integers(1, 3), c=st.integers(1, 6), h=st.integers(6, 20),
       w_=st.integers(6, 20), o=st.integers(1, 6), k=st.sampled_from([1, 3, 5]),
       bb=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_conv2d_shapes(b, c, h, w_, o, k, bb, seed):
    if h <= k or w_ <= k:
        return
    rng = np.random.default_rng(seed)
    x, w = arr(rng, (b, c, h, w_)), arr(rng, (o, c, k, k))
    np.testing.assert_allclose(np.asarray(kconv.conv2d(x, w, bb=bb)),
                               np.asarray(ref.conv2d(x, w)), atol=1e-4, rtol=1e-3)


@SET
@given(m=dims, n=dims, br=blocks, seed=st.integers(0, 2**16),
       name=st.sampled_from(["relu", "gelu", "sigmoid", "tanh", "silu", "elu",
                             "softplus", "hardtanh", "mish", "leaky_relu"]))
def test_elementwise_any_rows(m, n, br, seed, name):
    rng = np.random.default_rng(seed)
    x = arr(rng, (m, n), lo=-3, hi=3)
    got = getattr(kelt, name)(x, br=br)
    want = getattr(ref, name)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)


@SET
@given(m=dims, n=dims, br=blocks, seed=st.integers(0, 2**16))
def test_softmax_rows_sum_to_one(m, n, br, seed):
    """Property: softmax output rows are probability distributions."""
    rng = np.random.default_rng(seed)
    x = arr(rng, (m, n), lo=-5, hi=5)
    got = np.asarray(kred.softmax(x, br=br))
    np.testing.assert_allclose(got.sum(-1), np.ones(m), atol=1e-5)
    assert (got >= 0).all()
    np.testing.assert_allclose(got, np.asarray(ref.softmax(x)), atol=1e-5, rtol=1e-4)


@SET
@given(m=dims, n=dims, br=blocks, seed=st.integers(0, 2**16))
def test_layernorm_stats(m, n, br, seed):
    """Property: layernorm(g=1,b=0) rows have ~zero mean, ~unit var."""
    rng = np.random.default_rng(seed)
    x = arr(rng, (m, n), lo=-2, hi=2)
    g = jnp.ones((1, n), jnp.float32)
    b = jnp.zeros((1, n), jnp.float32)
    got = np.asarray(kred.layernorm(x, g, b, br=br))
    np.testing.assert_allclose(got.mean(-1), np.zeros(m), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kred.layernorm(x, g, b, br=br)),
                               np.asarray(ref.layernorm(x, g, b)), atol=1e-4, rtol=1e-3)


@SET
@given(m=dims, n=dims, br=blocks, seed=st.integers(0, 2**16))
def test_cumsum_last_equals_sum(m, n, br, seed):
    """Property: last scan element equals the row sum (prefix-sum law)."""
    rng = np.random.default_rng(seed)
    x = arr(rng, (m, n))
    got = np.asarray(kscan.cumsum_rows(x, br=br))
    np.testing.assert_allclose(got[:, -1], np.asarray(x).sum(-1), atol=1e-4)
    np.testing.assert_allclose(got, np.asarray(ref.cumsum_rows(x)), atol=1e-4, rtol=1e-3)


@SET
@given(m=dims, n=dims, seed=st.integers(0, 2**16))
def test_reverse_cumsum_is_flip_of_cumsum(m, n, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (m, n))
    got = np.asarray(kscan.reverse_cumsum_rows(x))
    want = np.flip(np.cumsum(np.flip(np.asarray(x), -1), -1), -1)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


@SET
@given(b=st.integers(1, 4), c=st.integers(1, 8),
       hw=st.sampled_from([4, 8, 12, 16]), k=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**16))
def test_pool_bounds(b, c, hw, k, seed):
    """Property: maxpool >= avgpool element-wise; both match oracle."""
    if hw % k != 0:
        return
    rng = np.random.default_rng(seed)
    x = arr(rng, (b, c, hw, hw))
    mx = np.asarray(kelt.maxpool2d(x, k))
    av = np.asarray(kelt.avgpool2d(x, k))
    assert (mx >= av - 1e-6).all()
    np.testing.assert_allclose(mx, np.asarray(ref.maxpool2d(x, k)), atol=1e-6)
    np.testing.assert_allclose(av, np.asarray(ref.avgpool2d(x, k)), atol=1e-6)
