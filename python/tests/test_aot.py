"""AOT pipeline tests: lowering produces loadable HLO text + a manifest
consistent with the registry. (The rust side has the mirror test that
actually executes these on PJRT.)"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable():
    """Lowered text must be real HLO (ENTRY + parsable header), and must
    NOT be a serialized proto (the xla 0.1.6 / jax>=0.5 id clash)."""
    op = model.build_registry()[0]
    specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in op.args]
    lowered = jax.jit(aot._tuple_wrap(op.build_ref)).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    assert text.isprintable() or "\n" in text  # text, not binary proto


def test_variants_complete():
    op = model.build_registry()[0]
    v = aot.variants_of(op)
    assert set(v) == {"ref", "opt", "bug_scale", "bug_offset"}


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_all_ops_present(self):
        names = {e["name"] for e in self.manifest["ops"]}
        want = {o.name for o in model.build_registry()}
        assert names == want

    def test_artifact_files_exist(self):
        for e in self.manifest["ops"]:
            for v, rel in e["artifacts"].items():
                p = os.path.join(ARTIFACTS, rel)
                assert os.path.exists(p), p
                with open(p) as f:
                    head = f.read(200)
                assert "HloModule" in head, p

    def test_metadata_matches_registry(self):
        reg = {o.name: o for o in model.build_registry()}
        for e in self.manifest["ops"]:
            op = reg[e["name"]]
            assert e["category"] == op.category
            assert tuple(e["out_shape"]) == tuple(op.out_shape)
            assert e["flops"] == op.flops
            assert [tuple(a["shape"]) for a in e["args"]] == [a.shape for a in op.args]

    def test_category_counts(self):
        counts = {}
        for e in self.manifest["ops"]:
            counts[e["category"]] = counts.get(e["category"], 0) + 1
        assert counts == {1: 18, 2: 28, 3: 21, 4: 14, 5: 6, 6: 4}
