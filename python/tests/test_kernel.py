"""pytest: every Pallas kernel vs the pure-jnp oracle — the CORE
correctness signal (paper §4.3 functional testing, build-time half)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

OPS = model.build_registry()


def gen_arg(rng, spec: model.ArgSpec):
    shape, gen = spec.shape, spec.gen
    if gen == "prob":
        v = rng.uniform(0.1, 1.0, shape)
        v = v / v.sum(-1, keepdims=True)
    elif gen == "logprob":
        v = rng.uniform(0.1, 1.0, shape)
        v = np.log(v / v.sum(-1, keepdims=True))
    elif gen == "sign":
        v = rng.choice([-1.0, 1.0], shape)
    elif gen == "near_one":
        v = rng.uniform(0.8, 1.2, shape)
    elif gen == "positive":
        v = rng.uniform(0.1, 1.1, shape)
    else:
        v = rng.uniform(-1.0, 1.0, shape)
    return jnp.asarray(v, jnp.float32)


def make_args(op, seed=0):
    rng = np.random.default_rng(seed)
    return [gen_arg(rng, a) for a in op.args]


@pytest.mark.parametrize("op", OPS, ids=[o.name for o in OPS])
def test_opt_matches_ref(op):
    """Pallas kernel output == oracle for every dataset op."""
    args = make_args(op)
    r = np.asarray(op.build_ref(*args))
    o = np.asarray(op.build_opt(*args))
    assert r.shape == tuple(op.out_shape)
    np.testing.assert_allclose(o, r, atol=op.atol, rtol=op.rtol)


@pytest.mark.parametrize("op", OPS, ids=[o.name for o in OPS])
def test_bug_variants_differ(op):
    """The injected-defect variants must actually fail the functional
    check the rust evaluator applies (otherwise SimLLM semantic defects
    would be undetectable)."""
    args = make_args(op, seed=1)
    r = np.asarray(op.build_ref(*args))
    for bug in (lambda *a: op.build_ref(*a) * 1.25,
                lambda *a: op.build_ref(*a) + 0.05):
        b = np.asarray(bug(*args))
        assert not np.allclose(b, r, atol=op.atol, rtol=op.rtol), (
            f"{op.name}: bug variant indistinguishable from ref")


@pytest.mark.parametrize("op", OPS, ids=[o.name for o in OPS])
def test_metadata_sane(op):
    assert op.flops > 0
    assert op.bytes_moved > 0
    assert op.pt_launches >= 1
    assert op.pt_passes >= 1.0
    assert 0.0 < op.pt_efficiency <= 1.0
    assert op.algo_penalty >= 1.0
    assert 1 <= op.category <= 6


def test_registry_shape():
    """Dataset composition: 91 ops, Table-5 category proportions."""
    assert len(OPS) == 91
    counts = {}
    for o in OPS:
        counts[o.category] = counts.get(o.category, 0) + 1
    assert counts == {1: 18, 2: 28, 3: 21, 4: 14, 5: 6, 6: 4}


def test_determinism():
    """Same seed -> identical inputs (the rust evaluator relies on
    deterministic per-seed input generation for memoized functional
    verdicts)."""
    op = OPS[0]
    a1 = make_args(op, seed=7)
    a2 = make_args(op, seed=7)
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
