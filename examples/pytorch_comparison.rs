//! PyTorch-comparison study (paper §5.4, Figure 5 + Table 7): run the
//! EvoEngineer variants across the whole dataset and benchmark the
//! final kernels against the modeled eager-PyTorch implementations —
//! which ops beat the library, by how much, and who wins each op.
//!
//! Run with:  cargo run --release --example pytorch_comparison

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::costmodel::{price_baseline, price_pytorch};
use evoengineer::evals::Evaluator;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::{metrics, report, Result};

fn main() -> Result<()> {
    let registry = std::sync::Arc::new(TaskRegistry::load("artifacts")?);
    let evaluator = Evaluator::new(registry.clone(), Runtime::new()?);

    // Where does the modeled PyTorch baseline sit vs the dataset's
    // initial kernels? (context for the comparison)
    println!("baseline-vs-PyTorch context (first 8 ops):");
    for op in registry.ops.iter().take(8) {
        let base = price_baseline(op, &evaluator.gpu).time;
        let pt = price_pytorch(op, &evaluator.gpu);
        println!(
            "  {:<24} initial kernel {:>9.2} us   eager PyTorch {:>9.2} us",
            op.name,
            base * 1e6,
            pt * 1e6
        );
    }

    let cfg = CampaignConfig {
        methods: vec![
            "evoengineer-free".into(),
            "evoengineer-insight".into(),
            "evoengineer-full".into(),
        ],
        seeds: vec![0, 1],
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator)?;

    println!("\n{}", report::fig5(&records));
    println!("{}", report::table7(&records));
    println!("{}", report::fig8(&records));

    // Category view: where do the wins against the library live?
    let best = metrics::pytorch_best_per_op(&records);
    let mut by_cat = [0usize; 7];
    let mut over2_by_cat = [0usize; 7];
    for b in &best {
        by_cat[b.category as usize] += 1;
        if b.speedup > 2.0 {
            over2_by_cat[b.category as usize] += 1;
        }
    }
    println!("\n>2x-vs-PyTorch ops per category:");
    for c in 1..=6usize {
        println!(
            "  cat {c}: {:>2}/{:<2} ({})",
            over2_by_cat[c],
            by_cat[c],
            evoengineer::tasks::category_name(c as u8)
        );
    }
    Ok(())
}
