//! Token-budget study (paper §5.3, Figure 4): speedup and validity vs
//! token usage for all six methods on one model. Demonstrates the
//! paper's "resource inefficiency" claim about verbose prompting — the
//! AI CUDA Engineer's token bill vs the EvoEngineer variants'.
//!
//! Run with:  cargo run --release --example token_budget

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::metrics;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::Result;

fn main() -> Result<()> {
    let registry = std::sync::Arc::new(TaskRegistry::load("artifacts")?);
    let evaluator = Evaluator::new(registry, Runtime::new()?);

    let cfg = CampaignConfig {
        models: vec!["gpt".into()],
        max_ops: 18,
        seeds: vec![0, 1],
        ..CampaignConfig::default()
    };
    let records = campaign::run(&cfg, evaluator)?;

    let mut pts = metrics::tradeoff_points(&records);
    pts.sort_by(|a, b| a.total_tokens.cmp(&b.total_tokens));
    let runs = |m: &str| records.iter().filter(|r| r.method == m).count().max(1) as f64;

    println!("TOKEN BUDGET vs PERFORMANCE (GPT-4.1, {} ops x 2 seeds)\n", 18);
    println!(
        "{:<28} {:>12} {:>14} {:>12}  note",
        "Method", "kTok/kernel", "MedianSpeedup", "Functional%"
    );
    println!("{}", "-".repeat(86));
    for p in &pts {
        let ktok = p.total_tokens as f64 / runs(&p.method) / 1e3;
        let note = if p.method.contains("AI CUDA") {
            "<- verbose prompting, paper Fig.4's token-heavy point"
        } else if p.method.ends_with("Free") {
            "<- minimal prompts, exploration-heavy"
        } else if p.method.ends_with("Full") {
            "<- buys validity with moderate extra tokens"
        } else {
            ""
        };
        println!(
            "{:<28} {:>12.1} {:>14.2} {:>12.1}  {note}",
            p.method, ktok, p.median_speedup, p.correct_rate
        );
    }

    // The paper's headline check: EvoEngineer variants should dominate
    // AI CUDA Engineer on tokens at comparable or better validity.
    let ai = pts.iter().find(|p| p.method.contains("AI CUDA")).unwrap();
    let full = pts.iter().find(|p| p.method.ends_with("Full")).unwrap();
    let ai_ktok = ai.total_tokens as f64 / runs(&ai.method);
    let full_ktok = full.total_tokens as f64 / runs(&full.method);
    println!(
        "\nEvoEngineer-Full uses {:.1}x fewer tokens/kernel than AI CUDA Engineer \
         ({:.0} vs {:.0}) at {:+.1} pp functional correctness.",
        ai_ktok / full_ktok,
        full_ktok,
        ai_ktok,
        full.correct_rate - ai.correct_rate
    );
    Ok(())
}
