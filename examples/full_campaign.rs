//! End-to-end driver (the EXPERIMENTS.md run): the full system on a
//! real workload — all six methods x all three models on a stratified
//! subset of the dataset, multiple seeds, 45 trials per run — then
//! regenerates every table/figure from the records, exactly as the
//! paper's evaluation section reports them.
//!
//! All layers compose here: SimLLM (prompt-conditioned generation) ->
//! KernelScript front-end (compile gate) -> PJRT execution of the
//! AOT-lowered JAX/Pallas artifacts (functional gate) -> RTX-4090 cost
//! model (perf) -> population management -> metrics -> reports.
//!
//! Run with:  cargo run --release --example full_campaign
//! Env knobs: EVO_MAX_OPS (default 24), EVO_SEEDS (default 2),
//!            EVO_OUT (default results/example_campaign.jsonl)

use evoengineer::campaign::{self, results, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::report;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::Result;

fn env_num(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let max_ops = env_num("EVO_MAX_OPS", 24) as usize;
    let seeds = env_num("EVO_SEEDS", 2);
    let out = std::env::var("EVO_OUT")
        .unwrap_or_else(|_| "results/example_campaign.jsonl".to_string());

    let registry = std::sync::Arc::new(TaskRegistry::load("artifacts")?);
    let evaluator = Evaluator::new(registry, Runtime::new()?);

    let cfg = CampaignConfig {
        max_ops,
        seeds: (0..seeds).collect(),
        ..CampaignConfig::default()
    };
    let t0 = std::time::Instant::now();
    let records = campaign::run(&cfg, evaluator.clone())?;
    let wall = t0.elapsed();
    results::save(&out, &records)?;

    println!("== campaign complete: {} runs in {:.1}s -> {out} ==\n", records.len(), wall.as_secs_f64());
    println!("{}", report::table4(&records));
    println!("{}", report::fig1(&records));
    println!("{}", report::fig4(&records, "GPT"));
    println!("{}", report::fig5(&records));
    println!("{}", report::table7(&records));
    println!("{}", report::fig8(&records));
    println!("{}", report::table8(&records));
    println!("{}", report::fig9(&records));

    let stats = evaluator.runtime_stats()?;
    println!(
        "pjrt runtime: {} artifact executions, {} compiles, {} cache hits",
        stats.executions, stats.compiles, stats.cache_hits
    );
    let trials: usize = records.iter().map(|r| r.trials).sum();
    println!(
        "throughput: {:.0} trials/s over {} total trials",
        trials as f64 / wall.as_secs_f64(),
        trials
    );
    Ok(())
}
