//! Ablation of the paper's two orthogonal components (DESIGN.md §7):
//!
//! 1. **Information ablation** — the solution-guiding layer's I1 / I3 /
//!    I1+I2+I3 ladder (the three EvoEngineer configurations) at a fixed
//!    budget, isolating what each information type buys (Table 3's
//!    point).
//! 2. **Population ablation** — single-best vs elite vs islands at
//!    fixed information (via EvoEngineer-Insight, EoH, FunSearch which
//!    differ chiefly in population management).
//! 3. **Budget sweep** — 15 / 45 / 90 trials for EvoEngineer-Full.
//!
//! Run with:  cargo run --release --example ablation_information

use evoengineer::campaign::{self, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::metrics;
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::Result;

fn summarize(tag: &str, records: &[evoengineer::methods::KernelRunRecord]) {
    for p in metrics::tradeoff_points(records) {
        println!(
            "  {tag:<18} {:<28} median speedup {:>5.2}  functional {:>5.1}%",
            p.method, p.median_speedup, p.correct_rate
        );
    }
}

fn main() -> Result<()> {
    let registry = std::sync::Arc::new(TaskRegistry::load("artifacts")?);
    let evaluator = Evaluator::new(registry, Runtime::new()?);
    let base = CampaignConfig {
        models: vec!["claude".into()],
        max_ops: 30,
        seeds: vec![0, 1],
        quiet: true,
        ..CampaignConfig::default()
    };

    println!("== 1. information ablation (I1 -> I1+I3 -> I1+I2+I3) ==");
    let cfg = CampaignConfig {
        methods: vec![
            "evoengineer-free".into(),    // I1 only
            "evoengineer-insight".into(), // I1 + I3
            "evoengineer-full".into(),    // I1 + I2 + I3
        ],
        ..base.clone()
    };
    let recs = campaign::run(&cfg, evaluator.clone())?;
    summarize("info", &recs);
    println!("  -> expected: validity rises monotonically with information;");
    println!("     Free trades validity for exploration reach.\n");

    println!("== 2. population ablation (single-best vs elite vs islands) ==");
    let cfg = CampaignConfig {
        methods: vec![
            "evoengineer-insight".into(), // single-best
            "evoengineer-solution".into(),// elite (EoH)
            "funsearch".into(),           // islands
        ],
        ..base.clone()
    };
    let recs = campaign::run(&cfg, evaluator.clone())?;
    summarize("population", &recs);
    println!();

    println!("== 3. trial-budget sweep (EvoEngineer-Full) ==");
    for budget in [15usize, 45, 90] {
        let cfg = CampaignConfig {
            methods: vec!["evoengineer-full".into()],
            budget,
            ..base.clone()
        };
        let recs = campaign::run(&cfg, evaluator.clone())?;
        let p = &metrics::tradeoff_points(&recs)[0];
        println!(
            "  budget {budget:>3}: median speedup {:>5.2}  functional {:>5.1}%",
            p.median_speedup, p.correct_rate
        );
    }
    Ok(())
}
