//! Quickstart: optimize one kernel with EvoEngineer-Full and inspect
//! what the system did — the 60-second tour of the public API.
//!
//! Run with:  cargo run --release --example quickstart

use std::sync::Arc;

use evoengineer::evals::Evaluator;
use evoengineer::llm::{profile, SimProvider};
use evoengineer::methods::{self, Archive, RepairPolicy, RunCtx};
use evoengineer::runtime::Runtime;
use evoengineer::tasks::TaskRegistry;
use evoengineer::Result;

fn main() -> Result<()> {
    // 1. Load the 91-op dataset manifest (`make artifacts` builds it).
    let registry = Arc::new(TaskRegistry::load("artifacts")?);
    println!("dataset: {} ops across 6 categories", registry.ops.len());

    // 2. Bring up the PJRT runtime (functional ground truth) and the
    //    evaluation pipeline (compile -> functional -> perf).
    let evaluator = Evaluator::new(registry.clone(), Runtime::new()?);

    // 3. Pick a task, a method, and a model.
    let task = registry.get("matmul_128").expect("matmul_128").clone();
    let method = methods::by_name("evoengineer-full")?;
    let model = profile::by_name("claude").unwrap();

    // 4. Run one 45-trial optimization campaign on that kernel.
    let archive = Archive::new();
    // The generation backend: SimLLM here; swap in ReplayProvider or
    // (with the http-provider feature) HttpProvider without touching
    // anything below this line.
    let provider = SimProvider::new();
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model,
        seed: 0,
        archive: &archive,
        provider: &provider,
        budget: 45,
        // Stage-0 guard off: the historical pipeline. Try
        // RepairPolicy::Repair { max_attempts: 2 } (or the CLI's
        // `--repair repair`) for the guard + LLM repair loop.
        repair: RepairPolicy::Off,
    };
    let record = method.run(&ctx)?;

    // 5. Inspect the outcome.
    println!(
        "\n{} with {} on {}:",
        record.method, record.model, record.op
    );
    println!("  best speedup vs baseline kernel : {:.2}x", record.best_speedup);
    println!("  best speedup vs PyTorch (model) : {:.2}x", record.best_pytorch_speedup);
    println!(
        "  trial validity: {}/{} compiled, {}/{} functionally correct",
        record.compiled_trials, record.trials, record.correct_trials, record.trials
    );
    println!(
        "  token usage: {} prompt + {} completion",
        record.prompt_tokens, record.completion_tokens
    );
    println!("\nbest kernel found:\n{}", record.best_src.as_deref().unwrap_or("(none)"));

    // 6. Convergence trajectory (best-so-far speedup per trial).
    print!("trajectory: ");
    for (i, s) in record.trajectory.iter().enumerate() {
        if i % 9 == 0 || i + 1 == record.trajectory.len() {
            print!("[t{i}] {s:.2}  ");
        }
    }
    println!();
    Ok(())
}
