import os
import sys

# Make the build-time `compile` package importable when pytest runs
# from the repository root (python/ is the package root).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
