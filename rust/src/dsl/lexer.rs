//! KernelScript lexer. Produces positioned tokens; any byte sequence
//! outside the grammar is a `LexError` — the first of the three real
//! failure gates (paper §3.1: "Syntactic Validity").

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Int(u64),
    Bool(bool),
    Colon,
    Semi,
    LBrace,
    RBrace,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "`{s}`"),
            Tok::Int(n) => write!(f, "{n}"),
            Tok::Bool(b) => write!(f, "{b}"),
            Tok::Colon => write!(f, "`:`"),
            Tok::Semi => write!(f, "`;`"),
            Tok::LBrace => write!(f, "`{{`"),
            Tok::RBrace => write!(f, "`}}`"),
        }
    }
}

/// A token with its source line/column (1-based) for error messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: u32,
    pub col: u32,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub msg: String,
    pub line: u32,
    pub col: u32,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for LexError {}

/// Tokenize a KernelScript source string. `#` starts a line comment.
pub fn lex(src: &str) -> Result<Vec<Spanned>, LexError> {
    let mut toks = Vec::new();
    let mut line = 1u32;
    let mut col = 1u32;
    let bytes = src.as_bytes();
    let mut i = 0usize;

    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            '\n' => {
                line += 1;
                col = 1;
                i += 1;
            }
            ' ' | '\t' | '\r' => {
                col += 1;
                i += 1;
            }
            '#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            ':' => {
                toks.push(Spanned { tok: Tok::Colon, line, col });
                col += 1;
                i += 1;
            }
            ';' => {
                toks.push(Spanned { tok: Tok::Semi, line, col });
                col += 1;
                i += 1;
            }
            '{' => {
                toks.push(Spanned { tok: Tok::LBrace, line, col });
                col += 1;
                i += 1;
            }
            '}' => {
                toks.push(Spanned { tok: Tok::RBrace, line, col });
                col += 1;
                i += 1;
            }
            '0'..='9' => {
                let start = i;
                let c0 = col;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                    col += 1;
                }
                // digits followed by letters (e.g. `32abc`) are invalid
                if i < bytes.len() && (bytes[i].is_ascii_alphabetic() || bytes[i] == b'_') {
                    return Err(LexError {
                        msg: format!(
                            "malformed number starting `{}`",
                            &src[start..(i + 1).min(src.len())]
                        ),
                        line,
                        col: c0,
                    });
                }
                let text = &src[start..i];
                let n: u64 = text.parse().map_err(|_| LexError {
                    msg: format!("integer overflow `{text}`"),
                    line,
                    col: c0,
                })?;
                toks.push(Spanned { tok: Tok::Int(n), line, col: c0 });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                let c0 = col;
                while i < bytes.len()
                    && ((bytes[i] as char).is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                    col += 1;
                }
                let word = &src[start..i];
                let tok = match word {
                    "true" => Tok::Bool(true),
                    "false" => Tok::Bool(false),
                    _ => Tok::Ident(word.to_string()),
                };
                toks.push(Spanned { tok, line, col: c0 });
            }
            other => {
                return Err(LexError {
                    msg: format!("unexpected character `{other}`"),
                    line,
                    col,
                });
            }
        }
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_basic_program() {
        let toks = lex("kernel m { semantics: ref; }").unwrap();
        assert_eq!(toks[0].tok, Tok::Ident("kernel".into()));
        assert_eq!(toks[2].tok, Tok::LBrace);
        assert_eq!(toks.last().unwrap().tok, Tok::RBrace);
    }

    #[test]
    fn comments_ignored() {
        let toks = lex("# a comment\nkernel x {}\n# trailing").unwrap();
        assert_eq!(toks.len(), 4);
    }

    #[test]
    fn rejects_cuda_source() {
        // Raw CUDA is not KernelScript — `(` is outside the grammar.
        assert!(lex("__global__ void k(float* x) {}").is_err());
    }

    #[test]
    fn rejects_malformed_number() {
        let err = lex("tile_m: 32abc;").unwrap_err();
        assert!(err.msg.contains("malformed number"), "{err}");
    }

    #[test]
    fn positions_tracked() {
        let toks = lex("a\n  b").unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn bools_are_tokens() {
        let toks = lex("true false truthy").unwrap();
        assert_eq!(toks[0].tok, Tok::Bool(true));
        assert_eq!(toks[1].tok, Tok::Bool(false));
        assert_eq!(toks[2].tok, Tok::Ident("truthy".into()));
    }
}
