//! Canonical KernelScript printer. `parse(print(spec)) == spec` is the
//! round-trip invariant (proptest-checked in rust/tests/proptests.rs);
//! the SimLLM emits candidate programs through this printer before
//! (possibly) corrupting them with syntax defects.

use super::ast::KernelSpec;

/// Render a spec as canonical KernelScript text.
pub fn print(spec: &KernelSpec) -> String {
    let s = &spec.schedule;
    format!(
        "kernel {op} {{\n  semantics: {sem};\n  schedule {{\n    tile_m: {tm}; tile_n: {tn}; tile_k: {tk};\n    vector_width: {vw}; unroll: {un}; stages: {st};\n    smem_staging: {sm}; fuse_epilogue: {fe};\n    layout: {lay};\n    threads_per_block: {tpb}; regs_per_thread: {rpt};\n  }}\n}}\n",
        op = spec.op,
        sem = spec.semantics,
        tm = s.tile_m,
        tn = s.tile_n,
        tk = s.tile_k,
        vw = s.vector_width,
        un = s.unroll,
        st = s.stages,
        sm = s.smem_staging,
        fe = s.fuse_epilogue,
        lay = s.layout.as_str(),
        tpb = s.threads_per_block,
        rpt = s.regs_per_thread,
    )
}

#[cfg(test)]
mod tests {
    use super::super::ast::{Layout, Schedule};
    use super::super::parser::parse;
    use super::*;

    #[test]
    fn roundtrip_default() {
        let spec = KernelSpec::baseline("softmax_64");
        assert_eq!(parse(&print(&spec)).unwrap(), spec);
    }

    #[test]
    fn roundtrip_nontrivial() {
        let spec = KernelSpec {
            op: "conv2d_k3_c8".into(),
            semantics: "bug_scale".into(),
            schedule: Schedule {
                tile_m: 64,
                tile_n: 128,
                tile_k: 32,
                vector_width: 8,
                unroll: 4,
                stages: 3,
                smem_staging: true,
                fuse_epilogue: true,
                layout: Layout::ColMajor,
                threads_per_block: 512,
                regs_per_thread: 96,
            },
        };
        assert_eq!(parse(&print(&spec)).unwrap(), spec);
    }

    #[test]
    fn printed_text_is_stable() {
        let spec = KernelSpec::baseline("matmul_64");
        assert_eq!(print(&spec), print(&parse(&print(&spec)).unwrap()));
    }
}
