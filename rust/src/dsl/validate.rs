//! Schedule validation — the "nvcc resource check" half of the compile
//! gate (paper §4.3 "Compilation Check"). RTX-4090 (sm_89) limits:
//!
//! * threads/block: 32..=1024, multiple of 32 (warp granularity)
//! * registers/thread: 16..=255 (hardware encodable range)
//! * shared memory/block: <= 99 KiB (sm_89 opt-in maximum)
//! * vector width in {1,2,4,8} (float/float2/float4/double4 packing)
//! * stages 1..=4, unroll 1..=16, tile dims 1..=256
//! * estimated register pressure must fit regs_per_thread (spill ->
//!   hard error above the 255 ceiling, soft perf penalty otherwise —
//!   the cost model prices the soft case)

use std::fmt;

use super::ast::{KernelSpec, Schedule};

/// sm_89 per-block shared-memory ceiling (bytes).
pub const MAX_SMEM_BYTES: u64 = 99 * 1024;
pub const MAX_THREADS: u32 = 1024;
pub const MAX_REGS: u32 = 255;
pub const MAX_TILE: u32 = 256;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationError(pub String);

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

impl std::error::Error for ValidationError {}

fn err(msg: impl Into<String>) -> Result<(), ValidationError> {
    Err(ValidationError(msg.into()))
}

/// Validate one schedule against the hardware model.
pub fn validate_schedule(s: &Schedule) -> Result<(), ValidationError> {
    for (name, v) in [("tile_m", s.tile_m), ("tile_n", s.tile_n), ("tile_k", s.tile_k)] {
        if v == 0 || v > MAX_TILE {
            return err(format!("{name}={v} outside 1..={MAX_TILE}"));
        }
    }
    if !matches!(s.vector_width, 1 | 2 | 4 | 8) {
        return err(format!(
            "vector_width={} not a supported packing (1/2/4/8)",
            s.vector_width
        ));
    }
    if s.unroll == 0 || s.unroll > 16 {
        return err(format!("unroll={} outside 1..=16", s.unroll));
    }
    if s.stages == 0 || s.stages > 4 {
        return err(format!("stages={} outside 1..=4", s.stages));
    }
    if s.stages > 1 && !s.smem_staging {
        return err("multi-stage pipelining requires smem_staging");
    }
    if s.threads_per_block < 32
        || s.threads_per_block > MAX_THREADS
        || s.threads_per_block % 32 != 0
    {
        return err(format!(
            "threads_per_block={} must be a multiple of 32 in 32..={MAX_THREADS}",
            s.threads_per_block
        ));
    }
    if s.regs_per_thread < 16 || s.regs_per_thread > MAX_REGS {
        return err(format!(
            "regs_per_thread={} outside 16..={MAX_REGS}",
            s.regs_per_thread
        ));
    }
    let smem = s.smem_bytes();
    if smem > MAX_SMEM_BYTES {
        return err(format!(
            "shared memory {smem}B exceeds the {MAX_SMEM_BYTES}B/block limit (sm_89)"
        ));
    }
    if s.est_registers() > MAX_REGS {
        return err(format!(
            "estimated register pressure {} exceeds the {MAX_REGS}-register ceiling \
             (output tile too large for the block)",
            s.est_registers()
        ));
    }
    Ok(())
}

/// Validate a whole program (schedule checks; op/semantics existence is
/// checked at lowering time against the artifact manifest).
pub fn validate(spec: &KernelSpec) -> Result<(), ValidationError> {
    if spec.op.is_empty() {
        return err("empty kernel name");
    }
    if spec.semantics.is_empty() {
        return err("empty semantics variant");
    }
    validate_schedule(&spec.schedule)
}

#[cfg(test)]
mod tests {
    use super::super::ast::KernelSpec;
    use super::*;

    #[test]
    fn baseline_is_valid() {
        validate(&KernelSpec::baseline("matmul_64")).unwrap();
    }

    #[test]
    fn smem_overflow_rejected() {
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.schedule.smem_staging = true;
        spec.schedule.tile_m = 256;
        spec.schedule.tile_n = 256;
        spec.schedule.tile_k = 64;
        spec.schedule.stages = 4;
        spec.schedule.threads_per_block = 1024;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("shared memory"), "{e}");
    }

    #[test]
    fn bad_vector_width_rejected() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.vector_width = 3;
        assert!(validate(&spec).is_err());
    }

    #[test]
    fn warp_granularity_enforced() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.threads_per_block = 100;
        assert!(validate(&spec).is_err());
        spec.schedule.threads_per_block = 0;
        assert!(validate(&spec).is_err());
        spec.schedule.threads_per_block = 2048;
        assert!(validate(&spec).is_err());
    }

    #[test]
    fn staging_requires_smem() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.stages = 2;
        spec.schedule.smem_staging = false;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("smem_staging"), "{e}");
    }

    #[test]
    fn register_ceiling_enforced() {
        let mut spec = KernelSpec::baseline("x");
        // 256x256 output tile over 32 threads -> 2048 acc registers
        spec.schedule.tile_m = 256;
        spec.schedule.tile_n = 256;
        spec.schedule.threads_per_block = 32;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("register"), "{e}");
    }

    #[test]
    fn zero_tile_rejected() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.tile_k = 0;
        assert!(validate(&spec).is_err());
    }
}
