//! Schedule validation — the "nvcc resource check" half of the compile
//! gate (paper §4.3 "Compilation Check"). RTX-4090 (sm_89) limits:
//!
//! * threads/block: 32..=1024, multiple of 32 (warp granularity)
//! * registers/thread: 16..=255 (hardware encodable range)
//! * shared memory/block: <= 99 KiB (sm_89 opt-in maximum)
//! * vector width in {1,2,4,8} (float/float2/float4/double4 packing)
//! * stages 1..=4, unroll 1..=16, tile dims 1..=256
//! * estimated register pressure must fit regs_per_thread (spill ->
//!   hard error above the 255 ceiling, soft perf penalty otherwise —
//!   the cost model prices the soft case)
//!
//! Two views of the same rules:
//! * [`validate`] / [`validate_schedule`] — the historical first-error
//!   compile-gate API (stage 1 of the evaluation pipeline);
//! * [`schedule_violations`] — the *exhaustive* structured checker the
//!   stage-0 guard consumes: every violated limit is reported, each
//!   tagged with a [`ViolationKind`] and the offending field, so the
//!   repair loop can target fixes instead of re-discovering limits one
//!   compile at a time.

use std::fmt;

use super::ast::{KernelSpec, Schedule};

/// sm_89 per-block shared-memory ceiling (bytes).
pub const MAX_SMEM_BYTES: u64 = 99 * 1024;
pub const MAX_THREADS: u32 = 1024;
pub const MAX_REGS: u32 = 255;
pub const MAX_TILE: u32 = 256;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationError(pub String);

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

impl std::error::Error for ValidationError {}

/// Which hardware limit a schedule violates. The guard maps these to
/// structured diagnostics; the repair loop keys targeted fixes on them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// Tile dimension outside 1..=[`MAX_TILE`].
    TileRange,
    /// Vector width not a supported packing (1/2/4/8).
    VectorWidth,
    /// Unroll factor outside 1..=16.
    Unroll,
    /// Pipeline stages outside 1..=4.
    Stages,
    /// Multi-stage pipelining without shared-memory staging.
    StagingRequired,
    /// Threads/block not a multiple of 32 in 32..=[`MAX_THREADS`].
    ThreadsPerBlock,
    /// Register budget outside 16..=[`MAX_REGS`].
    RegsRange,
    /// Shared-memory request over the per-block ceiling.
    SmemOverflow,
    /// Estimated register pressure over the hardware ceiling.
    RegPressure,
}

/// One violated limit: kind + offending field + human message (the
/// message text matches what the first-error gate has always emitted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub kind: ViolationKind,
    pub field: &'static str,
    pub message: String,
}

/// Exhaustive structured check of one schedule against the hardware
/// model: *every* violated limit is returned, in a fixed deterministic
/// order (same schedule → same list).
pub fn schedule_violations(s: &Schedule) -> Vec<Violation> {
    let mut v = Vec::new();
    for (name, val) in [("tile_m", s.tile_m), ("tile_n", s.tile_n), ("tile_k", s.tile_k)] {
        if val == 0 || val > MAX_TILE {
            v.push(Violation {
                kind: ViolationKind::TileRange,
                field: name,
                message: format!("{name}={val} outside 1..={MAX_TILE}"),
            });
        }
    }
    if !matches!(s.vector_width, 1 | 2 | 4 | 8) {
        v.push(Violation {
            kind: ViolationKind::VectorWidth,
            field: "vector_width",
            message: format!(
                "vector_width={} not a supported packing (1/2/4/8)",
                s.vector_width
            ),
        });
    }
    if s.unroll == 0 || s.unroll > 16 {
        v.push(Violation {
            kind: ViolationKind::Unroll,
            field: "unroll",
            message: format!("unroll={} outside 1..=16", s.unroll),
        });
    }
    if s.stages == 0 || s.stages > 4 {
        v.push(Violation {
            kind: ViolationKind::Stages,
            field: "stages",
            message: format!("stages={} outside 1..=4", s.stages),
        });
    }
    if s.stages > 1 && !s.smem_staging {
        v.push(Violation {
            kind: ViolationKind::StagingRequired,
            field: "smem_staging",
            message: "multi-stage pipelining requires smem_staging".into(),
        });
    }
    if s.threads_per_block < 32
        || s.threads_per_block > MAX_THREADS
        || s.threads_per_block % 32 != 0
    {
        v.push(Violation {
            kind: ViolationKind::ThreadsPerBlock,
            field: "threads_per_block",
            message: format!(
                "threads_per_block={} must be a multiple of 32 in 32..={MAX_THREADS}",
                s.threads_per_block
            ),
        });
    }
    if s.regs_per_thread < 16 || s.regs_per_thread > MAX_REGS {
        v.push(Violation {
            kind: ViolationKind::RegsRange,
            field: "regs_per_thread",
            message: format!(
                "regs_per_thread={} outside 16..={MAX_REGS}",
                s.regs_per_thread
            ),
        });
    }
    let smem = s.smem_bytes();
    if smem > MAX_SMEM_BYTES {
        v.push(Violation {
            kind: ViolationKind::SmemOverflow,
            field: "smem_staging",
            message: format!(
                "shared memory {smem}B exceeds the {MAX_SMEM_BYTES}B/block limit (sm_89)"
            ),
        });
    }
    if s.est_registers() > MAX_REGS {
        v.push(Violation {
            kind: ViolationKind::RegPressure,
            field: "regs_per_thread",
            message: format!(
                "estimated register pressure {} exceeds the {MAX_REGS}-register ceiling \
                 (output tile too large for the block)",
                s.est_registers()
            ),
        });
    }
    v
}

/// Validate one schedule against the hardware model (first violation
/// wins — the historical compile-gate behaviour).
pub fn validate_schedule(s: &Schedule) -> Result<(), ValidationError> {
    match schedule_violations(s).into_iter().next() {
        Some(v) => Err(ValidationError(v.message)),
        None => Ok(()),
    }
}

/// Validate a whole program (schedule checks; op/semantics existence is
/// checked at lowering time against the artifact manifest).
pub fn validate(spec: &KernelSpec) -> Result<(), ValidationError> {
    if spec.op.is_empty() {
        return Err(ValidationError("empty kernel name".into()));
    }
    if spec.semantics.is_empty() {
        return Err(ValidationError("empty semantics variant".into()));
    }
    validate_schedule(&spec.schedule)
}

#[cfg(test)]
mod tests {
    use super::super::ast::KernelSpec;
    use super::*;

    #[test]
    fn baseline_is_valid() {
        validate(&KernelSpec::baseline("matmul_64")).unwrap();
        assert!(schedule_violations(&KernelSpec::baseline("matmul_64").schedule).is_empty());
    }

    #[test]
    fn smem_overflow_rejected() {
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.schedule.smem_staging = true;
        spec.schedule.tile_m = 256;
        spec.schedule.tile_n = 256;
        spec.schedule.tile_k = 64;
        spec.schedule.stages = 4;
        spec.schedule.threads_per_block = 1024;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("shared memory"), "{e}");
    }

    #[test]
    fn bad_vector_width_rejected() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.vector_width = 3;
        assert!(validate(&spec).is_err());
    }

    #[test]
    fn warp_granularity_enforced() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.threads_per_block = 100;
        assert!(validate(&spec).is_err());
        spec.schedule.threads_per_block = 0;
        assert!(validate(&spec).is_err());
        spec.schedule.threads_per_block = 2048;
        assert!(validate(&spec).is_err());
    }

    #[test]
    fn staging_requires_smem() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.stages = 2;
        spec.schedule.smem_staging = false;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("smem_staging"), "{e}");
    }

    #[test]
    fn register_ceiling_enforced() {
        let mut spec = KernelSpec::baseline("x");
        // 256x256 output tile over 32 threads -> 2048 acc registers
        spec.schedule.tile_m = 256;
        spec.schedule.tile_n = 256;
        spec.schedule.threads_per_block = 32;
        let e = validate(&spec).unwrap_err();
        assert!(e.0.contains("register"), "{e}");
    }

    #[test]
    fn zero_tile_rejected() {
        let mut spec = KernelSpec::baseline("x");
        spec.schedule.tile_k = 0;
        assert!(validate(&spec).is_err());
    }

    #[test]
    fn violations_are_exhaustive_and_tagged() {
        // One schedule, three simultaneous limit breaks: the structured
        // checker reports all of them; the legacy gate only the first.
        let mut s = KernelSpec::baseline("x").schedule;
        s.tile_m = 0; // TileRange
        s.vector_width = 5; // VectorWidth
        s.threads_per_block = 100; // ThreadsPerBlock
        let v = schedule_violations(&s);
        let kinds: Vec<ViolationKind> = v.iter().map(|x| x.kind).collect();
        assert!(kinds.contains(&ViolationKind::TileRange), "{v:?}");
        assert!(kinds.contains(&ViolationKind::VectorWidth), "{v:?}");
        assert!(kinds.contains(&ViolationKind::ThreadsPerBlock), "{v:?}");
        assert!(v.len() >= 3);
        // First-error wrapper reports the first of the same list.
        let e = validate_schedule(&s).unwrap_err();
        assert_eq!(e.0, v[0].message);
        // Deterministic: same schedule, same list.
        assert_eq!(schedule_violations(&s), v);
    }
}
