//! KernelScript — the raw-text code space `S_text` (paper §3.1).
//!
//! The paper evolves CUDA C source strings; our substitution (DESIGN.md
//! §2) evolves KernelScript programs: a small, fully-parseable kernel
//! language whose `semantics` block selects which AOT-lowered HLO
//! artifact the program computes (functional truth, executed on PJRT)
//! and whose `schedule` block is the CUDA-flavoured performance genome
//! the cost model prices (tiles, vector width, staging, occupancy
//! knobs).
//!
//! Like the paper's `S_text`, *most strings are invalid*: the lexer and
//! parser reject malformed text (syntactic validity), the validator
//! rejects illegal schedules (the "nvcc" resource checks: shared-memory
//! overflow, bad block sizes, register limits), and unknown semantics
//! variants fail artifact resolution — the three real failure modes the
//! SimLLM's defect injection exercises.
//!
//! ```text
//! kernel matmul_64 {
//!   semantics: opt;
//!   schedule {
//!     tile_m: 32; tile_n: 32; tile_k: 16;
//!     vector_width: 4; unroll: 2; stages: 2;
//!     smem_staging: true; fuse_epilogue: true;
//!     layout: row_major;
//!     threads_per_block: 256; regs_per_thread: 64;
//!   }
//! }
//! ```

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod printer;
pub mod validate;

pub use ast::{KernelSpec, Layout, Schedule};
pub use parser::parse;
pub use printer::print;
pub use validate::{validate, ValidationError};

/// Parse + validate in one step (the "compile front-end").
pub fn compile_front(src: &str) -> Result<KernelSpec, String> {
    let spec = parse(src).map_err(|e| format!("syntax error: {e}"))?;
    validate(&spec).map_err(|e| format!("validation error: {e}"))?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_example() {
        let spec = KernelSpec::baseline("matmul_64");
        let text = print(&spec);
        let back = parse(&text).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn front_rejects_garbage() {
        assert!(compile_front("__global__ void k() {}").is_err());
        assert!(compile_front("").is_err());
        assert!(compile_front("kernel x { semantics: ref;").is_err());
    }
}
