//! KernelScript recursive-descent parser.
//!
//! Grammar:
//! ```text
//! program  := "kernel" IDENT "{" field* "}"
//! field    := "semantics" ":" IDENT ";"
//!           | "schedule" "{" sched* "}"
//! sched    := IDENT ":" (INT | BOOL | IDENT) ";"
//! ```
//! Unknown schedule *fields* are a parse error (mirrors an undeclared
//! identifier in CUDA); out-of-range *values* are left to the validator
//! (mirrors nvcc resource errors).

use std::fmt;

use super::ast::{KernelSpec, Layout, Schedule};
use super::lexer::{lex, Spanned, Tok};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub line: u32,
    pub col: u32,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum PeekKind {
    RBrace,
    Ident,
    Other,
    End,
}

impl Parser {
    fn peek(&self) -> Option<&Spanned> {
        self.toks.get(self.pos)
    }

    fn peek_word(&self) -> &str {
        match self.peek().map(|t| &t.tok) {
            Some(Tok::Ident(s)) => s.as_str(),
            _ => "",
        }
    }

    fn err_here(&self, msg: impl Into<String>) -> ParseError {
        let (line, col) = self
            .peek()
            .map(|t| (t.line, t.col))
            .or_else(|| self.toks.last().map(|t| (t.line, t.col + 1)))
            .unwrap_or((1, 1));
        ParseError { msg: msg.into(), line, col }
    }

    /// Advance and return a reference to the consumed token (perf: the
    /// hot compile path must not clone token Strings — see
    /// EXPERIMENTS.md §Perf).
    fn next(&mut self) -> Result<&Spanned, ParseError> {
        match self.toks.get(self.pos) {
            Some(_) => {
                self.pos += 1;
                Ok(&self.toks[self.pos - 1])
            }
            None => Err(self.err_here("unexpected end of input")),
        }
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<(), ParseError> {
        let t = self.next()?;
        if &t.tok == want {
            Ok(())
        } else {
            Err(ParseError {
                msg: format!("expected {what}, found {}", t.tok),
                line: t.line,
                col: t.col,
            })
        }
    }

    fn expect_ident(&mut self, what: &str) -> Result<String, ParseError> {
        let t = self.next()?;
        match &t.tok {
            Tok::Ident(s) => Ok(s.clone()),
            other => Err(ParseError {
                msg: format!("expected {what}, found {other}"),
                line: t.line,
                col: t.col,
            }),
        }
    }

    fn expect_u32(&mut self, field: &str) -> Result<u32, ParseError> {
        let t = self.next()?;
        match t.tok {
            Tok::Int(n) if n <= u32::MAX as u64 => Ok(n as u32),
            Tok::Int(n) => Err(ParseError {
                msg: format!("value {n} for `{field}` out of integer range"),
                line: t.line,
                col: t.col,
            }),
            ref other => Err(ParseError {
                msg: format!("expected integer for `{field}`, found {other}"),
                line: t.line,
                col: t.col,
            }),
        }
    }

    fn expect_bool(&mut self, field: &str) -> Result<bool, ParseError> {
        let t = self.next()?;
        match &t.tok {
            Tok::Bool(b) => Ok(*b),
            other => Err(ParseError {
                msg: format!("expected true/false for `{field}`, found {other}"),
                line: t.line,
                col: t.col,
            }),
        }
    }

    /// Clone-free peek classification (hot path).
    fn peek_kind(&self) -> PeekKind {
        match self.peek().map(|t| &t.tok) {
            Some(Tok::RBrace) => PeekKind::RBrace,
            Some(Tok::Ident(_)) => PeekKind::Ident,
            Some(_) => PeekKind::Other,
            None => PeekKind::End,
        }
    }

    fn parse_schedule(&mut self) -> Result<Schedule, ParseError> {
        self.expect(&Tok::LBrace, "`{` after `schedule`")?;
        let mut sched = Schedule::default();
        loop {
            match self.peek_kind() {
                PeekKind::RBrace => {
                    self.pos += 1;
                    return Ok(sched);
                }
                PeekKind::Ident => {
                    let name = self.expect_ident("schedule field")?;
                    self.expect(&Tok::Colon, "`:`")?;
                    match name.as_str() {
                        "tile_m" => sched.tile_m = self.expect_u32(&name)?,
                        "tile_n" => sched.tile_n = self.expect_u32(&name)?,
                        "tile_k" => sched.tile_k = self.expect_u32(&name)?,
                        "vector_width" => sched.vector_width = self.expect_u32(&name)?,
                        "unroll" => sched.unroll = self.expect_u32(&name)?,
                        "stages" => sched.stages = self.expect_u32(&name)?,
                        "threads_per_block" => {
                            sched.threads_per_block = self.expect_u32(&name)?
                        }
                        "regs_per_thread" => sched.regs_per_thread = self.expect_u32(&name)?,
                        "smem_staging" => sched.smem_staging = self.expect_bool(&name)?,
                        "fuse_epilogue" => sched.fuse_epilogue = self.expect_bool(&name)?,
                        "layout" => {
                            let t = self.next()?;
                            let (line, col) = (t.line, t.col);
                            match &t.tok {
                                Tok::Ident(s) => {
                                    sched.layout =
                                        Layout::from_str(s).ok_or_else(|| ParseError {
                                            msg: format!("unknown layout `{s}`"),
                                            line,
                                            col,
                                        })?
                                }
                                other => {
                                    return Err(ParseError {
                                        msg: format!("expected layout name, found {other}"),
                                        line,
                                        col,
                                    })
                                }
                            }
                        }
                        unknown => {
                            return Err(self.err_here(format!(
                                "unknown schedule field `{unknown}`"
                            )))
                        }
                    }
                    self.expect(&Tok::Semi, "`;`")?;
                }
                _ => return Err(self.err_here("expected schedule field or `}`")),
            }
        }
    }

    fn parse_program(&mut self) -> Result<KernelSpec, ParseError> {
        let kw = self.expect_ident("`kernel`")?;
        if kw != "kernel" {
            return Err(self.err_here(format!("expected `kernel`, found `{kw}`")));
        }
        let op = self.expect_ident("kernel name")?;
        self.expect(&Tok::LBrace, "`{`")?;

        let mut semantics: Option<String> = None;
        let mut schedule: Option<Schedule> = None;
        loop {
            match self.peek_kind() {
                PeekKind::RBrace => {
                    self.pos += 1;
                    break;
                }
                PeekKind::Ident => match self.peek_word() {
                    "semantics" => {
                        self.pos += 1;
                        self.expect(&Tok::Colon, "`:`")?;
                        let v = self.expect_ident("semantics variant")?;
                        if semantics.replace(v).is_some() {
                            return Err(self.err_here("duplicate `semantics`"));
                        }
                        self.expect(&Tok::Semi, "`;`")?;
                    }
                    "schedule" => {
                        self.pos += 1;
                        if schedule.replace(self.parse_schedule()?).is_some() {
                            return Err(self.err_here("duplicate `schedule`"));
                        }
                    }
                    other => {
                        let msg = format!("unknown section `{other}`");
                        return Err(self.err_here(msg));
                    }
                },
                _ => return Err(self.err_here("expected `semantics`, `schedule`, or `}`")),
            }
        }
        if self.pos != self.toks.len() {
            return Err(self.err_here("trailing tokens after program"));
        }
        let semantics =
            semantics.ok_or_else(|| self.err_here("missing `semantics` declaration"))?;
        Ok(KernelSpec {
            op,
            semantics,
            schedule: schedule.unwrap_or_default(),
        })
    }
}

/// Parse a KernelScript program.
pub fn parse(src: &str) -> Result<KernelSpec, ParseError> {
    let toks = lex(src).map_err(|e| ParseError { msg: e.msg, line: e.line, col: e.col })?;
    Parser { toks, pos: 0 }.parse_program()
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
kernel matmul_64 {
  semantics: opt;
  schedule {
    tile_m: 32; tile_n: 32; tile_k: 16;
    vector_width: 4; unroll: 2; stages: 2;
    smem_staging: true; fuse_epilogue: true;
    layout: tiled;
    threads_per_block: 256; regs_per_thread: 64;
  }
}
"#;

    #[test]
    fn parses_full_program() {
        let spec = parse(GOOD).unwrap();
        assert_eq!(spec.op, "matmul_64");
        assert_eq!(spec.semantics, "opt");
        assert_eq!(spec.schedule.tile_m, 32);
        assert_eq!(spec.schedule.layout, Layout::Tiled);
        assert!(spec.schedule.smem_staging);
    }

    #[test]
    fn defaults_fill_missing_schedule() {
        let spec = parse("kernel x { semantics: ref; }").unwrap();
        assert_eq!(spec.schedule, Schedule::default());
    }

    #[test]
    fn missing_semantics_is_error() {
        let err = parse("kernel x { }").unwrap_err();
        assert!(err.msg.contains("semantics"), "{err}");
    }

    #[test]
    fn unknown_field_is_error() {
        let err = parse("kernel x { semantics: ref; schedule { warp_size: 32; } }")
            .unwrap_err();
        assert!(err.msg.contains("warp_size"), "{err}");
    }

    #[test]
    fn unbalanced_brace_is_error() {
        assert!(parse("kernel x { semantics: ref;").is_err());
    }

    #[test]
    fn duplicate_sections_rejected() {
        assert!(parse("kernel x { semantics: a; semantics: b; }").is_err());
        assert!(parse("kernel x { semantics: a; schedule {} schedule {} }").is_err());
    }

    #[test]
    fn missing_semicolon_is_error() {
        let err =
            parse("kernel x { semantics: ref; schedule { tile_m: 8 tile_n: 8; } }").unwrap_err();
        assert!(err.msg.contains("expected `;`"), "{err}");
    }

    #[test]
    fn error_positions_point_at_problem() {
        let err = parse("kernel x {\n  semantics: ref;\n  bogus: 1;\n}").unwrap_err();
        assert_eq!(err.line, 3);
    }
}
