//! KernelScript abstract syntax tree.

/// Memory layout of the operand staging (the CUDA coalescing analogue;
/// on TPU this is the HBM→VMEM tiling order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    RowMajor,
    ColMajor,
    Tiled,
}

impl Layout {
    pub fn as_str(self) -> &'static str {
        match self {
            Layout::RowMajor => "row_major",
            Layout::ColMajor => "col_major",
            Layout::Tiled => "tiled",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "row_major" => Some(Layout::RowMajor),
            "col_major" => Some(Layout::ColMajor),
            "tiled" => Some(Layout::Tiled),
            _ => None,
        }
    }
}

/// The performance genome: a CUDA-flavoured schedule the cost model
/// prices. Field vocabulary follows the paper's optimization landscape
/// (§1: "memory coalescing, thread divergence, occupancy optimization,
/// and register usage").
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub tile_m: u32,
    pub tile_n: u32,
    pub tile_k: u32,
    /// Per-thread vector load width (1/2/4/8 — float4-style packing).
    pub vector_width: u32,
    /// Inner-loop unroll factor.
    pub unroll: u32,
    /// Software-pipelining stages (double/triple buffering).
    pub stages: u32,
    /// Stage operand tiles through shared memory (VMEM on TPU).
    pub smem_staging: bool,
    /// Fuse the op's epilogue (bias/activation/residual) into the kernel.
    pub fuse_epilogue: bool,
    pub layout: Layout,
    pub threads_per_block: u32,
    pub regs_per_thread: u32,
}

impl Default for Schedule {
    /// The naive initial schedule — the paper's "initial C++/CUDA
    /// implementation serving as the starting point for optimization".
    fn default() -> Self {
        Schedule {
            tile_m: 8,
            tile_n: 8,
            tile_k: 8,
            vector_width: 1,
            unroll: 1,
            stages: 1,
            smem_staging: false,
            fuse_epilogue: false,
            layout: Layout::RowMajor,
            threads_per_block: 128,
            regs_per_thread: 32,
        }
    }
}

impl Schedule {
    /// Shared-memory bytes this schedule requests per block (f32).
    pub fn smem_bytes(&self) -> u64 {
        if !self.smem_staging {
            return 0;
        }
        let per_stage = (self.tile_m as u64 * self.tile_k as u64)
            + (self.tile_k as u64 * self.tile_n as u64);
        per_stage * self.stages as u64 * 4
    }

    /// Crude per-thread register-pressure estimate: accumulator slice
    /// of the output tile plus vector/unroll operand registers.
    pub fn est_registers(&self) -> u32 {
        let acc = (self.tile_m as u64 * self.tile_n as u64)
            .div_ceil(self.threads_per_block.max(1) as u64) as u32;
        acc + 2 * self.vector_width * self.unroll + 8
    }
}

/// A complete KernelScript program: one kernel for one dataset op.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct KernelSpec {
    /// Which dataset operation this kernel implements.
    pub op: String,
    /// Which semantic variant it computes (must name an AOT artifact:
    /// ref / opt / bug_scale / bug_offset — or a hallucination).
    pub semantics: String,
    pub schedule: Schedule,
}

impl KernelSpec {
    /// The baseline kernel the optimization starts from (paper §5.1:
    /// "an initial C++/CUDA implementation to serve as the starting
    /// point"): correct semantics, naive schedule.
    pub fn baseline(op: &str) -> Self {
        KernelSpec {
            op: op.to_string(),
            semantics: "opt".to_string(),
            schedule: Schedule::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smem_accounting() {
        let mut s = Schedule::default();
        assert_eq!(s.smem_bytes(), 0);
        s.smem_staging = true;
        s.tile_m = 32;
        s.tile_n = 32;
        s.tile_k = 16;
        s.stages = 2;
        // 2 stages * (32*16 + 16*32) * 4B = 8192
        assert_eq!(s.smem_bytes(), 8192);
    }

    #[test]
    fn register_estimate_scales_with_tile() {
        let mut s = Schedule::default();
        let r0 = s.est_registers();
        s.tile_m = 128;
        s.tile_n = 128;
        assert!(s.est_registers() > r0);
    }

    #[test]
    fn layout_roundtrip() {
        for l in [Layout::RowMajor, Layout::ColMajor, Layout::Tiled] {
            assert_eq!(Layout::from_str(l.as_str()), Some(l));
        }
        assert_eq!(Layout::from_str("zigzag"), None);
    }
}
