//! Seed-deterministic multi-armed bandit over
//! (ensemble member × operator × op-category) arms (DESIGN.md §16).
//!
//! The engine owns one [`Bandit`] per campaign cell (it lives in the
//! method `Session`, never in the shared provider), selects an arm at
//! request-assembly time, and feeds eval/guard outcomes back after
//! each trial completes. That placement is the whole determinism
//! story:
//!
//! * **selection is pure** — [`Bandit::select`] is a function of the
//!   arm statistics, the configured prior weights, the exploration
//!   ratio, and the request's already-derived llm seed (mixed, never
//!   drawn from an [`Rng`] — no new derivation points, DESIGN.md §13);
//! * **updates are sequential** — only [`finish_trial`] mutates arms,
//!   and trials finish in order within a cell, so the arm state a
//!   trial observes is independent of `--prefetch`. A speculative
//!   request assembled against stale arm state simply hash-misses the
//!   prefetch pool and is re-issued live: mis-speculation costs
//!   throughput, never correctness.
//!
//! Rewards follow the validity-first framing the paper centers:
//! a correct kernel earns 1.0 plus a capped speedup bonus, functional/
//! runtime failures earn a sliver (the arm produced something
//! compilable), compile failures nearly nothing, and stage-0 guard
//! rejections zero. Repair arms are scored by whether the repaired
//! emission passed the guard.
//!
//! [`Rng`]: crate::util::Rng
//! [`finish_trial`]: crate::methods::engine

use std::collections::BTreeMap;

use super::ensemble::RoutingSpec;

/// Exported learned state of one arm — attached to run records and
/// surfaced by `report tokens`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArmWeight {
    pub member: String,
    pub operator: String,
    pub category: String,
    pub pulls: u64,
    /// Mean observed reward (the "learned weight").
    pub mean_reward: f64,
}

#[derive(Debug, Clone, Copy, Default)]
struct ArmStat {
    pulls: u64,
    reward_sum: f64,
}

/// UCB-style bandit with weighted-prior exploration. See the module
/// docs for where it lives and why.
#[derive(Debug, Clone)]
pub struct Bandit {
    /// `(alias, prior weight)` in spec order — the deterministic
    /// tie-break order.
    members: Vec<(String, f64)>,
    exploration_ratio: f64,
    arms: BTreeMap<(String, String, String), ArmStat>,
}

impl Bandit {
    pub fn new(spec: &RoutingSpec) -> Self {
        Self {
            members: spec.members.clone(),
            exploration_ratio: spec.exploration_ratio,
            arms: BTreeMap::new(),
        }
    }

    fn stat(&self, member: &str, operator: &str, category: &str) -> ArmStat {
        self.arms
            .get(&(member.to_string(), operator.to_string(), category.to_string()))
            .copied()
            .unwrap_or_default()
    }

    /// Pick the member to route a `(operator, category)` call to.
    /// Pure: same statistics + same `seed` → same member, regardless
    /// of prefetch, threading, or how often it is called.
    ///
    /// With probability `exploration_ratio` (decided by a mix of
    /// `seed`), or while the context is entirely unexplored, the pick
    /// is weighted by the configured priors; otherwise the
    /// highest-UCB arm wins, unpulled arms first, ties broken by spec
    /// order.
    pub fn select(&self, operator: &str, category: &str, seed: u64) -> String {
        debug_assert!(!self.members.is_empty());
        let total_pulls: u64 = self
            .members
            .iter()
            .map(|(alias, _)| self.stat(alias, operator, category).pulls)
            .sum();
        let explore = unit(mix(seed, 0x9E37_79B9_7F4A_7C15));
        if total_pulls == 0 || explore < self.exploration_ratio {
            return self.weighted_pick(unit(mix(seed, 0xD1B5_4A32_D192_ED03)));
        }
        let ln_total = (total_pulls as f64).ln();
        let mut best: Option<(usize, f64)> = None;
        for (i, (alias, _)) in self.members.iter().enumerate() {
            let s = self.stat(alias, operator, category);
            let score = if s.pulls == 0 {
                // Force a first pull before trusting any mean.
                f64::INFINITY
            } else {
                s.reward_sum / s.pulls as f64
                    + self.exploration_ratio * (2.0 * ln_total / s.pulls as f64).sqrt()
            };
            // Strictly-greater keeps the first (spec-order) arm on
            // ties — including INFINITY vs INFINITY.
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((i, score));
            }
        }
        self.members[best.expect("non-empty members").0].0.clone()
    }

    fn weighted_pick(&self, u: f64) -> String {
        let total: f64 = self.members.iter().map(|(_, w)| w).sum();
        let target = u * total;
        let mut acc = 0.0;
        for (alias, w) in &self.members {
            acc += w;
            if target < acc {
                return alias.clone();
            }
        }
        self.members.last().expect("non-empty members").0.clone()
    }

    /// Record one observed reward for an arm. Called only from the
    /// engine's sequential trial-completion path.
    pub fn update(&mut self, member: &str, operator: &str, category: &str, reward: f64) {
        let e = self
            .arms
            .entry((member.to_string(), operator.to_string(), category.to_string()))
            .or_default();
        e.pulls += 1;
        e.reward_sum += reward;
    }

    /// Learned arm state, sorted by (member, operator, category).
    pub fn arms(&self) -> Vec<ArmWeight> {
        self.arms
            .iter()
            .map(|((member, operator, category), s)| ArmWeight {
                member: member.clone(),
                operator: operator.clone(),
                category: category.clone(),
                pulls: s.pulls,
                mean_reward: if s.pulls == 0 { 0.0 } else { s.reward_sum / s.pulls as f64 },
            })
            .collect()
    }
}

/// Reward for a generate arm, from the trial's outcome label (the
/// engine's `outcome_label`) and the measured speedup of a correct
/// kernel. Correctness dominates; the speedup bonus is capped at 4×
/// so one lucky kernel cannot lock the bandit in.
pub fn trial_reward(outcome: &str, speedup: Option<f64>) -> f64 {
    match outcome {
        "ok" => {
            let s = speedup.unwrap_or(1.0).clamp(1.0, 4.0);
            1.0 + (s - 1.0) / 3.0
        }
        "functional_fail" | "runtime_fail" => 0.2,
        "compile_fail" => 0.05,
        _ => 0.0, // guard_reject and anything unrecognised
    }
}

/// Reward for a repair arm: did the repaired emission pass stage 0?
pub fn repair_reward(guard_pass: bool) -> f64 {
    if guard_pass {
        1.0
    } else {
        0.0
    }
}

/// Structured operator tag from a method's free-form generation
/// instruction: first word, ascii-lowercased, truncated. Stable
/// against prompt-template wording changes *after* the first word,
/// which is all the arm key needs.
pub fn operator_tag(instruction: &str) -> String {
    let word = instruction.split_whitespace().next().unwrap_or("");
    let mut tag: String = word
        .chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .take(24)
        .collect();
    if tag.is_empty() {
        tag = "generate".into();
    }
    tag
}

/// SplitMix64 finalizer over a salted seed — the bandit's only source
/// of randomness, derived from the request's llm seed.
fn mix(seed: u64, salt: u64) -> u64 {
    let mut z = seed
        .wrapping_add(salt)
        .wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) from a mixed word.
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routing(ratio: f64) -> RoutingSpec {
        RoutingSpec {
            members: vec![("a".into(), 1.0), ("b".into(), 1.0)],
            exploration_ratio: ratio,
        }
    }

    #[test]
    fn selection_is_pure_and_seed_deterministic() {
        let b = Bandit::new(&routing(0.25));
        for seed in 0..64u64 {
            let first = b.select("mutation", "matmul", seed);
            assert_eq!(first, b.select("mutation", "matmul", seed));
        }
        // Identically-built bandits agree pick-for-pick.
        let c = Bandit::new(&routing(0.25));
        let picks_b: Vec<String> = (0..64).map(|s| b.select("m", "c", s)).collect();
        let picks_c: Vec<String> = (0..64).map(|s| c.select("m", "c", s)).collect();
        assert_eq!(picks_b, picks_c);
    }

    #[test]
    fn rewards_steer_exploitation() {
        let mut b = Bandit::new(&routing(0.0));
        // Zero exploration after both arms have one pull: the better
        // mean must win every seed.
        b.update("a", "mutation", "matmul", 1.0);
        b.update("b", "mutation", "matmul", 0.05);
        for seed in 0..32u64 {
            assert_eq!(b.select("mutation", "matmul", seed), "a");
        }
        // Arms are per-(operator, category): an unexplored context
        // falls back to the weighted prior, not a's record.
        let pulls: Vec<String> = (0..32).map(|s| b.select("crossover", "scan", s)).collect();
        assert!(pulls.contains(&"a".to_string()) && pulls.contains(&"b".to_string()));
    }

    #[test]
    fn priors_weight_the_exploration_pick() {
        let spec = RoutingSpec {
            members: vec![("heavy".into(), 99.0), ("light".into(), 1.0)],
            exploration_ratio: 1.0, // always explore
        };
        let b = Bandit::new(&spec);
        let heavy = (0..200u64)
            .filter(|s| b.select("m", "c", *s) == "heavy")
            .count();
        assert!(heavy > 180, "prior-weighted pick chose heavy {heavy}/200");
    }

    #[test]
    fn unpulled_arm_is_forced_before_means_are_trusted() {
        let mut b = Bandit::new(&routing(0.0));
        b.update("a", "m", "c", 2.0);
        // `b` never pulled in this context → infinite UCB → selected
        // despite a's perfect mean (exploration_ratio 0 disables the
        // random explore branch entirely).
        for seed in 0..8u64 {
            assert_eq!(b.select("m", "c", seed), "b");
        }
    }

    #[test]
    fn arm_export_is_sorted_with_means() {
        let mut b = Bandit::new(&routing(0.25));
        b.update("b", "mutation", "matmul", 1.0);
        b.update("a", "repair", "scan", 0.0);
        b.update("a", "repair", "scan", 1.0);
        let arms = b.arms();
        assert_eq!(arms.len(), 2);
        assert_eq!(
            (arms[0].member.as_str(), arms[0].pulls, arms[0].mean_reward),
            ("a", 2, 0.5)
        );
        assert_eq!(arms[1].member.as_str(), "b");
        assert_eq!(arms[1].mean_reward, 1.0);
    }

    #[test]
    fn reward_mapping_orders_outcomes() {
        let ok_fast = trial_reward("ok", Some(8.0));
        let ok = trial_reward("ok", Some(1.0));
        assert_eq!(ok_fast, 2.0, "speedup bonus caps at 4x");
        assert!(ok_fast > ok);
        assert!(ok > trial_reward("functional_fail", None));
        assert!(trial_reward("functional_fail", None) > trial_reward("compile_fail", None));
        assert!(trial_reward("compile_fail", None) > trial_reward("guard_reject", None));
        assert_eq!(trial_reward("guard_reject", None), 0.0);
        assert_eq!(repair_reward(true), 1.0);
        assert_eq!(repair_reward(false), 0.0);
    }

    #[test]
    fn operator_tags_are_first_word_lowercase() {
        assert_eq!(operator_tag("Mutate the incumbent kernel"), "mutate");
        assert_eq!(operator_tag("  CROSSOVER: combine two parents"), "crossover");
        assert_eq!(operator_tag(""), "generate");
        assert_eq!(operator_tag("---"), "generate");
    }
}
