//! Prompt parsing — the SimLLM's "reading comprehension". Only what is
//! actually present in the rendered prompt text becomes available to
//! the generator (see module docs in llm/mod.rs for the honesty
//! contract).

use crate::dsl::{self, KernelSpec};

/// One insight line recovered from the `## INSIGHTS` section.
#[derive(Debug, Clone)]
pub struct ParsedInsight {
    /// The action text, e.g. `set vector_width to 8 (wider loads)`.
    pub action: String,
    /// The recorded effect, e.g. +0.40 (from `[+0.40x]`).
    pub delta: f64,
}

/// Everything the generator recovered from the prompt. Historical
/// kernel blocks are kept as raw slices and parsed lazily (perf:
/// crossover touches at most one donor per trial — EXPERIMENTS.md
/// §Perf).
#[derive(Debug, Clone, Default)]
pub struct PromptCtx<'a> {
    pub op: String,
    pub category: u8,
    /// Long boilerplate detected (verbose prompt style).
    pub verbose: bool,
    pub parent: Option<KernelSpec>,
    /// Raw KernelScript blocks from the `## HISTORY` section.
    pub history: Vec<&'a str>,
    pub insights: Vec<ParsedInsight>,
    pub instruction: String,
    /// Roofline bound recovered from a `## PERFORMANCE PROFILE`
    /// section (`Memory` / `Compute` / `Launch`), when present
    /// (DESIGN.md §17). `None` for legacy prompts.
    pub profile_bound: Option<String>,
    /// Raw `## OPTIMIZATION GOAL` emphasis text, when present.
    pub goal: Option<String>,
}

impl<'a> PromptCtx<'a> {
    pub fn instruction_has_any(&self, keys: &[&str]) -> bool {
        let low = self.instruction.to_ascii_lowercase();
        keys.iter().any(|k| low.contains(k))
    }

    /// Parse one historical block on demand.
    pub fn parse_history(&self, idx: usize) -> Option<KernelSpec> {
        self.history.get(idx).and_then(|b| dsl::parse(b).ok())
    }
}

/// Extract the raw text range of every KernelScript block in a chunk
/// (a block runs from a line starting `kernel ` to the first column-0
/// `}` line). No parsing happens here.
fn extract_kernel_blocks(chunk: &str) -> Vec<&str> {
    let mut blocks = Vec::new();
    let bytes = chunk.as_bytes();
    let mut pos = 0usize;
    let mut start: Option<usize> = None;
    for line in chunk.split_inclusive('\n') {
        let line_start = pos;
        pos += line.len();
        let trimmed = line.trim_end_matches(['\n', '\r']);
        if start.is_none() && trimmed.trim_start().starts_with("kernel ") {
            start = Some(line_start);
        } else if let Some(s) = start {
            if trimmed == "}" {
                blocks.push(&chunk[s..pos.min(bytes.len())]);
                start = None;
            }
        }
    }
    blocks
}

/// Parse the full prompt into a [`PromptCtx`].
pub fn parse_prompt(prompt: &str) -> PromptCtx<'_> {
    let mut ctx = PromptCtx {
        category: 3,
        ..Default::default()
    };
    ctx.verbose = prompt.contains("elite GPU performance engineer");

    // Split into `## `-headed sections. Perf (EXPERIMENTS.md §Perf):
    // sections are byte-range slices of the prompt, not rebuilt
    // Strings — this runs once per trial on prompts up to several KB.
    let mut sections: Vec<(&str, &str)> = Vec::new();
    {
        let mut header: Option<&str> = None;
        let mut body_start = 0usize;
        let mut pos = 0usize;
        for line in prompt.split_inclusive('\n') {
            let line_start = pos;
            pos += line.len();
            let trimmed = line.trim_end_matches(['\n', '\r']);
            if let Some(h) = trimmed.strip_prefix("## ") {
                if let Some(prev) = header.take() {
                    sections.push((prev, &prompt[body_start..line_start]));
                }
                header = Some(h.trim());
                body_start = pos;
            }
        }
        if let Some(prev) = header.take() {
            sections.push((prev, &prompt[body_start..]));
        }
    }

    for (header, body) in &sections {
        match *header {
            "TASK" => {
                for line in body.lines() {
                    if let Some(v) = line.strip_prefix("op: ") {
                        ctx.op = v.trim().to_string();
                    } else if let Some(v) = line.strip_prefix("category: ") {
                        let digits: String =
                            v.chars().take_while(|c| c.is_ascii_digit()).collect();
                        ctx.category = digits.parse().unwrap_or(3);
                    }
                }
            }
            "CURRENT KERNEL" => {
                ctx.parent = extract_kernel_blocks(body)
                    .first()
                    .and_then(|b| dsl::parse(b).ok());
            }
            "HISTORY" => {
                ctx.history = extract_kernel_blocks(body);
            }
            "INSIGHTS" => {
                for line in body.lines() {
                    let Some(rest) = line.strip_prefix("- ") else { continue };
                    // `action [±D.DDx]`
                    let (action, delta) = match rest.rfind('[') {
                        Some(i) => {
                            let tail = rest[i + 1..].trim_end_matches([']', 'x', ' ']);
                            (rest[..i].trim().to_string(), tail.parse().unwrap_or(0.0))
                        }
                        None => (rest.trim().to_string(), 0.0),
                    };
                    ctx.insights.push(ParsedInsight { action, delta });
                }
            }
            "INSTRUCTION" => {
                ctx.instruction = body.trim().to_string();
            }
            "PERFORMANCE PROFILE" => {
                for line in body.lines() {
                    if let Some(v) = line.strip_prefix("bound: ") {
                        let word: String = v
                            .chars()
                            .take_while(|c| c.is_ascii_alphabetic())
                            .collect();
                        if !word.is_empty() {
                            ctx.profile_bound = Some(word);
                        }
                    }
                }
            }
            "OPTIMIZATION GOAL" => {
                let text = body.trim();
                if !text.is_empty() {
                    ctx.goal = Some(text.to_string());
                }
            }
            _ => {}
        }
    }
    if ctx.op.is_empty() {
        ctx.op = "unknown_op".to_string();
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::print;

    #[test]
    fn parses_task_and_instruction() {
        let p = "## TASK\nop: gelu_64\ncategory: 3 (Activation & Pooling)\n\n\
                 ## INSTRUCTION\nImprove the kernel.\n";
        let ctx = parse_prompt(p);
        assert_eq!(ctx.op, "gelu_64");
        assert_eq!(ctx.category, 3);
        assert_eq!(ctx.instruction, "Improve the kernel.");
        assert!(!ctx.verbose);
    }

    #[test]
    fn recovers_parent_and_history_kernels() {
        let k1 = print(&KernelSpec::baseline("matmul_64"));
        let mut spec2 = KernelSpec::baseline("matmul_64");
        spec2.schedule.tile_m = 64;
        let k2 = print(&spec2);
        let p = format!(
            "## TASK\nop: matmul_64\ncategory: 1 (M)\n\n## CURRENT KERNEL\nspeedup: 1.2\n{k1}\n\
             ## HISTORY\n### solution 1 (speedup 2.0)\n{k2}### solution 2 (speedup 1.5)\n{k1}\n\
             ## INSTRUCTION\nGo.\n"
        );
        let ctx = parse_prompt(&p);
        assert!(ctx.parent.is_some());
        assert_eq!(ctx.history.len(), 2);
        assert_eq!(ctx.parse_history(0).unwrap().schedule.tile_m, 64);
        assert!(ctx.parse_history(1).is_some());
        assert!(ctx.parse_history(2).is_none());
    }

    #[test]
    fn parses_insight_deltas() {
        let p = "## TASK\nop: x\ncategory: 1 (M)\n\n## INSIGHTS\n\
                 - set vector_width to 8 (wider loads) [+0.40x]\n\
                 - enabled smem_staging (reuse) [-0.10x]\n\n## INSTRUCTION\nGo.\n";
        let ctx = parse_prompt(p);
        assert_eq!(ctx.insights.len(), 2);
        assert!((ctx.insights[0].delta - 0.40).abs() < 1e-9);
        assert!((ctx.insights[1].delta + 0.10).abs() < 1e-9);
        assert!(ctx.insights[0].action.starts_with("set vector_width"));
    }

    #[test]
    fn missing_sections_are_empty() {
        let ctx = parse_prompt("## TASK\nop: y\ncategory: 6 (C)\n");
        assert!(ctx.parent.is_none());
        assert!(ctx.history.is_empty());
        assert!(ctx.insights.is_empty());
        assert!(ctx.profile_bound.is_none());
        assert!(ctx.goal.is_none());
        assert_eq!(ctx.category, 6);
    }

    #[test]
    fn recovers_profile_bound_and_goal() {
        let p = "## TASK\nop: x\ncategory: 1 (M)\n\n## INSTRUCTION\nGo.\n\n\
                 ## PERFORMANCE PROFILE\nop: x\noutcome: ok\n\
                 bound: Memory; occupancy: 0.67; eff_bw: 0.84; eff_compute: 0.21; \
                 traffic_bytes: 4.200e6; launches: 1\n\n\
                 ## OPTIMIZATION GOAL\nMinimize DRAM traffic.\n";
        let ctx = parse_prompt(p);
        assert_eq!(ctx.profile_bound.as_deref(), Some("Memory"));
        assert_eq!(ctx.goal.as_deref(), Some("Minimize DRAM traffic."));
        // The instruction body stops at the next section header.
        assert_eq!(ctx.instruction, "Go.");
    }
}
