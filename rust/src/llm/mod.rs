//! SimLLM — the prompt-conditioned stochastic code generator standing
//! in for GPT-4.1 / DeepSeek-V3.1 / Claude-Sonnet-4 (DESIGN.md §2).
//!
//! Honesty contract of the simulation:
//!
//! * The generator sees **only the rendered prompt text** (plus its
//!   model profile and RNG stream). Information the solution-guiding
//!   layer omitted is genuinely unavailable — it must *parse* the
//!   prompt to recover the parent kernel, history, insights and
//!   instruction, exactly like a real LLM reads context.
//! * Its output is **raw text**: a KernelScript program (possibly with
//!   injected syntax/semantic/legality defects) plus a one-line
//!   insight. The evaluator treats it like any untrusted LLM emission.
//! * Defect rates and move quality depend on the information present
//!   (history and insights reduce error rates and steer mutations),
//!   reproducing the paper's core finding: information-rich traverse
//!   configurations trade exploration for validity.
//! * Token accounting is real: prompt tokens from the actual prompt
//!   length, completion tokens from the actual emitted text (Figure 4).
//!
//! Since the provider redesign (DESIGN.md §12) the SimLLM is one
//! backend behind the typed [`Provider`] seam: `Session::trial` and
//! the repair loop issue [`GenerationRequest`]s, and [`SimProvider`]
//! expands each request's seed to the exact RNG stream the old inline
//! call sites derived — the free functions below remain the sim
//! backend's implementation (and its conformance oracle).

pub mod bandit;
pub mod ensemble;
pub mod mutate;
pub mod parse;
pub mod profile;
pub mod provider;

#[cfg(feature = "http-provider")]
pub mod http;

pub use bandit::{ArmWeight, Bandit};
pub use ensemble::{EnsembleProvider, EnsembleSpec, MemberBackend, RoutingSpec};
pub use profile::{ModelProfile, MODELS};
pub use provider::{
    GenerationRequest, GenerationResponse, GenerationRole, Provider, ProviderConfig,
    ProviderSpec, RecordingProvider, ReplayProvider, ReusePolicy, SimProvider, TokenUsage,
};

use crate::dsl::{self, KernelSpec};
use crate::util::Rng;

/// One LLM call's result.
#[derive(Debug, Clone)]
pub struct LlmResponse {
    /// The emitted candidate program (raw, possibly corrupted, text).
    pub text: String,
    /// The accompanying optimization insight (solution-insight pair, as
    /// EoH / AI CUDA Engineer / EvoEngineer all request).
    pub insight: String,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

/// ~4 chars/token, the usual BPE rule of thumb.
pub fn count_tokens(text: &str) -> u64 {
    (text.len() as u64).div_ceil(4)
}

/// One repair call: the SimLLM is shown its own rejected emission plus
/// the stage-0 guard diagnostics (DESIGN.md §11) and asked to fix it.
///
/// Like a real model it repairs *from the diagnostics*: mechanical
/// text mends for syntax findings, targeted field assignments from the
/// structured repair hints, a make-consistent rebalance for the
/// multi-field resource findings — each applied with a skill-dependent
/// probability, so repair success is imperfect and model-dependent but
/// fully deterministic given the RNG stream. Token accounting is real:
/// the prompt charges for the source + diagnostics the repair request
/// would carry, the completion for the re-emitted program.
pub fn repair(
    src: &str,
    report: &crate::guard::GuardReport,
    profile: &ModelProfile,
    rng: &mut Rng,
) -> LlmResponse {
    let diag_text = report.summary();
    // What the repair request would contain: instructions + program +
    // the structured diagnostics.
    const REPAIR_INSTRUCTION: &str =
        "Fix the kernel so it passes the static checks; keep the optimization intent.";
    let prompt_tokens =
        count_tokens(src) + count_tokens(&diag_text) + count_tokens(REPAIR_INSTRUCTION);

    // Skilled models land targeted fixes more reliably.
    let p_fix = (0.55 + 0.40 * profile.skill).min(0.95);

    let mut text = src.to_string();
    if report.has(crate::guard::GuardCode::Syntax) && rng.chance(p_fix) {
        text = mutate::mend_text(&text);
    }
    let mut notes: Vec<String> = Vec::new();
    if let Ok(mut spec) = dsl::parse(&text) {
        for d in &report.diagnostics {
            if let Some((field, value)) = &d.hint {
                if rng.chance(p_fix) && mutate::apply_named_fix(&mut spec, field, value) {
                    notes.push(format!("set {field} to {value} (guard: {})", d.code));
                }
            }
        }
        // Multi-field resource findings (smem overflow, register
        // pressure) have no single-assignment hint; a competent model
        // rebalances the schedule the way a compiler pragma would.
        let needs_rebalance = report
            .diagnostics
            .iter()
            .any(|d| d.code == crate::guard::GuardCode::ResourceLimit && d.hint.is_none());
        if needs_rebalance && rng.chance(p_fix) {
            mutate::make_consistent(&mut spec.schedule);
            notes.push("rebalanced the schedule within resource limits".into());
        }
        // Canonical re-print (also collapses shadowed bindings).
        text = dsl::print(&spec);
    }

    let insight = notes
        .last()
        .cloned()
        .unwrap_or_else(|| "attempted a repair from the diagnostics".into());
    let completion_overhead = (profile.verbosity * 80.0) as u64; // short apology + fix
    LlmResponse {
        prompt_tokens,
        completion_tokens: count_tokens(&text) + count_tokens(&insight) + completion_overhead,
        text,
        insight,
    }
}

/// Run one SimLLM completion for `prompt` under `profile`.
pub fn generate(prompt: &str, profile: &ModelProfile, rng: &mut Rng) -> LlmResponse {
    let ctx = parse::parse_prompt(prompt);
    let cat_idx = (ctx.category.clamp(1, 6) - 1) as usize;

    // --- effective stochastic parameters for this call -----------------
    let has_hist = !ctx.history.is_empty();
    let has_ins = !ctx.insights.is_empty();
    let temp = profile.temperature * if ctx.verbose { 0.85 } else { 1.0 };
    let validity_mul = profile.category_validity[cat_idx]
        * if has_hist { 0.45 } else { 1.0 }
        * if has_ins { 0.70 } else { 1.0 }
        * (1.0 + 0.5 * (temp - 1.0).max(0.0));
    let syntax_rate = (profile.syntax_rate * validity_mul).clamp(0.0, 0.9);
    let semantic_rate = (profile.semantic_rate * validity_mul).clamp(0.0, 0.9);
    let legality_rate = (profile.legality_rate * validity_mul).clamp(0.0, 0.9);
    let skill = (profile.skill * profile.category_skill[cat_idx]).clamp(0.05, 0.95);

    // --- base spec: parent, or a fresh baseline ------------------------
    let from_scratch = ctx.instruction_has_any(&["from scratch", "design a new", "convert"]);
    let mut spec = match (&ctx.parent, from_scratch) {
        (Some(p), false) => p.clone(),
        _ => KernelSpec::baseline(&ctx.op),
    };
    spec.op = ctx.op.clone();

    let mut notes: Vec<String> = Vec::new();

    // --- semantics channel ---------------------------------------------
    if rng.chance(semantic_rate) {
        // Semantic defect: subtly wrong numerics or a hallucinated
        // variant name (the LLM "rewrites the math").
        spec.semantics = (*rng.pick(&[
            "bug_scale",
            "bug_offset",
            "bug_scale",
            "bug_offset",
            "opt_v2", // hallucination -> resolution failure
        ]))
        .to_string();
        notes.push("rewrote the inner computation".into());
    } else if spec.semantics != "opt" && spec.semantics != "ref" {
        // Repair path: with good context the model fixes broken
        // semantics; blind configurations often keep them.
        let p_repair = if has_hist || has_ins { 0.9 } else { 0.55 };
        if rng.chance(p_repair) {
            spec.semantics = "opt".into();
            notes.push("restored the reference computation".into());
        }
    } else {
        spec.semantics = "opt".into();
    }

    // --- schedule channel -----------------------------------------------
    // 1) follow recorded positive insights (the I3 signal).
    for ins in &ctx.insights {
        if ins.delta > 0.0 && rng.chance(profile.insight_follow) {
            if let Some(applied) = mutate::apply_insight(&mut spec.schedule, &ins.action) {
                notes.push(applied);
            }
        }
    }
    // 2) crossover fields from history (the I2 signal). The donor
    // block is parsed lazily — at most one per trial.
    if has_hist
        && (rng.chance(0.35) || ctx.instruction_has_any(&["combine", "crossover"]))
    {
        if let Some(donor) = ctx.parse_history(rng.below(ctx.history.len())) {
            let n = 1 + rng.below(3);
            for _ in 0..n {
                notes.push(mutate::copy_random_field(&mut spec.schedule, &donor.schedule, rng));
            }
        }
    }
    // 3) mutation moves: directed (skill) or random (temperature).
    let param_only = ctx.instruction_has_any(&["parameter", "tune the numeric"]);
    let n_moves = 1 + (temp * rng.f64() * 2.5) as usize;
    for _ in 0..n_moves {
        let note = if rng.chance(skill) {
            mutate::directed_move(&mut spec.schedule, ctx.category, rng)
        } else {
            mutate::random_move(&mut spec.schedule, param_only, rng)
        };
        notes.push(note);
    }
    // 3.5) performance-profile feedback (DESIGN.md §17): a profiled
    // prompt lets the model react to the measured bottleneck with a
    // targeted move. Legacy prompts (no profile section) draw no RNG
    // here, so their streams — and emissions — stay byte-identical to
    // pre-feedback builds.
    if let Some(bound) = ctx.profile_bound.as_deref() {
        let follow = (0.45 + 0.45 * profile.skill).min(0.9);
        if rng.chance(follow) {
            let s = &mut spec.schedule;
            let note = match bound {
                "Memory" if s.vector_width < 8 => {
                    s.vector_width *= 2;
                    format!(
                        "set vector_width to {} (profile: memory-bound)",
                        s.vector_width
                    )
                }
                "Memory" if !s.smem_staging => {
                    s.smem_staging = true;
                    s.stages = 2;
                    "enabled smem_staging (profile: memory-bound, stage for reuse)".into()
                }
                "Launch" if !s.fuse_epilogue => {
                    s.fuse_epilogue = true;
                    "enabled fuse_epilogue (profile: launch-bound)".into()
                }
                _ => mutate::directed_move(s, ctx.category, rng),
            };
            notes.push(note);
        }
        // A memory objective additionally biases toward reuse over raw
        // width (the `--goal memory` emphasis names DRAM traffic).
        if ctx.goal.as_deref().map_or(false, |g| g.contains("DRAM traffic"))
            && !spec.schedule.smem_staging
            && rng.chance(0.5)
        {
            spec.schedule.smem_staging = true;
            spec.schedule.stages = 2;
            notes.push("enabled smem_staging (goal: reduce DRAM traffic)".into());
        }
    }

    // 4) exploration jump (what makes -Free find distant optima):
    // information-light prompts leave the model unanchored, so it
    // proposes structurally different schedules more often.
    let p_jump = 0.10 * temp + if !has_hist && !has_ins { 0.15 } else { 0.0 };
    if rng.chance(p_jump) {
        for _ in 0..3 + rng.below(3) {
            notes.push(mutate::random_move(&mut spec.schedule, false, rng));
        }
        notes.push("restructured the schedule".into());
    }
    // 5) keep the schedule self-consistent (the LLM usually writes
    // *plausible* code), unless a legality defect slips through.
    mutate::make_consistent(&mut spec.schedule);
    if rng.chance(legality_rate) {
        notes.push(mutate::inject_legality_defect(&mut spec.schedule, rng));
    }

    // --- emit text --------------------------------------------------------
    let mut text = dsl::print(&spec);
    if rng.chance(syntax_rate) {
        text = mutate::corrupt_text(&text, rng);
    }

    let insight = match notes.last() {
        Some(n) => n.clone(),
        None => "kept the schedule unchanged".into(),
    };

    let completion_overhead = (profile.verbosity * 220.0) as u64; // reasoning filler
    LlmResponse {
        prompt_tokens: count_tokens(prompt),
        completion_tokens: count_tokens(&text) + count_tokens(&insight) + completion_overhead,
        text,
        insight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt_for(op: &str, cat: u8) -> String {
        format!(
            "## TASK\nop: {op}\ncategory: {cat} (X)\nflops: 1e6\nbytes: 1e5\n\
             baseline_time_us: 10.0\nobjective: minimize\n\n## INSTRUCTION\nImprove.\n"
        )
    }

    #[test]
    fn deterministic_given_seed() {
        let p = prompt_for("matmul_64", 1);
        let prof = &MODELS[0];
        let a = generate(&p, prof, &mut Rng::new(5));
        let b = generate(&p, prof, &mut Rng::new(5));
        assert_eq!(a.text, b.text);
        assert_eq!(a.insight, b.insight);
    }

    #[test]
    fn emits_programs_for_the_requested_op() {
        let p = prompt_for("softmax_64", 4);
        let mut rng = Rng::new(1);
        let mut parsed_ok = 0;
        for i in 0..50 {
            let mut r = rng.derive(&format!("t{i}"));
            let resp = generate(&p, &MODELS[0], &mut r);
            if let Ok(spec) = dsl::parse(&resp.text) {
                assert_eq!(spec.op, "softmax_64");
                parsed_ok += 1;
            }
        }
        assert!(parsed_ok > 30, "only {parsed_ok}/50 parse");
        assert!(parsed_ok < 50, "syntax defects should occur sometimes");
    }

    #[test]
    fn history_improves_validity() {
        // The paper's core phenomenon: information-rich prompts yield
        // higher validity. Measured over many draws.
        let bare = prompt_for("matmul_64", 1);
        let spec = KernelSpec::baseline("matmul_64");
        let rich = format!(
            "## TASK\nop: matmul_64\ncategory: 1 (X)\nbaseline_time_us: 10\n\n\
             ## HISTORY\n### solution 1 (speedup 2.0)\n{}\n\
             ## INSIGHTS\n- set vector_width to 8 (wider loads) [+0.40x]\n\n\
             ## INSTRUCTION\nImprove.\n",
            dsl::print(&spec)
        );
        let count_valid = |prompt: &str| {
            let mut ok = 0;
            for i in 0..400 {
                let mut r = Rng::new(1000 + i);
                let resp = generate(prompt, &MODELS[0], &mut r);
                if dsl::parse(&resp.text)
                    .ok()
                    .map(|s| crate::dsl::validate(&s).is_ok() && s.semantics == "opt")
                    .unwrap_or(false)
                {
                    ok += 1;
                }
            }
            ok
        };
        let v_bare = count_valid(&bare);
        let v_rich = count_valid(&rich);
        assert!(
            v_rich > v_bare,
            "rich prompt should be more valid: bare={v_bare} rich={v_rich}"
        );
    }

    #[test]
    fn repair_applies_hints_deterministically() {
        use crate::guard::{GuardCode, GuardDiagnostic, GuardReport};
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.semantics = "turbo".into();
        spec.schedule.vector_width = 3;
        let src = dsl::print(&spec);
        let report = GuardReport {
            diagnostics: vec![
                GuardDiagnostic {
                    code: GuardCode::UndefinedRef,
                    field: "semantics".into(),
                    message: "undefined semantics variant `turbo`".into(),
                    hint: Some(("semantics".into(), "opt".into())),
                },
                GuardDiagnostic {
                    code: GuardCode::ResourceLimit,
                    field: "vector_width".into(),
                    message: "vector_width=3 not a supported packing".into(),
                    hint: Some(("vector_width".into(), "4".into())),
                },
            ],
        };
        // Deterministic given the RNG stream.
        let a = repair(&src, &report, &MODELS[0], &mut Rng::new(1));
        let b = repair(&src, &report, &MODELS[0], &mut Rng::new(1));
        assert_eq!(a.text, b.text);
        assert_eq!(a.insight, b.insight);
        assert!(a.prompt_tokens > 0 && a.completion_tokens > 0);
        // Targeted fixes land most of the time (skill-dependent, not
        // always — repair is imperfect like a real model's).
        let mut both_fixed = 0;
        for seed in 0..100 {
            let r = repair(&src, &report, &MODELS[0], &mut Rng::new(seed));
            if let Ok(s) = dsl::parse(&r.text) {
                if s.semantics == "opt" && s.schedule.vector_width == 4 {
                    both_fixed += 1;
                }
            }
        }
        assert!(both_fixed > 40, "{both_fixed}/100 repairs landed both fixes");
        assert!(both_fixed < 100, "repair should not be infallible");
    }

    #[test]
    fn repair_mends_syntax_defects() {
        use crate::guard::{GuardCode, GuardDiagnostic, GuardReport};
        let text = dsl::print(&KernelSpec::baseline("matmul_64"));
        let broken = text.replacen("schedule", "schedul", 1);
        assert!(dsl::parse(&broken).is_err());
        let report = GuardReport {
            diagnostics: vec![GuardDiagnostic {
                code: GuardCode::Syntax,
                field: String::new(),
                message: "not a parseable program".into(),
                hint: None,
            }],
        };
        let mut mended = 0;
        for seed in 0..60 {
            let r = repair(&broken, &report, &MODELS[2], &mut Rng::new(seed));
            if dsl::parse(&r.text).is_ok() {
                mended += 1;
            }
        }
        assert!(mended > 30, "{mended}/60 syntax repairs parsed");
    }

    #[test]
    fn profile_section_steers_generation_deterministically() {
        let bare = prompt_for("matmul_64", 1);
        let profiled = format!(
            "{bare}\n## PERFORMANCE PROFILE\nop: matmul_64\noutcome: ok\n\
             bound: Memory; occupancy: 0.50; eff_bw: 0.30; eff_compute: 0.10; \
             traffic_bytes: 1.000e6; launches: 1\n"
        );
        // Deterministic given the RNG stream, profile included.
        let a = generate(&profiled, &MODELS[0], &mut Rng::new(9));
        let b = generate(&profiled, &MODELS[0], &mut Rng::new(9));
        assert_eq!(a.text, b.text);
        assert_eq!(a.insight, b.insight);
        // The profile reaction fires for a healthy fraction of seeds
        // (its note survives as the final insight when no later move
        // overwrites it).
        let mut reacted = 0;
        for seed in 0..100 {
            let r = generate(&profiled, &MODELS[0], &mut Rng::new(seed));
            if r.insight.contains("profile:") {
                reacted += 1;
            }
        }
        assert!(reacted > 10, "profile reaction fired only {reacted}/100 times");
        // The profile section costs real prompt tokens.
        let p = generate(&profiled, &MODELS[0], &mut Rng::new(1));
        let q = generate(&bare, &MODELS[0], &mut Rng::new(1));
        assert!(p.prompt_tokens > q.prompt_tokens);
    }

    #[test]
    fn tokens_scale_with_prompt() {
        let small = prompt_for("relu_64", 3);
        let big = format!("{}{}", "x".repeat(4000), small);
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let a = generate(&small, &MODELS[1], &mut r1);
        let b = generate(&big, &MODELS[1], &mut r2);
        assert!(b.prompt_tokens > a.prompt_tokens + 900);
    }
}
