//! The pluggable LLM provider API (DESIGN.md §12) — the seam every
//! generation backend plugs into.
//!
//! The paper runs three real models through one evolution framework;
//! this module makes the model an interchangeable, journaled component
//! so the same campaign can run against the SimLLM, a recorded
//! transcript, or a live OpenAI-compatible endpoint:
//!
//! * [`Provider`] — the trait: one typed call,
//!   [`GenerationRequest`] → [`GenerationResponse`].
//! * [`SimProvider`] — the SimLLM behind the seam. Byte-identical to
//!   the pre-provider free functions for a given seed: the request's
//!   `seed` is exactly the word [`Rng::derive`] would have expanded,
//!   so cached eval records and guarded replays all stay valid.
//! * [`RecordingProvider`] — transparent decorator that journals every
//!   call of an inner provider to a [`TranscriptStore`], keyed by the
//!   request content hash.
//! * [`ReplayProvider`] — serves calls from a transcript journal with
//!   **no** fallback backend: replayed campaigns perform zero live
//!   generation, and a request outside the journal is a hard error.
//!   Replay impersonates the recorded backend's label so run records
//!   match the recording run byte-for-byte.
//! * `HttpProvider` (behind the `http-provider` cargo feature, in
//!   `llm::http`) — OpenAI-compatible chat-completions client with
//!   retry/backoff and a hard token-budget cutoff.
//!
//! The honesty contract of the SimLLM (module docs of [`crate::llm`])
//! is inherited wholesale: a provider sees only the rendered prompt
//! text (plus, for repair calls, the rejected emission and the
//! structured stage-0 diagnostics), and returns raw untrusted text
//! plus real token accounting.
//!
//! [`Rng::derive`]: crate::util::Rng::derive

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::guard::{GuardDiagnostic, GuardReport};
use crate::store::{sha256_hex, TranscriptEntry, TranscriptStore};
use crate::util::Rng;
use crate::{eyre, Result};

use super::profile;

/// What the caller is asking the model to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenerationRole {
    /// Propose a candidate kernel from a rendered prompt.
    Generate,
    /// Mend a rejected emission using stage-0 guard diagnostics.
    Repair,
}

impl GenerationRole {
    pub fn as_str(self) -> &'static str {
        match self {
            GenerationRole::Generate => "generate",
            GenerationRole::Repair => "repair",
        }
    }
}

impl std::fmt::Display for GenerationRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One typed LLM call. The request is self-contained: everything a
/// backend may condition on is in here, which is what makes calls
/// hashable, journalable and replayable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerationRequest {
    pub role: GenerationRole,
    /// Model identity — a [`profile::ModelProfile`] name for the sim
    /// backend, a remote model id for HTTP.
    pub model: String,
    /// The rendered prompt (`Generate`) or the rejected emission being
    /// repaired (`Repair`).
    pub prompt: String,
    /// Structured stage-0 diagnostics (`Repair` calls only; empty for
    /// `Generate`).
    pub diagnostics: Vec<GuardDiagnostic>,
    /// Deterministic stream id, produced by
    /// [`Rng::derive_seed`](crate::util::Rng::derive_seed) exactly
    /// where the pre-provider code derived its per-call RNG — the sim
    /// backend expands it to the identical stream.
    pub seed: u64,
}

impl GenerationRequest {
    /// A `Generate` call for a rendered prompt.
    pub fn generate(model: &str, prompt: &str, seed: u64) -> Self {
        GenerationRequest {
            role: GenerationRole::Generate,
            model: model.to_string(),
            prompt: prompt.to_string(),
            diagnostics: Vec::new(),
            seed,
        }
    }

    /// A `Repair` call for a guard-rejected emission.
    pub fn repair(model: &str, src: &str, report: &GuardReport, seed: u64) -> Self {
        GenerationRequest {
            role: GenerationRole::Repair,
            model: model.to_string(),
            prompt: src.to_string(),
            diagnostics: report.diagnostics.clone(),
            seed,
        }
    }

    /// Content hash of the request — the transcript journal key. The
    /// encoding is canonical (length-framed, NUL-separated fields over
    /// role, model, seed, prompt and every diagnostic), so two
    /// requests share a hash iff a backend could not tell them apart.
    pub fn hash(&self) -> String {
        let mut buf: Vec<u8> = Vec::with_capacity(64 + self.prompt.len());
        buf.extend_from_slice(b"genreq\0v1\0");
        buf.extend_from_slice(self.role.as_str().as_bytes());
        buf.push(0);
        buf.extend_from_slice(self.model.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&self.seed.to_be_bytes());
        buf.extend_from_slice(&(self.prompt.len() as u64).to_be_bytes());
        buf.extend_from_slice(self.prompt.as_bytes());
        for d in &self.diagnostics {
            buf.push(0);
            buf.extend_from_slice(d.code.as_str().as_bytes());
            buf.push(0);
            buf.extend_from_slice(d.field.as_bytes());
            buf.push(0);
            buf.extend_from_slice(d.message.as_bytes());
            buf.push(0);
            if let Some((hf, hv)) = &d.hint {
                buf.extend_from_slice(hf.as_bytes());
                buf.push(0);
                buf.extend_from_slice(hv.as_bytes());
            }
            buf.push(0);
        }
        sha256_hex(&buf)
    }
}

/// Real token accounting for one call (prompt side measured from what
/// was sent, completion side from what came back).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TokenUsage {
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

impl TokenUsage {
    pub fn total(&self) -> u64 {
        self.prompt_tokens + self.completion_tokens
    }
}

/// One call's result: raw untrusted text, the solution insight, and
/// token accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerationResponse {
    pub text: String,
    pub insight: String,
    pub usage: TokenUsage,
}

/// A generation backend. Implementations must be `Send + Sync`: the
/// campaign worker pool shares one provider across threads.
pub trait Provider: Send + Sync {
    /// Stable backend label recorded in every run record ("sim",
    /// "http", or — for replay — the label of the backend that
    /// *recorded* the transcript).
    fn label(&self) -> &str;

    /// Execute one typed call.
    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse>;

    /// Group-commit flush point (DESIGN.md §14): the engine calls this
    /// at every trial boundary; backends that buffer journal appends
    /// (the recording decorator) make them durable here. Default:
    /// no-op.
    fn flush(&self) {}
}

// ---------------------------------------------------------------------
// SimProvider

/// The SimLLM behind the provider seam.
///
/// Delegates to the free functions [`crate::llm::generate`] /
/// [`crate::llm::repair`] with `Rng::new(req.seed)` — byte-identical
/// to the pre-provider call sites for the same derived seed (proven by
/// `tests/provider_conformance.rs`).
#[derive(Debug, Default)]
pub struct SimProvider {
    calls: AtomicU64,
}

impl SimProvider {
    pub fn new() -> Self {
        Self::default()
    }

    /// Live generations performed by this instance (the
    /// record-then-replay identity test's zero-live-calls proof).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

impl Provider for SimProvider {
    fn label(&self) -> &str {
        "sim"
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        let prof = profile::by_name(&req.model)
            .ok_or_else(|| eyre!("sim provider: unknown model `{}`", req.model))?;
        let mut rng = Rng::new(req.seed);
        let resp = match req.role {
            GenerationRole::Generate => super::generate(&req.prompt, prof, &mut rng),
            GenerationRole::Repair => {
                let report = GuardReport { diagnostics: req.diagnostics.clone() };
                super::repair(&req.prompt, &report, prof, &mut rng)
            }
        };
        self.calls.fetch_add(1, Ordering::Relaxed);
        Ok(GenerationResponse {
            text: resp.text,
            insight: resp.insight,
            usage: TokenUsage {
                prompt_tokens: resp.prompt_tokens,
                completion_tokens: resp.completion_tokens,
            },
        })
    }
}

// ---------------------------------------------------------------------
// RecordingProvider

/// Transparent decorator: every call of the inner provider is appended
/// to a [`TranscriptStore`] keyed by the request hash. The label stays
/// the inner backend's — recording is provenance-neutral.
///
/// With [`RecordingProvider::with_reuse`], requests the journal
/// already covers are served from it without touching the inner
/// backend — the trial-granular resume mechanism (DESIGN.md §13): a
/// resumed campaign leg replays an interrupted cell's completed trials
/// from the journal (zero live generation, bit-identical) and goes
/// live only from the first unrecorded call. Responses are identical
/// either way for a deterministic backend; for HTTP this is what makes
/// mid-cell resume cheap *and* reproducible.
pub struct RecordingProvider {
    inner: Arc<dyn Provider>,
    journal: Arc<TranscriptStore>,
    reuse: bool,
}

impl RecordingProvider {
    /// Wrap `inner`, declaring it as the journal's source backend.
    /// Fails if the journal was recorded by a different backend.
    pub fn new(inner: Arc<dyn Provider>, journal: Arc<TranscriptStore>) -> Result<Self> {
        journal.record_source(inner.label())?;
        Ok(Self { inner, journal, reuse: false })
    }

    /// Serve already-journaled requests from the journal instead of
    /// re-calling the inner backend.
    pub fn with_reuse(mut self, reuse: bool) -> Self {
        self.reuse = reuse;
        self
    }

    pub fn journal(&self) -> &Arc<TranscriptStore> {
        &self.journal
    }
}

impl Provider for RecordingProvider {
    fn label(&self) -> &str {
        self.inner.label()
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        if self.reuse {
            if let Some(entry) = self.journal.lookup(&req.hash()) {
                return Ok(GenerationResponse {
                    text: entry.text,
                    insight: entry.insight,
                    usage: TokenUsage {
                        prompt_tokens: entry.prompt_tokens,
                        completion_tokens: entry.completion_tokens,
                    },
                });
            }
        }
        let resp = self.inner.call(req)?;
        let entry = TranscriptEntry {
            role: req.role.as_str().to_string(),
            model: req.model.clone(),
            seed: req.seed,
            text: resp.text.clone(),
            insight: resp.insight.clone(),
            prompt_tokens: resp.usage.prompt_tokens,
            completion_tokens: resp.usage.completion_tokens,
        };
        if let Err(e) = self.journal.append(&req.hash(), entry) {
            // Advisory, like the eval cache: a failed journal write
            // must not kill the run that produced the response.
            eprintln!("warning: transcript append failed: {e:#}");
        }
        Ok(resp)
    }

    fn flush(&self) {
        if let Err(e) = self.journal.flush() {
            eprintln!("warning: transcript flush failed: {e:#}");
        }
    }
}

// ---------------------------------------------------------------------
// ReplayProvider

/// Serves every call from a recorded transcript journal. No inner
/// backend: a request the journal does not cover is a hard error, so a
/// successful replay run performed zero live generation by
/// construction.
pub struct ReplayProvider {
    journal: Arc<TranscriptStore>,
    /// Impersonated label (the journal's recorded source backend).
    label: String,
}

impl ReplayProvider {
    /// Open a journal for replay. The file must exist.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(eyre!(
                "no transcript journal at {} — record one first (run with \
                 `--provider sim` or `--provider http` and `--transcripts`)",
                path.display()
            ));
        }
        let journal = TranscriptStore::open(path)?;
        let label = journal.source().unwrap_or_else(|| "replay".to_string());
        Ok(Self { journal, label })
    }

    pub fn len(&self) -> usize {
        self.journal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.journal.is_empty()
    }
}

impl Provider for ReplayProvider {
    fn label(&self) -> &str {
        &self.label
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        let entry = self.journal.lookup(&req.hash()).ok_or_else(|| {
            eyre!(
                "transcript miss: no recorded {} call for model {} (seed {}) in {} — \
                 the journal does not cover this run's grid/budget; re-record it \
                 (archive-reading methods like AI CUDA Engineer additionally need \
                 --concurrency 1 on both legs, DESIGN.md §12)",
                req.role,
                req.model,
                req.seed,
                self.journal.path().display()
            )
        })?;
        Ok(GenerationResponse {
            text: entry.text,
            insight: entry.insight,
            usage: TokenUsage {
                prompt_tokens: entry.prompt_tokens,
                completion_tokens: entry.completion_tokens,
            },
        })
    }
}

// ---------------------------------------------------------------------
// ProviderSpec: CLI / config surface

/// Which backend to run — the parsed form of the `--provider` flag
/// (`sim` | `replay:<path>` | `http`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ProviderSpec {
    #[default]
    Sim,
    Replay(PathBuf),
    Http,
}

impl ProviderSpec {
    /// Parse a `--provider` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "" | "sim" => Ok(ProviderSpec::Sim),
            "http" => Ok(ProviderSpec::Http),
            "replay" => Err(eyre!(
                "`--provider replay` needs a journal: replay:<transcripts.jsonl>"
            )),
            other => {
                if let Some(path) = other.strip_prefix("replay:") {
                    if path.is_empty() {
                        return Err(eyre!("empty replay journal path"));
                    }
                    Ok(ProviderSpec::Replay(PathBuf::from(path)))
                } else {
                    Err(eyre!(
                        "unknown --provider `{other}` (sim | replay:<path> | http)"
                    ))
                }
            }
        }
    }

    /// The flag syntax this spec round-trips to.
    pub fn label(&self) -> String {
        match self {
            ProviderSpec::Sim => "sim".into(),
            ProviderSpec::Replay(p) => format!("replay:{}", p.display()),
            ProviderSpec::Http => "http".into(),
        }
    }
}

#[cfg(feature = "http-provider")]
fn http_backend() -> Result<Arc<dyn Provider>> {
    Ok(Arc::new(super::http::HttpProvider::from_env()?))
}

#[cfg(not(feature = "http-provider"))]
fn http_backend() -> Result<Arc<dyn Provider>> {
    Err(eyre!(
        "this binary was built without the `http-provider` feature; \
         rebuild with `cargo build --features http-provider`"
    ))
}

/// Build a provider from a spec, optionally recording every live call
/// to `transcripts` (ignored for replay — a replayed run records
/// nothing, its journal already is the record). With `reuse`, a
/// recording provider serves requests the journal already covers from
/// the journal (a resumed campaign leg replays completed trials with
/// zero live generation — DESIGN.md §13).
pub fn build(
    spec: &ProviderSpec,
    transcripts: Option<&Path>,
    reuse: bool,
) -> Result<Arc<dyn Provider>> {
    let base: Arc<dyn Provider> = match spec {
        ProviderSpec::Sim => Arc::new(SimProvider::new()),
        ProviderSpec::Replay(path) => return Ok(Arc::new(ReplayProvider::open(path)?)),
        ProviderSpec::Http => http_backend()?,
    };
    match transcripts {
        Some(path) => {
            let journal = TranscriptStore::open(path)?;
            Ok(Arc::new(RecordingProvider::new(base, journal)?.with_reuse(reuse)))
        }
        None => Ok(base),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guard::GuardCode;

    fn sample_report() -> GuardReport {
        GuardReport {
            diagnostics: vec![GuardDiagnostic {
                code: GuardCode::ResourceLimit,
                field: "vector_width".into(),
                message: "vector_width=3 not a supported packing".into(),
                hint: Some(("vector_width".into(), "4".into())),
            }],
        }
    }

    #[test]
    fn request_hash_stable_and_sensitive() {
        let a = GenerationRequest::generate("GPT-4.1", "prompt body", 42);
        assert_eq!(a.hash(), a.hash());
        assert_eq!(a.hash().len(), 64);
        let mut b = a.clone();
        b.seed = 43;
        assert_ne!(a.hash(), b.hash());
        let mut c = a.clone();
        c.prompt.push('x');
        assert_ne!(a.hash(), c.hash());
        let mut d = a.clone();
        d.model = "Claude-Sonnet-4".into();
        assert_ne!(a.hash(), d.hash());
        let e = GenerationRequest::repair("GPT-4.1", "prompt body", &GuardReport::default(), 42);
        assert_ne!(a.hash(), e.hash(), "role must be part of the hash");
        let f = GenerationRequest::repair("GPT-4.1", "prompt body", &sample_report(), 42);
        assert_ne!(e.hash(), f.hash(), "diagnostics must be part of the hash");
    }

    #[test]
    fn provider_spec_parses() {
        assert_eq!(ProviderSpec::parse("sim").unwrap(), ProviderSpec::Sim);
        assert_eq!(ProviderSpec::parse("").unwrap(), ProviderSpec::Sim);
        assert_eq!(ProviderSpec::parse("http").unwrap(), ProviderSpec::Http);
        assert_eq!(
            ProviderSpec::parse("replay:a/b.jsonl").unwrap(),
            ProviderSpec::Replay(PathBuf::from("a/b.jsonl"))
        );
        assert!(ProviderSpec::parse("replay").is_err());
        assert!(ProviderSpec::parse("replay:").is_err());
        assert!(ProviderSpec::parse("martian").is_err());
    }

    #[test]
    fn sim_provider_rejects_unknown_model() {
        let p = SimProvider::new();
        let req = GenerationRequest::generate("llama", "x", 0);
        assert!(p.call(&req).is_err());
        assert_eq!(p.calls(), 0);
    }
}
