//! The pluggable LLM provider API (DESIGN.md §12) — the seam every
//! generation backend plugs into.
//!
//! The paper runs three real models through one evolution framework;
//! this module makes the model an interchangeable, journaled component
//! so the same campaign can run against the SimLLM, a recorded
//! transcript, or a live OpenAI-compatible endpoint:
//!
//! * [`Provider`] — the trait: one typed call,
//!   [`GenerationRequest`] → [`GenerationResponse`].
//! * [`SimProvider`] — the SimLLM behind the seam. Byte-identical to
//!   the pre-provider free functions for a given seed: the request's
//!   `seed` is exactly the word [`Rng::derive`] would have expanded,
//!   so cached eval records and guarded replays all stay valid.
//! * [`RecordingProvider`] — transparent decorator that journals every
//!   call of an inner provider to a [`TranscriptStore`], keyed by the
//!   request content hash.
//! * [`ReplayProvider`] — serves calls from a transcript journal with
//!   **no** fallback backend: replayed campaigns perform zero live
//!   generation, and a request outside the journal is a hard error.
//!   Replay impersonates the recorded backend's label so run records
//!   match the recording run byte-for-byte.
//! * `HttpProvider` (behind the `http-provider` cargo feature, in
//!   `llm::http`) — OpenAI-compatible chat-completions client with
//!   retry/backoff and a hard token-budget cutoff.
//!
//! The honesty contract of the SimLLM (module docs of [`crate::llm`])
//! is inherited wholesale: a provider sees only the rendered prompt
//! text (plus, for repair calls, the rejected emission and the
//! structured stage-0 diagnostics), and returns raw untrusted text
//! plus real token accounting.
//!
//! [`Rng::derive`]: crate::util::Rng::derive

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::guard::{GuardDiagnostic, GuardReport};
use crate::store::{sha256_hex, TranscriptEntry, TranscriptStore};
use crate::util::Rng;
use crate::{eyre, Result};

use super::ensemble::{EnsembleProvider, EnsembleSpec, MemberBackend, RoutingSpec};
use super::profile;

/// What the caller is asking the model to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GenerationRole {
    /// Propose a candidate kernel from a rendered prompt.
    Generate,
    /// Mend a rejected emission using stage-0 guard diagnostics.
    Repair,
}

impl GenerationRole {
    pub fn as_str(self) -> &'static str {
        match self {
            GenerationRole::Generate => "generate",
            GenerationRole::Repair => "repair",
        }
    }
}

impl std::fmt::Display for GenerationRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One typed LLM call. The request is self-contained: everything a
/// backend may condition on is in here, which is what makes calls
/// hashable, journalable and replayable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerationRequest {
    pub role: GenerationRole,
    /// Model identity — a [`profile::ModelProfile`] name for the sim
    /// backend, a remote model id for HTTP.
    pub model: String,
    /// The rendered prompt (`Generate`) or the rejected emission being
    /// repaired (`Repair`).
    pub prompt: String,
    /// Structured stage-0 diagnostics (`Repair` calls only; empty for
    /// `Generate`).
    pub diagnostics: Vec<GuardDiagnostic>,
    /// Deterministic stream id, produced by
    /// [`Rng::derive_seed`](crate::util::Rng::derive_seed) exactly
    /// where the pre-provider code derived its per-call RNG — the sim
    /// backend expands it to the identical stream.
    pub seed: u64,
    /// Structured operator tag (mutation / crossover / compose / …)
    /// the engine attaches when ensemble routing is active. `None` for
    /// single-backend runs — unset fields are *not* hashed, so every
    /// pre-ensemble request hash is unchanged.
    pub operator: Option<String>,
    /// Kernel-op category (the bandit's workload axis); set together
    /// with `operator`.
    pub op_category: Option<String>,
    /// Ensemble member alias the bandit routed this call to. Part of
    /// the request hash when set: a routing decision is part of the
    /// request's identity, which is what keeps record-then-replay of
    /// ensemble campaigns byte-identical.
    pub route: Option<String>,
    /// Rendered `## PERFORMANCE PROFILE` section body (DESIGN.md §17):
    /// the previous trial's measured profile, attached by the engine
    /// when `--goal` enables profile feedback. Composed into the text
    /// a backend sees via [`Self::full_prompt`]. `None` for legacy
    /// runs — unset fields are *not* hashed, so every pre-feedback
    /// request hash is unchanged.
    pub profile: Option<String>,
    /// Search objective name (`memory`, `balanced`) when a non-default
    /// `--goal` is active; rendered as an `## OPTIMIZATION GOAL`
    /// emphasis section. `None` under the default speedup objective.
    pub goal: Option<String>,
    /// Rendered `## PRIOR ELITES` few-shot section body (DESIGN.md
    /// §18): top-K kernel-bank retrievals for this cell, attached by
    /// the engine when a warm-start bank is active. Composed into the
    /// text a backend sees via [`Self::full_prompt`]. `None` for
    /// bank-less runs — unset fields are *not* hashed, so every
    /// pre-bank request hash is unchanged.
    pub bank_refs: Option<String>,
}

impl GenerationRequest {
    /// A `Generate` call for a rendered prompt.
    pub fn generate(model: &str, prompt: &str, seed: u64) -> Self {
        GenerationRequest {
            role: GenerationRole::Generate,
            model: model.to_string(),
            prompt: prompt.to_string(),
            diagnostics: Vec::new(),
            seed,
            operator: None,
            op_category: None,
            route: None,
            profile: None,
            goal: None,
            bank_refs: None,
        }
    }

    /// A `Repair` call for a guard-rejected emission.
    pub fn repair(model: &str, src: &str, report: &GuardReport, seed: u64) -> Self {
        GenerationRequest {
            role: GenerationRole::Repair,
            model: model.to_string(),
            prompt: src.to_string(),
            diagnostics: report.diagnostics.clone(),
            seed,
            operator: None,
            op_category: None,
            route: None,
            profile: None,
            goal: None,
            bank_refs: None,
        }
    }

    /// Attach the bandit's routing decision (ensemble runs only): the
    /// operator tag, the op category, and the member alias the call is
    /// routed to. All three become part of the request hash.
    pub fn with_routing(mut self, operator: &str, category: &str, member: &str) -> Self {
        self.operator = Some(operator.to_string());
        self.op_category = Some(category.to_string());
        self.route = Some(member.to_string());
        self
    }

    /// Attach profile-guided feedback (DESIGN.md §17): the rendered
    /// performance-profile section and/or the non-default objective
    /// name. Both become part of the request hash when set.
    pub fn with_feedback(mut self, profile: Option<String>, goal: Option<String>) -> Self {
        self.profile = profile;
        self.goal = goal;
        self
    }

    /// Attach retrieved kernel-bank elites (DESIGN.md §18): the
    /// rendered `## PRIOR ELITES` section body. Part of the request
    /// hash when set — the retrieval snapshot is part of the request's
    /// identity, which is what keeps record-then-replay of warm-started
    /// campaigns byte-identical.
    pub fn with_bank_refs(mut self, bank_refs: Option<String>) -> Self {
        self.bank_refs = bank_refs;
        self
    }

    /// The complete prompt text a backend conditions on: the rendered
    /// base prompt plus — when active — the `## PRIOR ELITES`,
    /// `## PERFORMANCE PROFILE` and `## OPTIMIZATION GOAL` sections.
    /// Borrows the base prompt unchanged when no extra field is set,
    /// so legacy requests cost nothing and stay byte-identical.
    pub fn full_prompt(&self) -> std::borrow::Cow<'_, str> {
        if self.profile.is_none() && self.goal.is_none() && self.bank_refs.is_none() {
            return std::borrow::Cow::Borrowed(&self.prompt);
        }
        let mut out = String::with_capacity(self.prompt.len() + 512);
        out.push_str(&self.prompt);
        if let Some(bank_refs) = &self.bank_refs {
            if !out.ends_with('\n') {
                out.push('\n');
            }
            out.push_str("\n## PRIOR ELITES\n");
            out.push_str(bank_refs);
        }
        if let Some(profile) = &self.profile {
            if !out.ends_with('\n') {
                out.push('\n');
            }
            out.push_str("\n## PERFORMANCE PROFILE\n");
            out.push_str(profile);
        }
        if let Some(goal) = &self.goal {
            use crate::feedback::Objective;
            if !out.ends_with('\n') {
                out.push('\n');
            }
            out.push_str("\n## OPTIMIZATION GOAL\n");
            match crate::feedback::FeedbackConfig::parse(goal) {
                Ok(cfg) => out.push_str(cfg.goal.emphasis()),
                // Unknown label (a future goal replayed by an older
                // binary): surface it verbatim rather than dropping it.
                Err(_) => out.push_str(goal),
            }
            out.push('\n');
        }
        std::borrow::Cow::Owned(out)
    }

    /// Content hash of the request — the transcript journal key. The
    /// encoding is canonical (length-framed, NUL-separated fields over
    /// role, model, seed, prompt and every diagnostic), so two
    /// requests share a hash iff a backend could not tell them apart.
    pub fn hash(&self) -> String {
        let mut buf: Vec<u8> = Vec::with_capacity(64 + self.prompt.len());
        buf.extend_from_slice(b"genreq\0v1\0");
        buf.extend_from_slice(self.role.as_str().as_bytes());
        buf.push(0);
        buf.extend_from_slice(self.model.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&self.seed.to_be_bytes());
        buf.extend_from_slice(&(self.prompt.len() as u64).to_be_bytes());
        buf.extend_from_slice(self.prompt.as_bytes());
        for d in &self.diagnostics {
            buf.push(0);
            buf.extend_from_slice(d.code.as_str().as_bytes());
            buf.push(0);
            buf.extend_from_slice(d.field.as_bytes());
            buf.push(0);
            buf.extend_from_slice(d.message.as_bytes());
            buf.push(0);
            if let Some((hf, hv)) = &d.hint {
                buf.extend_from_slice(hf.as_bytes());
                buf.push(0);
                buf.extend_from_slice(hv.as_bytes());
            }
            buf.push(0);
        }
        // Routing fields are hashed only when set, behind explicit
        // tags: every request a pre-ensemble binary could build keeps
        // its exact historical hash (journal compatibility), while a
        // routed request's identity includes where it was routed.
        for (tag, field) in [
            (&b"\0operator\0"[..], &self.operator),
            (&b"\0op_category\0"[..], &self.op_category),
            (&b"\0route\0"[..], &self.route),
            (&b"\0profile\0"[..], &self.profile),
            (&b"\0goal\0"[..], &self.goal),
            (&b"\0bank_refs\0"[..], &self.bank_refs),
        ] {
            if let Some(value) = field {
                buf.extend_from_slice(tag);
                buf.extend_from_slice(&(value.len() as u64).to_be_bytes());
                buf.extend_from_slice(value.as_bytes());
            }
        }
        sha256_hex(&buf)
    }
}

/// Real token accounting for one call (prompt side measured from what
/// was sent, completion side from what came back).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TokenUsage {
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

impl TokenUsage {
    pub fn total(&self) -> u64 {
        self.prompt_tokens + self.completion_tokens
    }
}

/// One call's result: raw untrusted text, the solution insight, and
/// token accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerationResponse {
    pub text: String,
    pub insight: String,
    pub usage: TokenUsage,
}

/// A generation backend. Implementations must be `Send + Sync`: the
/// campaign worker pool shares one provider across threads.
pub trait Provider: Send + Sync {
    /// Stable backend label recorded in every run record ("sim",
    /// "http", or — for replay — the label of the backend that
    /// *recorded* the transcript).
    fn label(&self) -> &str;

    /// Execute one typed call.
    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse>;

    /// Group-commit flush point (DESIGN.md §14): the engine calls this
    /// at every trial boundary; backends that buffer journal appends
    /// (the recording decorator) make them durable here. Default:
    /// no-op.
    fn flush(&self) {}

    /// Routing facts for the engine's bandit (DESIGN.md §16): `Some`
    /// only for a multi-member [`EnsembleProvider`] (decorators
    /// delegate; replay reconstructs it from the impersonated label).
    /// `None` means the engine attaches no routing fields to requests,
    /// which is what makes a single-backend run — and a degenerate
    /// one-member ensemble — byte-identical to the historical path.
    fn routing(&self) -> Option<RoutingSpec> {
        None
    }
}

// ---------------------------------------------------------------------
// SimProvider

/// The SimLLM behind the provider seam.
///
/// Delegates to the free functions [`crate::llm::generate`] /
/// [`crate::llm::repair`] with `Rng::new(req.seed)` — byte-identical
/// to the pre-provider call sites for the same derived seed (proven by
/// `tests/provider_conformance.rs`).
#[derive(Debug, Default)]
pub struct SimProvider {
    calls: AtomicU64,
}

impl SimProvider {
    pub fn new() -> Self {
        Self::default()
    }

    /// Live generations performed by this instance (the
    /// record-then-replay identity test's zero-live-calls proof).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

impl Provider for SimProvider {
    fn label(&self) -> &str {
        "sim"
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        let prof = profile::by_name(&req.model)
            .ok_or_else(|| eyre!("sim provider: unknown model `{}`", req.model))?;
        let mut rng = Rng::new(req.seed);
        let resp = match req.role {
            // `full_prompt` borrows the base prompt unchanged when no
            // feedback sections are attached — the legacy path is
            // byte-identical.
            GenerationRole::Generate => super::generate(&req.full_prompt(), prof, &mut rng),
            GenerationRole::Repair => {
                let report = GuardReport { diagnostics: req.diagnostics.clone() };
                super::repair(&req.prompt, &report, prof, &mut rng)
            }
        };
        self.calls.fetch_add(1, Ordering::Relaxed);
        Ok(GenerationResponse {
            text: resp.text,
            insight: resp.insight,
            usage: TokenUsage {
                prompt_tokens: resp.prompt_tokens,
                completion_tokens: resp.completion_tokens,
            },
        })
    }
}

// ---------------------------------------------------------------------
// RecordingProvider

/// Transparent decorator: every call of the inner provider is appended
/// to a [`TranscriptStore`] keyed by the request hash. The label stays
/// the inner backend's — recording is provenance-neutral.
///
/// With [`RecordingProvider::with_reuse`], requests the journal
/// already covers are served from it without touching the inner
/// backend — the trial-granular resume mechanism (DESIGN.md §13): a
/// resumed campaign leg replays an interrupted cell's completed trials
/// from the journal (zero live generation, bit-identical) and goes
/// live only from the first unrecorded call. Responses are identical
/// either way for a deterministic backend; for HTTP this is what makes
/// mid-cell resume cheap *and* reproducible.
pub struct RecordingProvider {
    inner: Arc<dyn Provider>,
    journal: Arc<TranscriptStore>,
    reuse: bool,
}

impl RecordingProvider {
    /// Wrap `inner`, declaring it as the journal's source backend.
    /// Fails if the journal was recorded by a different backend.
    pub fn new(inner: Arc<dyn Provider>, journal: Arc<TranscriptStore>) -> Result<Self> {
        journal.record_source(inner.label())?;
        Ok(Self { inner, journal, reuse: false })
    }

    /// Serve already-journaled requests from the journal instead of
    /// re-calling the inner backend.
    pub fn with_reuse(mut self, reuse: bool) -> Self {
        self.reuse = reuse;
        self
    }

    pub fn journal(&self) -> &Arc<TranscriptStore> {
        &self.journal
    }
}

impl Provider for RecordingProvider {
    fn label(&self) -> &str {
        self.inner.label()
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        if self.reuse {
            if let Some(entry) = self.journal.lookup(&req.hash()) {
                return Ok(GenerationResponse {
                    text: entry.text,
                    insight: entry.insight,
                    usage: TokenUsage {
                        prompt_tokens: entry.prompt_tokens,
                        completion_tokens: entry.completion_tokens,
                    },
                });
            }
        }
        let resp = self.inner.call(req)?;
        let entry = TranscriptEntry {
            role: req.role.as_str().to_string(),
            model: req.model.clone(),
            seed: req.seed,
            text: resp.text.clone(),
            insight: resp.insight.clone(),
            prompt_tokens: resp.usage.prompt_tokens,
            completion_tokens: resp.usage.completion_tokens,
        };
        let key = req.hash();
        if let Err(e) = self.journal.append(&key, entry) {
            // Advisory, like the eval cache: a failed journal write
            // must not kill the run that produced the response.
            eprintln!("warning: transcript append failed: {e:#}");
        }
        // Journal the routing decision next to the call it routed
        // (ensemble runs only) — the transcript is then a complete
        // audit record of *where* every call went, not just what it
        // returned.
        if let Some(member) = &req.route {
            if let Err(e) = self.journal.append_route(&key, member) {
                eprintln!("warning: transcript route append failed: {e:#}");
            }
        }
        Ok(resp)
    }

    fn flush(&self) {
        if let Err(e) = self.journal.flush() {
            eprintln!("warning: transcript flush failed: {e:#}");
        }
    }

    fn routing(&self) -> Option<RoutingSpec> {
        self.inner.routing()
    }
}

// ---------------------------------------------------------------------
// ReplayProvider

/// Serves every call from a recorded transcript journal. No inner
/// backend: a request the journal does not cover is a hard error, so a
/// successful replay run performed zero live generation by
/// construction.
pub struct ReplayProvider {
    journal: Arc<TranscriptStore>,
    /// Impersonated label (the journal's recorded source backend).
    label: String,
    /// Routing facts reconstructed from the impersonated label when
    /// the journal was recorded by a multi-member ensemble: the replay
    /// engine re-runs the same bandit over the same spec, so every
    /// request re-acquires the recorded route — and hash — with zero
    /// live generation.
    routing: Option<RoutingSpec>,
}

impl ReplayProvider {
    /// Open a journal for replay. The file must exist.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(eyre!(
                "no transcript journal at {} — record one first (run with \
                 `--provider sim` or `--provider http` and `--transcripts`)",
                path.display()
            ));
        }
        let journal = TranscriptStore::open(path)?;
        let label = journal.source().unwrap_or_else(|| "replay".to_string());
        // An ensemble label round-trips through the spec grammar
        // (members are resolved inline at record time, never behind a
        // config file), so the recorded routing setup is recoverable
        // from the label alone.
        let routing = match ProviderSpec::parse(&label) {
            Ok(ProviderSpec::Ensemble(spec)) => spec.routing(),
            _ => None,
        };
        Ok(Self { journal, label, routing })
    }

    pub fn len(&self) -> usize {
        self.journal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.journal.is_empty()
    }
}

impl Provider for ReplayProvider {
    fn label(&self) -> &str {
        &self.label
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        let entry = self.journal.lookup(&req.hash()).ok_or_else(|| {
            eyre!(
                "transcript miss: no recorded {} call for model {} (seed {}) in {} — \
                 the journal does not cover this run's grid/budget; re-record it \
                 (archive-reading methods like AI CUDA Engineer additionally need \
                 --concurrency 1 on both legs, DESIGN.md §12)",
                req.role,
                req.model,
                req.seed,
                self.journal.path().display()
            )
        })?;
        Ok(GenerationResponse {
            text: entry.text,
            insight: entry.insight,
            usage: TokenUsage {
                prompt_tokens: entry.prompt_tokens,
                completion_tokens: entry.completion_tokens,
            },
        })
    }

    fn routing(&self) -> Option<RoutingSpec> {
        self.routing.clone()
    }
}

// ---------------------------------------------------------------------
// ProviderSpec: CLI / config surface

/// The full `--provider` grammar, quoted verbatim by every parse
/// error so a malformed spec never strands the user without the
/// accepted forms.
pub const PROVIDER_GRAMMAR: &str = "\
accepted --provider forms:
  sim                    simulated LLM (default)
  replay:<path>          play back a recorded transcript journal
  http                   OpenAI-compatible endpoint (`http-provider` feature)
  ensemble:[m,m,...]     weighted multi-backend ensemble; each member is
                         (sim|http)[#alias][@weight] and an optional
                         x=<ratio> member sets the bandit exploration ratio
  ensemble:@<file.json>  ensemble members loaded from a JSON config file";

/// Which backend to run — the parsed form of the `--provider` flag.
/// See [`PROVIDER_GRAMMAR`] for the accepted surface syntax.
///
/// `Eq` is deliberately absent: ensemble member weights are `f64`
/// priors. `PartialEq` is all every call site needs (spec matching and
/// the coordinator/worker mismatch check).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ProviderSpec {
    #[default]
    Sim,
    Replay(PathBuf),
    Http,
    /// A weighted multi-backend ensemble (DESIGN.md §16). `@file.json`
    /// forms are resolved eagerly at parse time, so a spec in hand —
    /// and the label it round-trips to — never depends on a config
    /// file still existing (the coordinator serves the resolved label
    /// to workers that have no such file).
    Ensemble(EnsembleSpec),
}

impl ProviderSpec {
    /// Parse a `--provider` value. Errors name the offending token and
    /// quote [`PROVIDER_GRAMMAR`].
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "" | "sim" => Ok(ProviderSpec::Sim),
            "http" => Ok(ProviderSpec::Http),
            "replay" => Err(eyre!(
                "`--provider replay` needs a journal: replay:<transcripts.jsonl>\n{PROVIDER_GRAMMAR}"
            )),
            "ensemble" => Err(eyre!(
                "`--provider ensemble` needs members: ensemble:[sim@0.5,sim#alt@0.5] \
                 or ensemble:@<file.json>\n{PROVIDER_GRAMMAR}"
            )),
            other => {
                if let Some(path) = other.strip_prefix("replay:") {
                    if path.is_empty() {
                        return Err(eyre!(
                            "`replay:` is missing its journal path\n{PROVIDER_GRAMMAR}"
                        ));
                    }
                    Ok(ProviderSpec::Replay(PathBuf::from(path)))
                } else if let Some(body) = other.strip_prefix("ensemble:") {
                    Ok(ProviderSpec::Ensemble(EnsembleSpec::parse(body)?))
                } else {
                    Err(eyre!(
                        "unknown --provider token `{other}`\n{PROVIDER_GRAMMAR}"
                    ))
                }
            }
        }
    }

    /// The flag syntax this spec round-trips to:
    /// `ProviderSpec::parse(spec.label())` reproduces `spec` exactly
    /// (ensembles render their eagerly-resolved inline form).
    pub fn label(&self) -> String {
        match self {
            ProviderSpec::Sim => "sim".into(),
            ProviderSpec::Replay(p) => format!("replay:{}", p.display()),
            ProviderSpec::Http => "http".into(),
            ProviderSpec::Ensemble(spec) => spec.label(),
        }
    }
}

// ---------------------------------------------------------------------
// ProviderConfig: the one typed way to build a provider stack

/// What a recording provider does with requests its journal already
/// covers — the typed replacement for the old `reuse: bool` argument
/// of [`build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReusePolicy {
    /// Every request goes to the backend; the journal only records.
    #[default]
    Fresh,
    /// Requests the journal covers are served from it without touching
    /// the backend — the trial-granular resume mechanism (DESIGN.md
    /// §13): a resumed leg replays completed trials with zero live
    /// generation and goes live from the first unrecorded call.
    Resume,
}

impl ReusePolicy {
    pub fn label(self) -> &'static str {
        match self {
            ReusePolicy::Fresh => "fresh",
            ReusePolicy::Resume => "resume",
        }
    }
}

/// Everything needed to build a provider stack, in one typed value —
/// the builder that replaces the `(spec, transcripts, reuse)` triple
/// previously re-matched at every call site.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ProviderConfig {
    pub spec: ProviderSpec,
    /// Journal for recording live calls. Ignored for `replay:` specs —
    /// a replayed run records nothing, its journal already is the
    /// record (the builder owns that rule so call sites don't).
    pub transcripts: Option<PathBuf>,
    pub reuse: ReusePolicy,
}

impl ProviderConfig {
    pub fn new(spec: ProviderSpec) -> Self {
        ProviderConfig { spec, transcripts: None, reuse: ReusePolicy::Fresh }
    }

    /// Record live calls to `path` (`None` disables recording).
    pub fn transcripts(mut self, path: Option<PathBuf>) -> Self {
        self.transcripts = path;
        self
    }

    pub fn reuse(mut self, policy: ReusePolicy) -> Self {
        self.reuse = policy;
        self
    }
}

#[cfg(feature = "http-provider")]
fn http_backend() -> Result<Arc<dyn Provider>> {
    Ok(Arc::new(super::http::HttpProvider::from_env()?))
}

#[cfg(not(feature = "http-provider"))]
fn http_backend() -> Result<Arc<dyn Provider>> {
    Err(eyre!(
        "this binary was built without the `http-provider` feature; \
         rebuild with `cargo build --features http-provider`"
    ))
}

/// One ensemble member's backend instance.
fn member_backend(backend: MemberBackend) -> Result<Arc<dyn Provider>> {
    match backend {
        MemberBackend::Sim => Ok(Arc::new(SimProvider::new())),
        MemberBackend::Http => http_backend(),
    }
}

/// Build the provider stack a [`ProviderConfig`] describes.
pub fn build(cfg: &ProviderConfig) -> Result<Arc<dyn Provider>> {
    Ok(build_with_journal(cfg)?.0)
}

/// [`build`], also handing back the transcript journal the stack
/// records to (if any) — the campaign wire workers upload journal
/// deltas and need the handle the recording decorator writes through.
pub fn build_with_journal(
    cfg: &ProviderConfig,
) -> Result<(Arc<dyn Provider>, Option<Arc<TranscriptStore>>)> {
    let base: Arc<dyn Provider> = match &cfg.spec {
        ProviderSpec::Sim => Arc::new(SimProvider::new()),
        ProviderSpec::Replay(path) => {
            return Ok((Arc::new(ReplayProvider::open(path)?), None));
        }
        ProviderSpec::Http => http_backend()?,
        ProviderSpec::Ensemble(spec) => {
            let mut members = Vec::with_capacity(spec.members.len());
            for m in &spec.members {
                members.push((m.alias.clone(), member_backend(m.backend)?));
            }
            Arc::new(EnsembleProvider::new(members, spec))
        }
    };
    match &cfg.transcripts {
        Some(path) => {
            let journal = TranscriptStore::open(path)?;
            let reuse = cfg.reuse == ReusePolicy::Resume;
            let provider =
                Arc::new(RecordingProvider::new(base, journal.clone())?.with_reuse(reuse));
            Ok((provider, Some(journal)))
        }
        None => Ok((base, None)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guard::GuardCode;

    fn sample_report() -> GuardReport {
        GuardReport {
            diagnostics: vec![GuardDiagnostic {
                code: GuardCode::ResourceLimit,
                field: "vector_width".into(),
                message: "vector_width=3 not a supported packing".into(),
                hint: Some(("vector_width".into(), "4".into())),
            }],
        }
    }

    #[test]
    fn request_hash_stable_and_sensitive() {
        let a = GenerationRequest::generate("GPT-4.1", "prompt body", 42);
        assert_eq!(a.hash(), a.hash());
        assert_eq!(a.hash().len(), 64);
        let mut b = a.clone();
        b.seed = 43;
        assert_ne!(a.hash(), b.hash());
        let mut c = a.clone();
        c.prompt.push('x');
        assert_ne!(a.hash(), c.hash());
        let mut d = a.clone();
        d.model = "Claude-Sonnet-4".into();
        assert_ne!(a.hash(), d.hash());
        let e = GenerationRequest::repair("GPT-4.1", "prompt body", &GuardReport::default(), 42);
        assert_ne!(a.hash(), e.hash(), "role must be part of the hash");
        let f = GenerationRequest::repair("GPT-4.1", "prompt body", &sample_report(), 42);
        assert_ne!(e.hash(), f.hash(), "diagnostics must be part of the hash");
    }

    #[test]
    fn provider_spec_parses() {
        assert_eq!(ProviderSpec::parse("sim").unwrap(), ProviderSpec::Sim);
        assert_eq!(ProviderSpec::parse("").unwrap(), ProviderSpec::Sim);
        assert_eq!(ProviderSpec::parse("http").unwrap(), ProviderSpec::Http);
        assert_eq!(
            ProviderSpec::parse("replay:a/b.jsonl").unwrap(),
            ProviderSpec::Replay(PathBuf::from("a/b.jsonl"))
        );
        assert!(ProviderSpec::parse("replay").is_err());
        assert!(ProviderSpec::parse("replay:").is_err());
        assert!(ProviderSpec::parse("martian").is_err());
    }

    #[test]
    fn sim_provider_rejects_unknown_model() {
        let p = SimProvider::new();
        let req = GenerationRequest::generate("llama", "x", 0);
        assert!(p.call(&req).is_err());
        assert_eq!(p.calls(), 0);
    }

    #[test]
    fn parse_errors_name_the_token_and_quote_the_grammar() {
        // Every error arm must (a) point at the offending token and
        // (b) quote the full accepted grammar, ensemble forms included.
        for (input, named) in [
            ("martian", "martian"),
            ("replay", "replay"),
            ("replay:", "replay:"),
            ("ensemble", "ensemble"),
            ("ensemble:", "ensemble"),
            ("ensemble:[sim@0.5", "["),
            ("ensemble:[]", "["),
            ("ensemble:[sim@nope]", "nope"),
            ("ensemble:[sim@0.0]", "sim@0.0"),
            ("ensemble:[fpga@1.0]", "fpga"),
            ("ensemble:[sim@0.5,sim@0.5]", "sim"),
            ("ensemble:[sim@1.0,x=zero]", "zero"),
        ] {
            let err = format!("{:#}", ProviderSpec::parse(input).unwrap_err());
            assert!(err.contains(named), "error for `{input}` must name `{named}`: {err}");
            assert!(
                err.contains("accepted --provider forms"),
                "error for `{input}` must quote PROVIDER_GRAMMAR: {err}"
            );
            assert!(err.contains("ensemble:@<file.json>"), "{err}");
        }
    }

    #[test]
    fn ensemble_specs_parse_and_labels_roundtrip() {
        for s in [
            "ensemble:[sim@1.0]",
            "ensemble:[sim@0.5,sim#alt@0.5]",
            "ensemble:[sim@0.7,sim#alt@0.3,x=0.1]",
        ] {
            let spec = ProviderSpec::parse(s).unwrap();
            assert!(matches!(spec, ProviderSpec::Ensemble(_)), "{s}");
            let relabeled = ProviderSpec::parse(&spec.label()).unwrap();
            assert_eq!(spec, relabeled, "label must round-trip for {s}");
        }
    }

    #[test]
    fn routing_fields_extend_the_hash_without_perturbing_legacy_requests() {
        let bare = GenerationRequest::generate("GPT-4.1", "prompt body", 42);
        // No routing: hash is the pre-ensemble legacy hash (fields are
        // appended only when present, so old journals stay valid).
        assert_eq!(bare.operator, None);
        assert_eq!(bare.op_category, None);
        assert_eq!(bare.route, None);
        let routed = bare.clone().with_routing("mutate", "matmul", "alt");
        assert_ne!(bare.hash(), routed.hash(), "route must be part of the hash");
        let other_member = bare.clone().with_routing("mutate", "matmul", "sim");
        assert_ne!(routed.hash(), other_member.hash());
        let other_op = bare.clone().with_routing("crossover", "matmul", "alt");
        assert_ne!(routed.hash(), other_op.hash());
        // Deterministic across re-hashing.
        assert_eq!(routed.hash(), routed.hash());
    }

    #[test]
    fn feedback_fields_extend_the_hash_without_perturbing_legacy_requests() {
        let bare = GenerationRequest::generate("GPT-4.1", "## TASK\nop: x\n", 42);
        assert_eq!(bare.profile, None);
        assert_eq!(bare.goal, None);
        // Unset feedback never changes the hash or the prompt text.
        let noop = bare.clone().with_feedback(None, None);
        assert_eq!(bare.hash(), noop.hash());
        assert!(matches!(noop.full_prompt(), std::borrow::Cow::Borrowed(_)));
        assert_eq!(noop.full_prompt(), bare.prompt);

        let profiled = bare.clone().with_feedback(Some("outcome: ok\n".into()), None);
        assert_ne!(bare.hash(), profiled.hash(), "profile must be part of the hash");
        let goaled = bare.clone().with_feedback(None, Some("memory".into()));
        assert_ne!(bare.hash(), goaled.hash(), "goal must be part of the hash");
        assert_ne!(profiled.hash(), goaled.hash());
        let both = bare
            .clone()
            .with_feedback(Some("outcome: ok\n".into()), Some("memory".into()));
        assert_ne!(both.hash(), profiled.hash());
        assert_ne!(both.hash(), goaled.hash());
        assert_eq!(both.hash(), both.hash());

        // Composed prompt carries both sections, base prompt first.
        let text = both.full_prompt().into_owned();
        assert!(text.starts_with("## TASK\n"));
        assert!(text.contains("## PERFORMANCE PROFILE\noutcome: ok\n"));
        assert!(text.contains("## OPTIMIZATION GOAL\n"));
        assert!(text.contains("DRAM traffic"), "memory emphasis rendered: {text}");
        // Feedback composes with routing (both tag families hashed).
        let routed = both.clone().with_routing("mutate", "matmul", "alt");
        assert_ne!(routed.hash(), both.hash());
    }

    #[test]
    fn bank_refs_extend_the_hash_without_perturbing_legacy_requests() {
        let bare = GenerationRequest::generate("GPT-4.1", "## TASK\nop: x\n", 42);
        assert_eq!(bare.bank_refs, None);
        // Unset bank refs never change the hash or the prompt text —
        // every pre-bank journal hash survives.
        let noop = bare.clone().with_bank_refs(None);
        assert_eq!(bare.hash(), noop.hash());
        assert!(matches!(noop.full_prompt(), std::borrow::Cow::Borrowed(_)));

        let refs = "### elite 1 | op x | speedup 2.000x | goal speedup\nkernel a { }\n";
        let seeded = bare.clone().with_bank_refs(Some(refs.into()));
        assert_ne!(bare.hash(), seeded.hash(), "bank refs must be part of the hash");
        let other = bare.clone().with_bank_refs(Some("different refs\n".into()));
        assert_ne!(seeded.hash(), other.hash());
        assert_eq!(seeded.hash(), seeded.hash());

        // Composed prompt: base first, then the PRIOR ELITES section.
        let text = seeded.full_prompt().into_owned();
        assert!(text.starts_with("## TASK\n"));
        assert!(text.contains("## PRIOR ELITES\n### elite 1 |"));

        // Bank refs compose with feedback: elites section precedes the
        // profile/goal sections, and all tag families hash.
        let stacked = seeded
            .clone()
            .with_feedback(Some("outcome: ok\n".into()), Some("memory".into()));
        assert_ne!(stacked.hash(), seeded.hash());
        let text = stacked.full_prompt().into_owned();
        let elites = text.find("## PRIOR ELITES").unwrap();
        let profile = text.find("## PERFORMANCE PROFILE").unwrap();
        let goal = text.find("## OPTIMIZATION GOAL").unwrap();
        assert!(elites < profile && profile < goal);
        // The NUL-framed tag encoding cannot be confused across
        // fields: a goal value equal to a bank_refs value still yields
        // distinct hashes.
        let as_goal = bare.clone().with_feedback(None, Some(refs.into()));
        let as_refs = bare.clone().with_bank_refs(Some(refs.into()));
        assert_ne!(as_goal.hash(), as_refs.hash());
    }

    #[test]
    fn provider_config_builder_defaults_and_build() {
        let cfg = ProviderConfig::new(ProviderSpec::Sim);
        assert_eq!(cfg.reuse, ReusePolicy::Fresh);
        assert!(cfg.transcripts.is_none());
        let p = build(&cfg).unwrap();
        assert_eq!(p.label(), "sim");
        assert!(p.routing().is_none(), "bare sim has no routing table");

        // Single-member ensemble builds straight through to the inner
        // backend: same label, no routing, so the whole pipeline is
        // byte-identical to `--provider sim` (DESIGN.md §16).
        let single =
            ProviderConfig::new(ProviderSpec::parse("ensemble:[sim@1.0]").unwrap());
        let p = build(&single).unwrap();
        assert_eq!(p.label(), "sim");
        assert!(p.routing().is_none());

        // Multi-member: canonical ensemble label plus a routing table
        // carrying both members and the exploration ratio.
        let multi = ProviderConfig::new(
            ProviderSpec::parse("ensemble:[sim@0.75,sim#alt@0.25,x=0.5]").unwrap(),
        );
        let p = build(&multi).unwrap();
        assert_eq!(p.label(), "ensemble:[sim@0.75,sim#alt@0.25,x=0.5]");
        let routing = p.routing().expect("multi-member ensembles must expose routing");
        assert_eq!(routing.members.len(), 2);
        assert_eq!(routing.members[0].0, "sim");
        assert_eq!(routing.members[1].0, "alt");
        assert_eq!(routing.exploration_ratio, 0.5);
    }
}
