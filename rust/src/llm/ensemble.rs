//! Weighted multi-backend ensembles behind the provider seam
//! (DESIGN.md §16).
//!
//! The paper's cost/quality results (Table 6) come from running
//! *different* models through the same evolution framework; an
//! [`EnsembleProvider`] makes that a single run: each
//! [`GenerationRequest`] is dispatched to one of N configured member
//! backends. Which member handles a call is **not** decided here — the
//! engine's seed-deterministic bandit ([`super::bandit`]) picks a
//! member at request-assembly time and stamps the decision into the
//! request's `route` field, so the decision is part of the request
//! hash, journaled with the call, and exactly re-derived on replay.
//! This provider only honours the stamp.
//!
//! Determinism contract:
//!
//! * a **single-member** ensemble never routes: requests pass through
//!   untouched, the label is the member's own, and every byte of
//!   records, transcripts and reports matches the bare backend;
//! * a **multi-member** ensemble exposes a [`RoutingSpec`] via
//!   [`Provider::routing`]; the engine does the rest.
//!
//! [`GenerationRequest`]: super::GenerationRequest
//! [`Provider::routing`]: super::Provider::routing

use std::sync::Arc;

use crate::util::json;
use crate::{eyre, Result};

use super::provider::{
    GenerationRequest, GenerationResponse, Provider, PROVIDER_GRAMMAR,
};

/// Bandit exploration ratio when the spec does not set `x=<ratio>`
/// (the OpenEvolve-style default).
pub const DEFAULT_EXPLORATION_RATIO: f64 = 0.25;

/// Which live backend an ensemble member instantiates. `replay:` and
/// nested ensembles are grammar errors — replay already impersonates
/// whatever recorded the journal, ensemble included.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberBackend {
    Sim,
    Http,
}

impl MemberBackend {
    pub fn label(self) -> &'static str {
        match self {
            MemberBackend::Sim => "sim",
            MemberBackend::Http => "http",
        }
    }
}

/// One configured ensemble member: a backend, a unique alias (the
/// bandit's arm identity and the `route` value stamped into requests),
/// and a prior routing weight.
#[derive(Debug, Clone, PartialEq)]
pub struct EnsembleMember {
    pub backend: MemberBackend,
    pub alias: String,
    pub weight: f64,
}

/// Parsed form of `ensemble:[...]` / `ensemble:@<file.json>` — always
/// fully resolved: config-file forms are read at parse time, so the
/// spec (and the label it round-trips to) never depends on the file
/// afterwards. That is what lets the campaign coordinator hand the
/// resolved label to wire workers that have no such file.
#[derive(Debug, Clone, PartialEq)]
pub struct EnsembleSpec {
    pub members: Vec<EnsembleMember>,
    pub exploration_ratio: f64,
}

impl EnsembleSpec {
    /// Parse the part after `ensemble:` — either `[m,m,...]` or
    /// `@<file.json>`.
    pub fn parse(body: &str) -> Result<Self> {
        if let Some(path) = body.strip_prefix('@') {
            if path.is_empty() {
                return Err(eyre!(
                    "`ensemble:@` is missing its config-file path\n{PROVIDER_GRAMMAR}"
                ));
            }
            return Self::parse_file(path);
        }
        let inner = body
            .strip_prefix('[')
            .and_then(|b| b.strip_suffix(']'))
            .ok_or_else(|| {
                eyre!(
                    "ensemble members must be bracketed, like \
                     ensemble:[sim@0.5,sim#alt@0.5] — got `ensemble:{body}`\n{PROVIDER_GRAMMAR}"
                )
            })?;
        let mut members = Vec::new();
        let mut ratio = None;
        for token in inner.split(',') {
            let token = token.trim();
            if token.is_empty() {
                return Err(eyre!(
                    "empty member token in `ensemble:{body}`\n{PROVIDER_GRAMMAR}"
                ));
            }
            if let Some(r) = token.strip_prefix("x=") {
                if ratio.replace(parse_ratio(r, token)?).is_some() {
                    return Err(eyre!(
                        "duplicate exploration-ratio token `{token}` in \
                         `ensemble:{body}`\n{PROVIDER_GRAMMAR}"
                    ));
                }
                continue;
            }
            members.push(parse_member(token)?);
        }
        Self::assemble(members, ratio.unwrap_or(DEFAULT_EXPLORATION_RATIO))
    }

    /// Load members from a JSON config file:
    /// `{"members":[{"backend":"sim","alias":"a","weight":0.5},...],
    ///   "exploration_ratio":0.25}`
    /// (`alias` defaults to the backend name, `weight` to 1).
    fn parse_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| eyre!("reading ensemble config `{path}`: {e}\n{PROVIDER_GRAMMAR}"))?;
        let v = json::parse(&text)
            .map_err(|e| eyre!("ensemble config `{path}` is not valid JSON: {e}\n{PROVIDER_GRAMMAR}"))?;
        let arr = v.get("members").and_then(|m| m.as_arr()).ok_or_else(|| {
            eyre!("ensemble config `{path}` needs a `members` array\n{PROVIDER_GRAMMAR}")
        })?;
        let mut members = Vec::new();
        for (i, m) in arr.iter().enumerate() {
            let backend_tok = m.get("backend").and_then(|b| b.as_str()).ok_or_else(|| {
                eyre!(
                    "ensemble config `{path}`: member {i} is missing its string \
                     `backend` field\n{PROVIDER_GRAMMAR}"
                )
            })?;
            let backend = parse_backend(backend_tok, backend_tok)?;
            let alias = m
                .get("alias")
                .and_then(|a| a.as_str())
                .unwrap_or(backend_tok)
                .to_string();
            check_alias(&alias, backend_tok)?;
            let weight = match m.get("weight") {
                None => 1.0,
                Some(w) => {
                    let w = w.as_f64().ok_or_else(|| {
                        eyre!(
                            "ensemble config `{path}`: member {i} `weight` must be a \
                             number\n{PROVIDER_GRAMMAR}"
                        )
                    })?;
                    check_weight(w, &alias)?;
                    w
                }
            };
            members.push(EnsembleMember { backend, alias, weight });
        }
        let ratio = match v.get("exploration_ratio") {
            None => DEFAULT_EXPLORATION_RATIO,
            Some(r) => {
                let r = r.as_f64().ok_or_else(|| {
                    eyre!(
                        "ensemble config `{path}`: `exploration_ratio` must be a \
                         number\n{PROVIDER_GRAMMAR}"
                    )
                })?;
                check_ratio(r, "exploration_ratio")?
            }
        };
        Self::assemble(members, ratio)
    }

    fn assemble(members: Vec<EnsembleMember>, exploration_ratio: f64) -> Result<Self> {
        if members.is_empty() {
            return Err(eyre!(
                "ensemble has no members — at least one of sim|http is \
                 required\n{PROVIDER_GRAMMAR}"
            ));
        }
        for (i, m) in members.iter().enumerate() {
            if members[..i].iter().any(|p| p.alias == m.alias) {
                return Err(eyre!(
                    "duplicate ensemble member alias `{}` — disambiguate with \
                     #<alias>\n{PROVIDER_GRAMMAR}",
                    m.alias
                ));
            }
        }
        Ok(EnsembleSpec { members, exploration_ratio })
    }

    /// Canonical inline form, always including weights and the
    /// exploration ratio: `ensemble:[sim@0.5,sim#alt@0.5,x=0.25]`.
    /// `ProviderSpec::parse` of this string reproduces the spec.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = self
            .members
            .iter()
            .map(|m| {
                let backend = m.backend.label();
                if m.alias == backend {
                    format!("{backend}@{}", m.weight)
                } else {
                    format!("{backend}#{}@{}", m.alias, m.weight)
                }
            })
            .collect();
        parts.push(format!("x={}", self.exploration_ratio));
        format!("ensemble:[{}]", parts.join(","))
    }

    /// Routing facts for the engine's bandit — `None` for a degenerate
    /// single-member spec (no routing, byte-identical to the bare
    /// member backend).
    pub fn routing(&self) -> Option<RoutingSpec> {
        if self.members.len() < 2 {
            return None;
        }
        Some(RoutingSpec {
            members: self
                .members
                .iter()
                .map(|m| (m.alias.clone(), m.weight))
                .collect(),
            exploration_ratio: self.exploration_ratio,
        })
    }
}

fn parse_backend(tok: &str, member: &str) -> Result<MemberBackend> {
    if tok == "sim" {
        Ok(MemberBackend::Sim)
    } else if tok == "http" {
        Ok(MemberBackend::Http)
    } else if tok.starts_with("replay") || tok.starts_with("ensemble") {
        Err(eyre!(
            "`{tok}` cannot be an ensemble member — members are live backends \
             (sim | http); ensembles do not nest and replay already impersonates \
             whatever recorded the journal\n{PROVIDER_GRAMMAR}"
        ))
    } else {
        Err(eyre!(
            "unknown ensemble member backend `{tok}` in `{member}`\n{PROVIDER_GRAMMAR}"
        ))
    }
}

fn check_alias(alias: &str, member: &str) -> Result<()> {
    let ok = !alias.is_empty()
        && alias
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-');
    if ok {
        Ok(())
    } else {
        Err(eyre!(
            "bad ensemble member alias `{alias}` in `{member}` — aliases are \
             non-empty [A-Za-z0-9_-]\n{PROVIDER_GRAMMAR}"
        ))
    }
}

fn check_weight(w: f64, member: &str) -> Result<()> {
    if w.is_finite() && w > 0.0 {
        Ok(())
    } else {
        Err(eyre!(
            "ensemble member weight `{w}` in `{member}` must be a finite number \
             > 0\n{PROVIDER_GRAMMAR}"
        ))
    }
}

fn parse_ratio(text: &str, token: &str) -> Result<f64> {
    let r: f64 = text.parse().map_err(|_| {
        eyre!(
            "bad exploration ratio `{text}` in `{token}` (expected a \
             number)\n{PROVIDER_GRAMMAR}"
        )
    })?;
    check_ratio(r, token)
}

fn check_ratio(r: f64, token: &str) -> Result<f64> {
    if (0.0..=1.0).contains(&r) {
        Ok(r)
    } else {
        Err(eyre!(
            "exploration ratio `{r}` in `{token}` must be within \
             [0, 1]\n{PROVIDER_GRAMMAR}"
        ))
    }
}

/// One member token: `(sim|http)[#alias][@weight]`.
fn parse_member(token: &str) -> Result<EnsembleMember> {
    let (head, weight) = match token.rsplit_once('@') {
        Some((head, w)) => {
            let weight: f64 = w.parse().map_err(|_| {
                eyre!(
                    "bad ensemble member weight `{w}` in `{token}` (expected a \
                     number)\n{PROVIDER_GRAMMAR}"
                )
            })?;
            check_weight(weight, token)?;
            (head, weight)
        }
        None => (token, 1.0),
    };
    let (backend_tok, alias) = match head.split_once('#') {
        Some((b, a)) => (b, a.to_string()),
        None => (head, head.to_string()),
    };
    let backend = parse_backend(backend_tok, token)?;
    check_alias(&alias, token)?;
    Ok(EnsembleMember { backend, alias, weight })
}

/// What the engine's bandit needs from a multi-member ensemble: the
/// member aliases with their prior weights, and the exploration ratio.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingSpec {
    /// `(alias, prior weight)` in configured order — selection
    /// tie-breaks by this order, so it is part of the determinism
    /// contract.
    pub members: Vec<(String, f64)>,
    pub exploration_ratio: f64,
}

/// The ensemble behind the provider seam: dispatches each call to the
/// member the request's `route` stamp names. See the module docs for
/// the split of responsibilities with the engine-side bandit.
pub struct EnsembleProvider {
    members: Vec<(String, Arc<dyn Provider>)>,
    /// Single member: that member's own label (byte-identity with the
    /// bare backend). Multi-member: the spec's canonical inline label,
    /// which replay parses back into a [`RoutingSpec`].
    label: String,
    routing: Option<RoutingSpec>,
}

impl EnsembleProvider {
    /// Wrap instantiated member backends. `members` pairs each alias
    /// with its backend, in spec order; `spec` supplies the label and
    /// routing facts.
    pub fn new(members: Vec<(String, Arc<dyn Provider>)>, spec: &EnsembleSpec) -> Self {
        assert!(!members.is_empty(), "ensemble needs at least one member");
        let label = if members.len() == 1 {
            members[0].1.label().to_string()
        } else {
            spec.label()
        };
        Self { members, label, routing: spec.routing() }
    }
}

impl Provider for EnsembleProvider {
    fn label(&self) -> &str {
        &self.label
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        if self.members.len() == 1 {
            return self.members[0].1.call(req);
        }
        let route = req.route.as_deref().ok_or_else(|| {
            eyre!(
                "ensemble `{}` received an unrouted request (role {}, seed {}) — \
                 the engine must stamp a member route before calling a \
                 multi-member ensemble",
                self.label,
                req.role,
                req.seed
            )
        })?;
        let member = self
            .members
            .iter()
            .find(|(alias, _)| alias == route)
            .ok_or_else(|| {
                eyre!(
                    "ensemble `{}` has no member aliased `{route}` (members: {})",
                    self.label,
                    self.members
                        .iter()
                        .map(|(a, _)| a.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        member.1.call(req)
    }

    fn flush(&self) {
        for (_, m) in &self.members {
            m.flush();
        }
    }

    fn routing(&self) -> Option<RoutingSpec> {
        self.routing.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{ProviderSpec, SimProvider};

    fn spec(s: &str) -> EnsembleSpec {
        match ProviderSpec::parse(s).unwrap() {
            ProviderSpec::Ensemble(spec) => spec,
            other => panic!("expected ensemble, got {other:?}"),
        }
    }

    #[test]
    fn inline_grammar_parses() {
        let e = spec("ensemble:[sim@0.5,sim#alt@0.5]");
        assert_eq!(e.members.len(), 2);
        assert_eq!(e.members[0].alias, "sim");
        assert_eq!(e.members[0].weight, 0.5);
        assert_eq!(e.members[1].alias, "alt");
        assert_eq!(e.members[1].backend, MemberBackend::Sim);
        assert_eq!(e.exploration_ratio, DEFAULT_EXPLORATION_RATIO);

        let e = spec("ensemble:[sim,http#remote@2,x=0.1]");
        assert_eq!(e.members[0].weight, 1.0);
        assert_eq!(e.members[1].backend, MemberBackend::Http);
        assert_eq!(e.members[1].alias, "remote");
        assert_eq!(e.exploration_ratio, 0.1);
    }

    #[test]
    fn label_round_trips_through_parse() {
        for s in [
            "ensemble:[sim@0.5,sim#alt@0.5]",
            "ensemble:[sim,http#remote@2,x=0.1]",
            "ensemble:[sim]",
        ] {
            let e = spec(s);
            let back = spec(&e.label());
            assert_eq!(e, back, "label {} must round-trip", e.label());
        }
    }

    #[test]
    fn config_file_form_resolves_eagerly() {
        let dir = std::env::temp_dir()
            .join(format!("evo_ensemble_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ensemble.json");
        std::fs::write(
            &path,
            r#"{"members":[{"backend":"sim","alias":"a","weight":0.75},
                           {"backend":"sim","alias":"b"}],
                "exploration_ratio":0.5}"#,
        )
        .unwrap();
        let e = spec(&format!("ensemble:@{}", path.display()));
        assert_eq!(e.members.len(), 2);
        assert_eq!(e.members[0].weight, 0.75);
        assert_eq!(e.members[1].weight, 1.0);
        assert_eq!(e.exploration_ratio, 0.5);
        // Eager resolution: the label is the inline form and survives
        // the file disappearing (the coordinator→worker contract).
        std::fs::remove_file(&path).unwrap();
        assert_eq!(spec(&e.label()), e);
        assert!(!e.label().contains('@') || !e.label().contains(".json"));
    }

    #[test]
    fn routing_only_for_multi_member() {
        assert!(spec("ensemble:[sim]").routing().is_none());
        let r = spec("ensemble:[sim@3,sim#alt@1,x=0.2]").routing().unwrap();
        assert_eq!(r.members, vec![("sim".into(), 3.0), ("alt".into(), 1.0)]);
        assert_eq!(r.exploration_ratio, 0.2);
    }

    #[test]
    fn unrouted_call_to_multi_member_is_an_error() {
        let e = spec("ensemble:[sim,sim#alt]");
        let p = EnsembleProvider::new(
            vec![
                ("sim".into(), Arc::new(SimProvider::new()) as Arc<dyn Provider>),
                ("alt".into(), Arc::new(SimProvider::new()) as Arc<dyn Provider>),
            ],
            &e,
        );
        let req = crate::llm::GenerationRequest::generate("GPT-4.1", "p", 7);
        let err = p.call(&req).unwrap_err();
        assert!(err.to_string().contains("unrouted"), "{err}");
        let ok = req.clone().with_routing("mutation", "matmul", "alt");
        assert!(p.call(&ok).is_ok());
        let bad = req.with_routing("mutation", "matmul", "ghost");
        assert!(p.call(&bad).unwrap_err().to_string().contains("ghost"));
    }

    #[test]
    fn single_member_passthrough_keeps_bare_identity() {
        let e = spec("ensemble:[sim]");
        let inner = Arc::new(SimProvider::new());
        let p = EnsembleProvider::new(vec![("sim".into(), inner as _)], &e);
        assert_eq!(p.label(), "sim");
        assert!(p.routing().is_none());
        let req = crate::llm::GenerationRequest::generate("GPT-4.1", "p", 7);
        let bare = SimProvider::new().call(&req).unwrap();
        assert_eq!(p.call(&req).unwrap(), bare);
    }
}
