//! Model profiles for the three LLMs the paper evaluates (§5.1,
//! Table 6). Rates are calibrated so campaign-level aggregates land in
//! the neighbourhood of the paper's Table 4 patterns:
//!
//! * overall per-trial compile success 65–90%, functional 45–70%,
//!   modulated by the traverse configuration;
//! * GPT-4.1 weak on category 4 (norm/reduction) but strongest on
//!   category 5 (losses); DeepSeek-V3.1 and Claude-Sonnet-4 excel on
//!   category 4 (the paper's "Cross-Model Ability" observation);
//! * category 6 (cumulative) hardest for everyone;
//! * Claude slightly more verbose per completion (pricing table 6),
//!   DeepSeek most conservative (lowest temperature).

/// Behavioural profile of one simulated LLM.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Base probability of emitting syntactically-broken text.
    pub syntax_rate: f64,
    /// Base probability of rewriting the semantics (wrong numerics or
    /// hallucinated variant).
    pub semantic_rate: f64,
    /// Base probability of an illegal schedule slipping out.
    pub legality_rate: f64,
    /// Exploration temperature (move count / jump probability scale).
    pub temperature: f64,
    /// Probability a mutation move is *directed* (domain-informed).
    pub skill: f64,
    /// Probability of following a positive recorded insight.
    pub insight_follow: f64,
    /// Per-category multiplier on `skill` (index = category - 1).
    pub category_skill: [f64; 6],
    /// Per-category multiplier on defect rates (index = category - 1).
    pub category_validity: [f64; 6],
    /// Completion-length factor (reasoning verbosity).
    pub verbosity: f64,
    /// API price, USD per million prompt tokens (paper Table 6 —
    /// feeds the per-provider cost accounting in `report tokens`).
    pub usd_per_mtok_prompt: f64,
    /// API price, USD per million completion tokens (paper Table 6).
    pub usd_per_mtok_completion: f64,
}

impl ModelProfile {
    /// Modeled API cost of a token count under this profile's pricing.
    pub fn cost_usd(&self, prompt_tokens: u64, completion_tokens: u64) -> f64 {
        prompt_tokens as f64 / 1e6 * self.usd_per_mtok_prompt
            + completion_tokens as f64 / 1e6 * self.usd_per_mtok_completion
    }
}

/// GPT-4.1, DeepSeek-V3.1, Claude-Sonnet-4 — in the paper's order.
pub static MODELS: &[ModelProfile] = &[
    ModelProfile {
        name: "GPT-4.1",
        syntax_rate: 0.10,
        semantic_rate: 0.16,
        legality_rate: 0.09,
        temperature: 1.00,
        skill: 0.55,
        insight_follow: 0.60,
        category_skill: [1.00, 0.95, 1.05, 0.55, 1.35, 0.90],
        category_validity: [0.90, 1.00, 0.95, 1.10, 0.90, 2.30],
        verbosity: 1.00,
        usd_per_mtok_prompt: 2.00,
        usd_per_mtok_completion: 8.00,
    },
    ModelProfile {
        name: "DeepSeek-V3.1",
        syntax_rate: 0.12,
        semantic_rate: 0.18,
        legality_rate: 0.10,
        temperature: 0.80,
        skill: 0.50,
        insight_follow: 0.65,
        category_skill: [0.80, 0.85, 0.95, 1.45, 1.00, 0.95],
        category_validity: [0.80, 1.00, 1.00, 1.00, 0.90, 2.60],
        verbosity: 0.90,
        usd_per_mtok_prompt: 0.56,
        usd_per_mtok_completion: 1.68,
    },
    ModelProfile {
        name: "Claude-Sonnet-4",
        syntax_rate: 0.08,
        semantic_rate: 0.15,
        legality_rate: 0.08,
        temperature: 1.10,
        skill: 0.60,
        insight_follow: 0.60,
        category_skill: [1.00, 1.00, 1.30, 1.25, 1.05, 1.00],
        category_validity: [0.85, 0.95, 0.90, 1.00, 0.90, 1.80],
        verbosity: 1.15,
        usd_per_mtok_prompt: 3.00,
        usd_per_mtok_completion: 15.00,
    },
];

/// Look a profile up by (case-insensitive prefix of) name.
pub fn by_name(name: &str) -> Option<&'static ModelProfile> {
    let needle = name.to_ascii_lowercase();
    MODELS
        .iter()
        .find(|m| m.name.to_ascii_lowercase().starts_with(&needle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_models_in_paper_order() {
        assert_eq!(MODELS.len(), 3);
        assert_eq!(MODELS[0].name, "GPT-4.1");
        assert_eq!(MODELS[1].name, "DeepSeek-V3.1");
        assert_eq!(MODELS[2].name, "Claude-Sonnet-4");
    }

    #[test]
    fn cross_model_pattern_encoded() {
        let gpt = &MODELS[0];
        let dsk = &MODELS[1];
        let cla = &MODELS[2];
        // GPT weak cat4, strong cat5; DeepSeek/Claude strong cat4.
        assert!(gpt.category_skill[3] < dsk.category_skill[3]);
        assert!(gpt.category_skill[3] < cla.category_skill[3]);
        assert!(gpt.category_skill[4] > dsk.category_skill[4]);
        // cat6 hardest (validity multiplier > 1) for everyone.
        for m in MODELS {
            assert!(m.category_validity[5] > 1.0, "{}", m.name);
        }
    }

    #[test]
    fn pricing_is_positive_and_completion_heavier() {
        for m in MODELS {
            assert!(m.usd_per_mtok_prompt > 0.0, "{}", m.name);
            assert!(
                m.usd_per_mtok_completion > m.usd_per_mtok_prompt,
                "{}: completion tokens price above prompt tokens",
                m.name
            );
        }
        // 1M prompt + 1M completion tokens of GPT-4.1 = $10 (Table 6).
        assert!((MODELS[0].cost_usd(1_000_000, 1_000_000) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_prefix() {
        assert_eq!(by_name("gpt").unwrap().name, "GPT-4.1");
        assert_eq!(by_name("claude").unwrap().name, "Claude-Sonnet-4");
        assert_eq!(by_name("DeepSeek-V3.1").unwrap().name, "DeepSeek-V3.1");
        assert!(by_name("llama").is_none());
    }
}
