//! Schedule move operators, insight application, consistency repair,
//! and defect injection — the SimLLM's "hands".
//!
//! Every move returns a human-readable action string in the canonical
//! insight grammar (`set <field> to <value> (<why>)` / `enabled <field>
//! (<why>)` / `disabled <field>`), which is exactly what
//! [`apply_insight`] can parse back — closing the I3 loop: insights
//! recorded from one trial really steer later trials.

use crate::dsl::{Layout, Schedule};
use crate::util::Rng;

const TILE_CHOICES: [u32; 6] = [8, 16, 32, 64, 128, 256];
const VW_CHOICES: [u32; 4] = [1, 2, 4, 8];
const UNROLL_CHOICES: [u32; 5] = [1, 2, 4, 8, 16];
const TPB_CHOICES: [u32; 6] = [32, 64, 128, 256, 512, 1024];
const REG_CHOICES: [u32; 6] = [32, 64, 96, 128, 168, 255];

const FIELDS: [&str; 10] = [
    "tile_m",
    "tile_n",
    "tile_k",
    "vector_width",
    "unroll",
    "stages",
    "smem_staging",
    "fuse_epilogue",
    "layout",
    "threads_per_block",
];

fn set_field(s: &mut Schedule, field: &str, value: &str) -> bool {
    let as_u32 = || value.parse::<u32>().ok();
    match field {
        "tile_m" => as_u32().map(|v| s.tile_m = v).is_some(),
        "tile_n" => as_u32().map(|v| s.tile_n = v).is_some(),
        "tile_k" => as_u32().map(|v| s.tile_k = v).is_some(),
        "vector_width" => as_u32().map(|v| s.vector_width = v).is_some(),
        "unroll" => as_u32().map(|v| s.unroll = v).is_some(),
        "stages" => as_u32().map(|v| s.stages = v).is_some(),
        "threads_per_block" => as_u32().map(|v| s.threads_per_block = v).is_some(),
        "regs_per_thread" => as_u32().map(|v| s.regs_per_thread = v).is_some(),
        "smem_staging" => {
            s.smem_staging = value == "true";
            true
        }
        "fuse_epilogue" => {
            s.fuse_epilogue = value == "true";
            true
        }
        "layout" => Layout::from_str(value).map(|l| s.layout = l).is_some(),
        _ => false,
    }
}

/// Apply an insight action string; returns the note if it applied.
///
/// Grammar accepted: `set <field> to <value> ...`, `enabled <field> ...`,
/// `disabled <field> ...`, `adopted <field>=<value> ...`.
pub fn apply_insight(s: &mut Schedule, action: &str) -> Option<String> {
    let words: Vec<&str> = action.split_whitespace().collect();
    match words.as_slice() {
        ["set", field, "to", value, ..] => {
            let value = value.trim_end_matches([',', ';', '.']);
            set_field(s, field, value).then(|| format!("set {field} to {value} (followed insight)"))
        }
        ["enabled", field, ..] => {
            set_field(s, field, "true").then(|| format!("enabled {field} (followed insight)"))
        }
        ["disabled", field, ..] => {
            set_field(s, field, "false").then(|| format!("disabled {field} (followed insight)"))
        }
        ["adopted", assign, ..] => {
            let (field, value) = assign.split_once('=')?;
            set_field(s, field, value).then(|| format!("adopted {field}={value} (followed insight)"))
        }
        _ => None,
    }
}

/// Copy one random schedule field from a donor (I2 crossover).
pub fn copy_random_field(s: &mut Schedule, donor: &Schedule, rng: &mut Rng) -> String {
    let field = *rng.pick(&FIELDS);
    let value = match field {
        "tile_m" => donor.tile_m.to_string(),
        "tile_n" => donor.tile_n.to_string(),
        "tile_k" => donor.tile_k.to_string(),
        "vector_width" => donor.vector_width.to_string(),
        "unroll" => donor.unroll.to_string(),
        "stages" => donor.stages.to_string(),
        "smem_staging" => donor.smem_staging.to_string(),
        "fuse_epilogue" => donor.fuse_epilogue.to_string(),
        "layout" => donor.layout.as_str().to_string(),
        _ => donor.threads_per_block.to_string(),
    };
    set_field(s, field, &value);
    format!("adopted {field}={value} (from a historical solution)")
}

/// A domain-informed improvement move — what distinguishes a skilled
/// model from random search. Targets the real levers of the cost model
/// without consulting it (these are textbook CUDA heuristics).
pub fn directed_move(s: &mut Schedule, category: u8, rng: &mut Rng) -> String {
    // Priority repair/improvement list, category-aware.
    let gemm_like = matches!(category, 1 | 2);
    if category == 6 && !s.smem_staging && rng.chance(0.12) {
        // Textbook CUDA: cumulative ops need a staged block scan.
        s.smem_staging = true;
        s.stages = 2;
        return "enabled smem_staging (staged Blelloch block scan)".into();
    }
    if !s.fuse_epilogue && rng.chance(0.6) {
        s.fuse_epilogue = true;
        return "enabled fuse_epilogue (eliminate extra passes and launches)".into();
    }
    if gemm_like && !s.smem_staging && rng.chance(0.7) {
        s.smem_staging = true;
        s.stages = 2;
        return "enabled smem_staging (stage operand tiles for reuse)".into();
    }
    if s.vector_width < 8 && rng.chance(0.5) {
        let v = s.vector_width * 2;
        s.vector_width = v;
        return format!("set vector_width to {v} (wider vectorized loads)");
    }
    if gemm_like && s.smem_staging && (s.tile_m < 32 || s.tile_n < 32) && rng.chance(0.6) {
        s.tile_m = (s.tile_m * 2).min(64);
        s.tile_n = (s.tile_n * 2).min(64);
        return format!(
            "set tile_m to {} (grow the staged tile footprint)",
            s.tile_m
        );
    }
    if gemm_like && s.layout != Layout::Tiled && rng.chance(0.4) {
        s.layout = Layout::Tiled;
        return "set layout to tiled (tile-contiguous operand staging)".into();
    }
    if !gemm_like && s.layout == Layout::ColMajor {
        s.layout = Layout::RowMajor;
        return "set layout to row_major (coalesced last-axis access)".into();
    }
    if s.est_registers() > s.regs_per_thread {
        let r = REG_CHOICES
            .iter()
            .copied()
            .find(|r| *r >= s.est_registers().min(255))
            .unwrap_or(255);
        s.regs_per_thread = r;
        return format!("set regs_per_thread to {r} (avoid register spill)");
    }
    if s.threads_per_block != 256 && rng.chance(0.4) {
        s.threads_per_block = 256;
        return "set threads_per_block to 256 (balanced occupancy)".into();
    }
    if s.unroll < 2 {
        s.unroll = 2;
        return "set unroll to 2 (feed the pipelines)".into();
    }
    if s.smem_staging && s.stages == 1 {
        s.stages = 2;
        return "set stages to 2 (double buffering)".into();
    }
    // Nothing obviously broken: local tile tweak.
    random_move(s, true, rng)
}

/// A random neighbourhood move (temperature-driven exploration).
/// `param_only` restricts to numeric tweaks (EoH's M2 operator).
pub fn random_move(s: &mut Schedule, param_only: bool, rng: &mut Rng) -> String {
    let n_fields = if param_only { 7 } else { 10 };
    match rng.below(n_fields) {
        0 => {
            s.tile_m = *rng.pick(&TILE_CHOICES);
            format!("set tile_m to {} (tile sweep)", s.tile_m)
        }
        1 => {
            s.tile_n = *rng.pick(&TILE_CHOICES);
            format!("set tile_n to {} (tile sweep)", s.tile_n)
        }
        2 => {
            s.tile_k = *rng.pick(&TILE_CHOICES);
            format!("set tile_k to {} (tile sweep)", s.tile_k)
        }
        3 => {
            s.vector_width = *rng.pick(&VW_CHOICES);
            format!("set vector_width to {} (load width sweep)", s.vector_width)
        }
        4 => {
            s.unroll = *rng.pick(&UNROLL_CHOICES);
            format!("set unroll to {} (unroll sweep)", s.unroll)
        }
        5 => {
            s.threads_per_block = *rng.pick(&TPB_CHOICES);
            format!(
                "set threads_per_block to {} (block size sweep)",
                s.threads_per_block
            )
        }
        6 => {
            s.regs_per_thread = *rng.pick(&REG_CHOICES);
            format!("set regs_per_thread to {} (register budget)", s.regs_per_thread)
        }
        7 => {
            s.stages = 1 + rng.below(4) as u32;
            format!("set stages to {} (pipelining depth)", s.stages)
        }
        8 => {
            s.smem_staging = !s.smem_staging;
            if s.smem_staging {
                "enabled smem_staging (try operand staging)".into()
            } else {
                "disabled smem_staging".into()
            }
        }
        _ => {
            let flip = !s.fuse_epilogue;
            s.fuse_epilogue = flip;
            if flip {
                "enabled fuse_epilogue (fuse the epilogue)".into()
            } else {
                "disabled fuse_epilogue".into()
            }
        }
    }
}

/// Repair obviously-inconsistent combinations the way a competent
/// programmer silently would (stages without staging, spilled budget).
pub fn make_consistent(s: &mut Schedule) {
    if s.stages > 1 && !s.smem_staging {
        s.smem_staging = true;
    }
    if s.est_registers() > 255 {
        // Shrink the per-thread output slice by raising the block size.
        s.threads_per_block = 1024.min(((s.threads_per_block * 2) / 32) * 32).max(32);
        if s.est_registers() > 255 {
            s.tile_m = s.tile_m.min(64);
            s.tile_n = s.tile_n.min(64);
        }
        // Still over (wide vectors x deep unroll): back off the
        // operand registers the way a compiler pragma would.
        while s.est_registers() > 255 && s.unroll > 1 {
            s.unroll /= 2;
        }
        while s.est_registers() > 255 && s.vector_width > 1 {
            s.vector_width /= 2;
        }
        while s.est_registers() > 255 && s.tile_m.min(s.tile_n) > 1 {
            s.tile_m = (s.tile_m / 2).max(1);
            s.tile_n = (s.tile_n / 2).max(1);
        }
    }
    // Respect the smem ceiling by shrinking tile_k first (cheapest).
    while s.smem_bytes() > crate::dsl::validate::MAX_SMEM_BYTES && s.tile_k > 1 {
        s.tile_k /= 2;
    }
    while s.smem_bytes() > crate::dsl::validate::MAX_SMEM_BYTES && s.stages > 1 {
        s.stages -= 1;
    }
}

/// Apply a structured guard repair hint: `op` and `semantics` address
/// the program header, anything else a schedule field. Returns whether
/// the assignment applied (the hook [`crate::llm::repair`] feeds
/// stage-0 [`GuardDiagnostic`](crate::guard::GuardDiagnostic) hints
/// through).
pub fn apply_named_fix(spec: &mut crate::dsl::KernelSpec, field: &str, value: &str) -> bool {
    match field {
        "op" => {
            spec.op = value.to_string();
            true
        }
        "semantics" => {
            spec.semantics = value.to_string();
            true
        }
        _ => set_field(&mut spec.schedule, field, value),
    }
}

/// Mechanically mend the textual slips [`corrupt_text`] injects: a
/// misspelled `schedule` keyword, a `:` flipped to `=`, an unbalanced
/// closing brace. (A dropped semicolon is not mechanically recoverable
/// without a parse, which is exactly why syntax repair sometimes
/// fails — like a real LLM regenerating from a diagnostic.)
pub fn mend_text(text: &str) -> String {
    let mut t = text.replace("schedul ", "schedule ").replace("schedul{", "schedule{");
    if t.contains('=') {
        // `=` never appears in legal KernelScript; it is a flipped `:`.
        t = t.replacen('=', ":", 1);
    }
    let opens = t.matches('{').count();
    let closes = t.matches('}').count();
    for _ in closes..opens {
        t.push_str("\n}");
    }
    t
}

/// Inject an illegal-schedule defect (stage-1 validation failure).
pub fn inject_legality_defect(s: &mut Schedule, rng: &mut Rng) -> String {
    match rng.below(4) {
        0 => {
            s.threads_per_block = 96 + rng.below(7) as u32; // not mult of 32
            "tuned threads_per_block oddly".into()
        }
        1 => {
            s.vector_width = 3 + 2 * rng.below(2) as u32; // 3 or 5
            "used an unsupported vector packing".into()
        }
        2 => {
            s.smem_staging = true;
            s.stages = 4;
            s.tile_m = 256;
            s.tile_n = 256;
            s.tile_k = 64;
            "requested an oversized staged tile".into()
        }
        _ => {
            s.regs_per_thread = 300 + rng.below(100) as u32;
            "requested too many registers".into()
        }
    }
}

/// Corrupt emitted text (syntax defect): drop a semicolon, misspell a
/// keyword, or truncate the closing brace — all realistic LLM slips.
pub fn corrupt_text(text: &str, rng: &mut Rng) -> String {
    match rng.below(4) {
        0 => text.replacen(';', " ", 1),
        1 => text.replacen("schedule", "schedul", 1),
        2 => {
            let mut t = text.trim_end().to_string();
            t.pop(); // drop final `}`
            t
        }
        _ => text.replacen(':', "=", 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{parse, print, validate, KernelSpec};

    #[test]
    fn insight_roundtrip_set() {
        let mut s = Schedule::default();
        let note = apply_insight(&mut s, "set vector_width to 8 (wider loads)").unwrap();
        assert_eq!(s.vector_width, 8);
        assert!(note.contains("vector_width"));
    }

    #[test]
    fn insight_roundtrip_enable_disable() {
        let mut s = Schedule::default();
        apply_insight(&mut s, "enabled fuse_epilogue (single pass)").unwrap();
        assert!(s.fuse_epilogue);
        apply_insight(&mut s, "disabled fuse_epilogue").unwrap();
        assert!(!s.fuse_epilogue);
    }

    #[test]
    fn insight_roundtrip_adopted() {
        let mut s = Schedule::default();
        apply_insight(&mut s, "adopted tile_k=64 (from a historical solution)").unwrap();
        assert_eq!(s.tile_k, 64);
    }

    #[test]
    fn every_emitted_note_is_reapplicable() {
        // The closing of the I3 loop: whatever nota the move operators
        // emit, apply_insight must understand (when it names a field).
        let mut rng = Rng::new(11);
        for i in 0..200 {
            let mut s = Schedule::default();
            let mut r = rng.derive(&format!("m{i}"));
            let note = if i % 2 == 0 {
                directed_move(&mut s, 1 + (i % 6) as u8, &mut r)
            } else {
                random_move(&mut s, false, &mut r)
            };
            let mut s2 = Schedule::default();
            if note.starts_with("set ") || note.starts_with("enabled ")
                || note.starts_with("disabled ") || note.starts_with("adopted ")
            {
                assert!(
                    apply_insight(&mut s2, &note).is_some(),
                    "unparseable note: {note}"
                );
            }
        }
    }

    #[test]
    fn make_consistent_produces_valid_schedules() {
        let mut rng = Rng::new(7);
        for i in 0..500 {
            let mut s = Schedule::default();
            let mut r = rng.derive(&format!("c{i}"));
            for _ in 0..6 {
                random_move(&mut s, false, &mut r);
            }
            make_consistent(&mut s);
            let spec = KernelSpec { op: "x".into(), semantics: "opt".into(), schedule: s };
            validate(&spec).unwrap_or_else(|e| panic!("iteration {i}: {e}\n{spec:?}"));
        }
    }

    #[test]
    fn mend_text_recovers_most_corruptions() {
        let text = print(&KernelSpec::baseline("matmul_64"));
        let mut rng = Rng::new(9);
        let mut mended = 0;
        let mut broken = 0;
        for i in 0..80 {
            let mut r = rng.derive(&format!("m{i}"));
            let bad = corrupt_text(&text, &mut r);
            if parse(&bad).is_ok() {
                continue; // corruption happened to stay parseable
            }
            broken += 1;
            if parse(&mend_text(&bad)).is_ok() {
                mended += 1;
            }
        }
        // 3 of the 4 corruption classes are mechanically invertible.
        assert!(
            mended * 2 > broken,
            "only {mended}/{broken} corrupted programs mended"
        );
        // Clean text is left semantically untouched.
        assert_eq!(parse(&mend_text(&text)).unwrap(), parse(&text).unwrap());
    }

    #[test]
    fn apply_named_fix_addresses_header_and_schedule() {
        let mut spec = KernelSpec::baseline("matmul_64");
        assert!(apply_named_fix(&mut spec, "semantics", "ref"));
        assert_eq!(spec.semantics, "ref");
        assert!(apply_named_fix(&mut spec, "op", "softmax_64"));
        assert_eq!(spec.op, "softmax_64");
        assert!(apply_named_fix(&mut spec, "tile_m", "64"));
        assert_eq!(spec.schedule.tile_m, 64);
        assert!(!apply_named_fix(&mut spec, "warp_size", "32"));
    }

    #[test]
    fn corruption_breaks_parsing() {
        let text = print(&KernelSpec::baseline("matmul_64"));
        let mut rng = Rng::new(3);
        let mut broke = 0;
        for i in 0..40 {
            let mut r = rng.derive(&format!("x{i}"));
            if parse(&corrupt_text(&text, &mut r)).is_err() {
                broke += 1;
            }
        }
        assert!(broke >= 35, "only {broke}/40 corruptions broke the parse");
    }

    #[test]
    fn legality_defects_fail_validation() {
        let mut rng = Rng::new(4);
        let mut failed = 0;
        for i in 0..40 {
            let mut s = Schedule::default();
            let mut r = rng.derive(&format!("d{i}"));
            inject_legality_defect(&mut s, &mut r);
            let spec = KernelSpec { op: "x".into(), semantics: "opt".into(), schedule: s };
            if validate(&spec).is_err() {
                failed += 1;
            }
        }
        assert!(failed >= 38, "only {failed}/40 defects failed validation");
    }
}
