//! `HttpProvider` — OpenAI-compatible chat-completions backend for the
//! provider seam (DESIGN.md §12), behind the `http-provider` cargo
//! feature.
//!
//! The build environment is offline (no HTTP crates in the pre-seeded
//! cache), so the client is a minimal hand-rolled HTTP/1.1
//! implementation over `std::net::TcpStream`: plain `http://` only
//! (front a TLS endpoint with a local gateway), `Connection: close`
//! per request, Content-Length and chunked response bodies. That is
//! exactly enough for a local vLLM / llama.cpp / LiteLLM-style
//! gateway, and for the stub-server tests below. Response parsing
//! lives in the shared, feature-independent wire layer
//! ([`crate::util::httpwire`]) alongside the campaign plane's
//! client/server half (DESIGN.md §15).
//!
//! Configuration comes from the environment (all optional except the
//! endpoint when the defaults don't fit):
//!
//! | variable                | default                    | meaning |
//! |-------------------------|----------------------------|---------|
//! | `EVO_HTTP_BASE_URL`     | `http://127.0.0.1:8000/v1` | endpoint base; `/chat/completions` is appended |
//! | `EVO_HTTP_API_KEY`      | unset                      | sent as `Authorization: Bearer …` |
//! | `EVO_HTTP_MODEL`        | unset                      | overrides the request's model id |
//! | `EVO_HTTP_RETRIES`      | `3`                        | retries after connect errors / 5xx |
//! | `EVO_HTTP_BACKOFF_MS`   | `250`                      | base backoff, doubling per retry |
//! | `EVO_HTTP_TIMEOUT_MS`   | `60000`                    | connect/read/write timeout |
//! | `EVO_HTTP_TOKEN_BUDGET` | unset                      | **hard** cutoff on total tokens |
//!
//! The token budget is a hard stop, not advisory: each call atomically
//! *reserves* its prompt-side estimate before dialing out (so N racing
//! campaign workers cannot all slip under the line) and reconciles to
//! the endpoint's reported usage afterwards; once the budget is
//! crossed, every further call errors, which aborts the campaign sweep
//! cleanly. Overshoot is bounded by the completions already in flight
//! — a runaway endpoint cannot burn an unbounded bill.
//!
//! Determinism caveat: a real model is not a pure function of the
//! request, so HTTP runs are only replayable through the transcript
//! journal (`--transcripts` + `--provider replay:<path>`), never by
//! re-running live. The request seed is forwarded (31-bit, the common
//! API range) for endpoints that support seeded sampling.

use std::io::{Read as _, Write as _};
use std::net::{TcpStream, ToSocketAddrs as _};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::httpwire::parse_http_response;
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

use super::count_tokens;
use super::provider::{
    GenerationRequest, GenerationResponse, GenerationRole, Provider, TokenUsage,
};

/// Connection + policy configuration (see module docs for the env
/// mapping).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    pub base_url: String,
    pub api_key: Option<String>,
    /// Overrides the request's model id (the sim profile names are not
    /// real API model ids).
    pub model_override: Option<String>,
    pub retries: u32,
    pub backoff_ms: u64,
    pub timeout_ms: u64,
    /// Hard cutoff on cumulative prompt+completion tokens.
    pub token_budget: Option<u64>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            base_url: "http://127.0.0.1:8000/v1".into(),
            api_key: None,
            model_override: None,
            retries: 3,
            backoff_ms: 250,
            timeout_ms: 60_000,
            token_budget: None,
        }
    }
}

fn env_num<T: std::str::FromStr>(key: &str) -> Result<Option<T>> {
    match std::env::var(key) {
        Ok(v) => v
            .parse()
            .map(Some)
            .map_err(|_| eyre!("bad numeric value in ${key}: {v}")),
        Err(_) => Ok(None),
    }
}

impl HttpConfig {
    /// Read the `EVO_HTTP_*` environment.
    pub fn from_env() -> Result<Self> {
        let mut cfg = Self::default();
        if let Ok(v) = std::env::var("EVO_HTTP_BASE_URL") {
            cfg.base_url = v;
        }
        if let Ok(v) = std::env::var("EVO_HTTP_API_KEY") {
            cfg.api_key = Some(v);
        }
        if let Ok(v) = std::env::var("EVO_HTTP_MODEL") {
            cfg.model_override = Some(v);
        }
        if let Some(v) = env_num("EVO_HTTP_RETRIES")? {
            cfg.retries = v;
        }
        if let Some(v) = env_num("EVO_HTTP_BACKOFF_MS")? {
            cfg.backoff_ms = v;
        }
        if let Some(v) = env_num("EVO_HTTP_TIMEOUT_MS")? {
            cfg.timeout_ms = v;
        }
        cfg.token_budget = env_num("EVO_HTTP_TOKEN_BUDGET")?;
        Ok(cfg)
    }
}

const GENERATE_SYSTEM: &str = "You are an expert GPU kernel engineer. Respond with a single \
KernelScript program for the operation in the prompt (no commentary, no code fences), then one \
final line `INSIGHT: <one-line optimization insight>`.";
const REPAIR_SYSTEM: &str = "You are an expert GPU kernel engineer. Fix the kernel so it \
passes the static checks; keep the optimization intent. Respond with the corrected \
KernelScript program only, then one final line `INSIGHT: <what you fixed>`.";

/// OpenAI-compatible chat-completions provider.
pub struct HttpProvider {
    cfg: HttpConfig,
    /// Host header value (host or host:port as written in the URL).
    host: String,
    /// `host:port` used for the TCP connect.
    authority: String,
    /// URL path prefix (e.g. `/v1`), no trailing slash.
    path: String,
    spent: AtomicU64,
}

impl HttpProvider {
    pub fn new(cfg: HttpConfig) -> Result<Self> {
        let rest = cfg.base_url.strip_prefix("http://").ok_or_else(|| {
            eyre!(
                "EVO_HTTP_BASE_URL must be plain http:// (the offline client has no TLS; \
                 front an https endpoint with a local gateway): `{}`",
                cfg.base_url
            )
        })?;
        let (hostport, path) = match rest.find('/') {
            Some(i) => (&rest[..i], rest[i..].trim_end_matches('/')),
            None => (rest, ""),
        };
        if hostport.is_empty() {
            return Err(eyre!("EVO_HTTP_BASE_URL has no host: `{}`", cfg.base_url));
        }
        let authority = if hostport.contains(':') {
            hostport.to_string()
        } else {
            format!("{hostport}:80")
        };
        Ok(Self {
            host: hostport.to_string(),
            authority,
            path: path.to_string(),
            spent: AtomicU64::new(0),
            cfg,
        })
    }

    pub fn from_env() -> Result<Self> {
        Self::new(HttpConfig::from_env()?)
    }

    /// Cumulative prompt+completion tokens consumed by this provider.
    pub fn tokens_spent(&self) -> u64 {
        self.spent.load(Ordering::Relaxed)
    }

    fn body_for(&self, req: &GenerationRequest) -> String {
        let model = self
            .cfg
            .model_override
            .clone()
            .unwrap_or_else(|| req.model.clone());
        let msg = |role: &str, content: &str| {
            Json::obj(vec![
                ("role", Json::Str(role.to_string())),
                ("content", Json::Str(content.to_string())),
            ])
        };
        let (system, user) = match req.role {
            // `full_prompt` appends the performance-profile / goal
            // sections when feedback is active (DESIGN.md §17).
            GenerationRole::Generate => (GENERATE_SYSTEM, req.full_prompt().into_owned()),
            GenerationRole::Repair => {
                let mut diags = String::new();
                for d in &req.diagnostics {
                    diags.push_str(&format!("- {d}\n"));
                }
                (
                    REPAIR_SYSTEM,
                    format!("## PROGRAM\n{}\n\n## DIAGNOSTICS\n{diags}", req.prompt),
                )
            }
        };
        Json::obj(vec![
            ("model", Json::Str(model)),
            ("messages", Json::Arr(vec![msg("system", system), msg("user", &user)])),
            // Common API seed range is 32-bit; forward the low 31 bits
            // of the deterministic request seed.
            ("seed", Json::Num((req.seed & 0x7fff_ffff) as f64)),
        ])
        .to_string()
    }

    fn post_chat(&self, body: &str) -> Result<(u16, String)> {
        let timeout = Duration::from_millis(self.cfg.timeout_ms.max(1));
        let addr = self
            .authority
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", self.authority))?
            .next()
            .ok_or_else(|| eyre!("no address for {}", self.authority))?;
        let mut stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("connecting to {}", self.authority))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        let mut head = format!(
            "POST {}/chat/completions HTTP/1.1\r\nHost: {}\r\n\
             Content-Type: application/json\r\nAccept: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n",
            self.path,
            self.host,
            body.len()
        );
        if let Some(key) = &self.cfg.api_key {
            head.push_str(&format!("Authorization: Bearer {key}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;
        let mut raw = Vec::new();
        stream
            .read_to_end(&mut raw)
            .context("reading chat-completions response")?;
        parse_http_response(&raw)
    }
}

impl HttpProvider {
    fn post_with_retries(&self, body: &str, req: &GenerationRequest) -> Result<GenerationResponse> {
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                let factor = 1u64 << (attempt - 1).min(6);
                std::thread::sleep(Duration::from_millis(
                    self.cfg.backoff_ms.saturating_mul(factor),
                ));
            }
            match self.post_chat(body) {
                Err(e) => last_err = Some(e),
                Ok((status, text)) if status >= 500 => {
                    last_err = Some(eyre!("HTTP {status}: {}", snippet(&text)));
                }
                Ok((status, text)) if !(200..300).contains(&status) => {
                    // 4xx etc.: the request itself is bad; retrying
                    // cannot help.
                    return Err(eyre!(
                        "http provider: HTTP {status} (not retryable): {}",
                        snippet(&text)
                    ));
                }
                Ok((_, text)) => return parse_chat_response(&text, req),
            }
        }
        Err(last_err
            .expect("retry loop ran at least once")
            .context(format!(
                "http provider: giving up after {} attempt(s)",
                self.cfg.retries + 1
            )))
    }
}

impl Provider for HttpProvider {
    fn label(&self) -> &str {
        "http"
    }

    fn call(&self, req: &GenerationRequest) -> Result<GenerationResponse> {
        let body = self.body_for(req);
        // Hard budget under concurrency: atomically *reserve* the
        // prompt-side estimate before the call (check-then-act would
        // let N racing workers all slip under the line), then swap the
        // reservation for the endpoint's reported usage afterwards.
        // Overshoot is bounded by the in-flight completions, not by N
        // whole calls.
        let reservation = count_tokens(&body);
        if let Some(budget) = self.cfg.token_budget {
            let prior = self.spent.fetch_add(reservation, Ordering::Relaxed);
            if prior >= budget {
                self.spent.fetch_sub(reservation, Ordering::Relaxed);
                return Err(eyre!(
                    "http provider: hard token budget exhausted ({prior}/{budget} tokens); \
                     raise EVO_HTTP_TOKEN_BUDGET to continue"
                ));
            }
        } else {
            self.spent.fetch_add(reservation, Ordering::Relaxed);
        }
        match self.post_with_retries(&body, req) {
            Ok(resp) => {
                self.spent.fetch_add(resp.usage.total(), Ordering::Relaxed);
                self.spent.fetch_sub(reservation, Ordering::Relaxed);
                Ok(resp)
            }
            Err(e) => {
                self.spent.fetch_sub(reservation, Ordering::Relaxed);
                Err(e)
            }
        }
    }
}

fn snippet(text: &str) -> String {
    let t = text.trim();
    match t.char_indices().nth(200) {
        None => t.to_string(),
        Some((i, _)) => format!("{}…", &t[..i]),
    }
}

/// Pull (program text, insight) out of the assistant message: code
/// fences are stripped, the trailing `INSIGHT:` line becomes the
/// solution insight (the solution-insight pair every method requests).
fn split_content(content: &str) -> (String, String) {
    let mut insight = String::new();
    let mut kept: Vec<&str> = Vec::new();
    for line in content.lines() {
        let t = line.trim();
        if t.starts_with("```") {
            continue;
        }
        if let Some(rest) = t.strip_prefix("INSIGHT:") {
            insight = rest.trim().to_string();
            continue;
        }
        kept.push(line);
    }
    if insight.is_empty() {
        insight = "no insight reported".into();
    }
    (kept.join("\n").trim().to_string(), insight)
}

fn parse_chat_response(text: &str, req: &GenerationRequest) -> Result<GenerationResponse> {
    let v = json::parse(text).map_err(|e| eyre!("bad chat-completions JSON: {e}"))?;
    let content = v
        .get("choices")
        .and_then(|c| c.as_arr())
        .and_then(|a| a.first())
        .and_then(|c| c.get("message"))
        .and_then(|m| m.get("content"))
        .and_then(|s| s.as_str())
        .ok_or_else(|| eyre!("chat response missing choices[0].message.content"))?;
    let (out_text, insight) = split_content(content);
    let usage = v.get("usage");
    // Real usage when the endpoint reports it; the 4-chars/token
    // estimate otherwise (same rule the SimLLM uses).
    let prompt_tokens = usage
        .and_then(|u| u.get("prompt_tokens"))
        .and_then(|x| x.as_u64())
        .unwrap_or_else(|| count_tokens(&req.full_prompt()));
    let completion_tokens = usage
        .and_then(|u| u.get("completion_tokens"))
        .and_then(|x| x.as_u64())
        .unwrap_or_else(|| count_tokens(content));
    Ok(GenerationResponse {
        text: out_text,
        insight,
        usage: TokenUsage { prompt_tokens, completion_tokens },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead as _, BufReader, Read as _, Write as _};
    use std::net::TcpListener;

    /// One-shot stub server: serves the canned responses in order (one
    /// connection each) and returns the raw requests it saw.
    fn stub(responses: Vec<String>) -> (String, std::thread::JoinHandle<Vec<String>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut seen = Vec::new();
            for resp in responses {
                let (mut stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut head = String::new();
                let mut content_length = 0usize;
                loop {
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    if line == "\r\n" || line.is_empty() {
                        break;
                    }
                    if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:")
                    {
                        content_length = v.trim().parse().unwrap();
                    }
                    head.push_str(&line);
                }
                let mut body = vec![0u8; content_length];
                reader.read_exact(&mut body).unwrap();
                seen.push(format!("{head}\n{}", String::from_utf8_lossy(&body)));
                stream.write_all(resp.as_bytes()).unwrap();
                stream.flush().ok();
            }
            seen
        });
        (format!("http://{addr}/v1"), handle)
    }

    fn chat_body(content: &str, pt: u64, ct: u64) -> String {
        Json::obj(vec![
            (
                "choices",
                Json::Arr(vec![Json::obj(vec![(
                    "message",
                    Json::obj(vec![
                        ("role", Json::Str("assistant".into())),
                        ("content", Json::Str(content.into())),
                    ]),
                )])]),
            ),
            (
                "usage",
                Json::obj(vec![
                    ("prompt_tokens", Json::Num(pt as f64)),
                    ("completion_tokens", Json::Num(ct as f64)),
                ]),
            ),
        ])
        .to_string()
    }

    fn ok_response(content: &str, pt: u64, ct: u64) -> String {
        let body = chat_body(content, pt, ct);
        format!(
            "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
    }

    fn cfg_for(base_url: &str) -> HttpConfig {
        HttpConfig {
            base_url: base_url.to_string(),
            api_key: Some("test-key".into()),
            retries: 2,
            backoff_ms: 1,
            timeout_ms: 5_000,
            ..HttpConfig::default()
        }
    }

    #[test]
    fn generate_roundtrip_with_auth_and_usage() {
        let content = "kernel matmul_64 { semantics: opt; }\nINSIGHT: wider loads";
        let (url, handle) = stub(vec![ok_response(content, 321, 45)]);
        let provider = HttpProvider::new(cfg_for(&url)).unwrap();
        let req = GenerationRequest::generate("GPT-4.1", "## TASK\nop: matmul_64\n", 42);
        let resp = provider.call(&req).unwrap();
        assert_eq!(resp.text, "kernel matmul_64 { semantics: opt; }");
        assert_eq!(resp.insight, "wider loads");
        assert_eq!(resp.usage.prompt_tokens, 321);
        assert_eq!(resp.usage.completion_tokens, 45);
        assert_eq!(provider.tokens_spent(), 366);
        let seen = handle.join().unwrap();
        assert!(seen[0].contains("POST /v1/chat/completions"), "{}", seen[0]);
        assert!(seen[0].contains("Authorization: Bearer test-key"), "{}", seen[0]);
        assert!(seen[0].contains("op: matmul_64"), "{}", seen[0]);
        assert!(seen[0].contains("\"seed\":42"), "{}", seen[0]);
    }

    #[test]
    fn repair_requests_carry_diagnostics() {
        use crate::guard::{GuardCode, GuardDiagnostic, GuardReport};
        let (url, handle) = stub(vec![ok_response("kernel x { }\nINSIGHT: fixed", 10, 5)]);
        let provider = HttpProvider::new(cfg_for(&url)).unwrap();
        let report = GuardReport {
            diagnostics: vec![GuardDiagnostic {
                code: GuardCode::NonTerminating,
                field: "tile_k".into(),
                message: "tile_k=0 is a zero-step loop construct".into(),
                hint: None,
            }],
        };
        let req = GenerationRequest::repair("GPT-4.1", "kernel x { tile_k: 0; }", &report, 7);
        provider.call(&req).unwrap();
        let seen = handle.join().unwrap();
        assert!(seen[0].contains("DIAGNOSTICS"), "{}", seen[0]);
        assert!(seen[0].contains("tile_k=0"), "{}", seen[0]);
    }

    #[test]
    fn retries_5xx_then_succeeds() {
        let boom = "HTTP/1.1 503 Service Unavailable\r\nContent-Length: 4\r\n\
                    Connection: close\r\n\r\nbusy";
        let (url, handle) = stub(vec![
            boom.to_string(),
            ok_response("kernel y { }\nINSIGHT: ok", 1, 1),
        ]);
        let provider = HttpProvider::new(cfg_for(&url)).unwrap();
        let req = GenerationRequest::generate("GPT-4.1", "p", 1);
        let resp = provider.call(&req).unwrap();
        assert_eq!(resp.text, "kernel y { }");
        assert_eq!(handle.join().unwrap().len(), 2);
    }

    #[test]
    fn bad_request_is_not_retried() {
        let denied = "HTTP/1.1 401 Unauthorized\r\nContent-Length: 6\r\n\
                      Connection: close\r\n\r\ndenied";
        let (url, handle) = stub(vec![denied.to_string()]);
        let provider = HttpProvider::new(cfg_for(&url)).unwrap();
        let req = GenerationRequest::generate("GPT-4.1", "p", 1);
        let err = provider.call(&req).unwrap_err().to_string();
        assert!(err.contains("401"), "{err}");
        assert!(err.contains("not retryable"), "{err}");
        assert_eq!(handle.join().unwrap().len(), 1, "401 must not be retried");
    }

    #[test]
    fn hard_token_budget_cuts_off() {
        let (url, handle) = stub(vec![ok_response("kernel z { }\nINSIGHT: ok", 90, 20)]);
        let mut cfg = cfg_for(&url);
        cfg.token_budget = Some(100);
        let provider = HttpProvider::new(cfg).unwrap();
        let req = GenerationRequest::generate("GPT-4.1", "p", 1);
        provider.call(&req).unwrap(); // 110 tokens spent > 100 budget
        let err = provider.call(&req).unwrap_err().to_string();
        assert!(err.contains("token budget exhausted"), "{err}");
        assert_eq!(handle.join().unwrap().len(), 1, "no request after cutoff");
    }

    #[test]
    fn split_content_handles_fences_and_missing_insight() {
        let (text, insight) =
            split_content("```kernelscript\nkernel a { }\n```\nINSIGHT: tiled better");
        assert_eq!(text, "kernel a { }");
        assert_eq!(insight, "tiled better");
        let (text, insight) = split_content("kernel b { }");
        assert_eq!(text, "kernel b { }");
        assert_eq!(insight, "no insight reported");
    }

    #[test]
    fn config_rejects_https_and_missing_host() {
        assert!(HttpProvider::new(HttpConfig {
            base_url: "https://api.example.com/v1".into(),
            ..HttpConfig::default()
        })
        .is_err());
        assert!(HttpProvider::new(HttpConfig {
            base_url: "http:///v1".into(),
            ..HttpConfig::default()
        })
        .is_err());
    }
}
