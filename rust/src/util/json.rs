//! Minimal JSON reader/writer (the build environment is offline, so no
//! serde). Supports the full JSON grammar we produce and consume:
//! objects, arrays, strings with escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(out, "{}", *n as i64).unwrap();
                } else {
                    write!(out, "{n}").unwrap();
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                write!(out, "\\u{:04x}", c as u32).unwrap();
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = P { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{text}`"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":"x\ny","d":true,"e":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ nl\n".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert!(parse("1.2.3").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("nil").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ✓");
    }
}
