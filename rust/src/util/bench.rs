//! Minimal benchmarking harness (criterion is not available offline).
//!
//! Usage in a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("dsl");
//! b.bench("parse", || dsl::parse(SRC).unwrap());
//! b.report();
//! ```
//! Methodology: warmup, then adaptive batching until the measurement
//! window is filled; reports median / p10 / p90 of per-iteration times
//! across batches, criterion-style.
//!
//! Machine-readable trajectory: when `EVO_BENCH_JSON` names a file,
//! every finished bench appends one JSONL summary line to it, and
//! [`emit_ratio`] appends derived speedup ratios with their targets —
//! `scripts/bench.sh` merges these into the committed `BENCH_<date>.json`
//! artifact (schema in DESIGN.md §14).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::percentile;

/// Append one JSONL line to the `EVO_BENCH_JSON` file, if configured.
/// Advisory: a failed write warns and never fails a bench run.
fn emit_json_line(line: &str) {
    let Ok(path) = std::env::var("EVO_BENCH_JSON") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    use std::io::Write as _;
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        eprintln!("warning: bench: cannot append to EVO_BENCH_JSON={path}: {e}");
    }
}

/// Record a derived speedup ratio (e.g. indexed-open vs full-rescan)
/// in the bench JSON stream, with the acceptance target it is checked
/// against by `scripts/bench_compare.py`.
pub fn emit_ratio(group: &str, name: &str, value: f64, target: f64) {
    println!(
        "{:<40} {value:>10.2}x  (target >= {target}x): {}",
        format!("{group}/{name}"),
        if value >= target { "PASS" } else { "FAIL" }
    );
    emit_json_line(&format!(
        "{{\"type\":\"ratio\",\"group\":{},\"name\":{},\"value\":{value},\"target\":{target}}}",
        crate::util::json::Json::Str(group.to_string()),
        crate::util::json::Json::Str(name.to_string()),
    ));
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub group: String,
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: u64,
}

impl BenchResult {
    fn fmt_dur(d: Duration) -> String {
        let ns = d.as_nanos() as f64;
        if ns < 1_000.0 {
            format!("{ns:.1} ns")
        } else if ns < 1_000_000.0 {
            format!("{:.2} us", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

pub struct Bench {
    group: String,
    warmup: Duration,
    window: Duration,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            warmup: Duration::from_millis(150),
            window: Duration::from_millis(600),
            results: Vec::new(),
        }
    }

    /// Override the measurement window (long end-to-end benches).
    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    /// Benchmark `f`, consuming its output via `black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + batch sizing.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters as f64;
        let batch = ((0.01 / per_iter).ceil() as u64).clamp(1, 1 << 20);

        // Measurement: batches until the window closes.
        let mut samples: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.window || samples.len() < 10 {
            let b0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(b0.elapsed().as_secs_f64() / batch as f64);
            iters += batch;
            if samples.len() >= 5000 {
                break;
            }
        }
        let result = BenchResult {
            group: self.group.clone(),
            name: name.to_string(),
            median: Duration::from_secs_f64(percentile(&samples, 50.0)),
            p10: Duration::from_secs_f64(percentile(&samples, 10.0)),
            p90: Duration::from_secs_f64(percentile(&samples, 90.0)),
            iters,
        };
        println!(
            "{:<40} median {:>12}   [{} .. {}]   ({} iters)",
            format!("{}/{}", self.group, name),
            BenchResult::fmt_dur(result.median),
            BenchResult::fmt_dur(result.p10),
            BenchResult::fmt_dur(result.p90),
            result.iters
        );
        emit_json_line(&format!(
            "{{\"type\":\"bench\",\"group\":{},\"name\":{},\"median_ns\":{},\
             \"p10_ns\":{},\"p90_ns\":{},\"iters\":{}}}",
            crate::util::json::Json::Str(result.group.clone()),
            crate::util::json::Json::Str(result.name.clone()),
            result.median.as_nanos(),
            result.p10.as_nanos(),
            result.p90.as_nanos(),
            result.iters
        ));
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a compact summary (already printed per-bench; this adds a
    /// trailer useful for `tee`d logs).
    pub fn report(&self) {
        println!(
            "# group `{}`: {} benchmarks",
            self.group,
            self.results.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").with_window(Duration::from_millis(30));
        let r = b.bench("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7));
        assert!(r.median.as_nanos() < 1_000_000);
        assert!(r.iters > 0);
        b.report();
    }

    #[test]
    fn ordering_sane() {
        let mut b = Bench::new("test").with_window(Duration::from_millis(30));
        let fast = b.bench("fast", || black_box(1u64) + 1).median;
        let slow = b
            .bench("slow", || {
                (0..black_box(5000u64)).fold(0u64, |a, x| a.wrapping_add(x.wrapping_mul(x) ^ a))
            })
            .median;
        assert!(slow > fast);
    }
}
