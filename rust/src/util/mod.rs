//! Small shared utilities: deterministic RNG and statistics.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, median, pearson, percentile};
