//! Small shared utilities: deterministic RNG, statistics, a serde-free
//! JSON reader/writer, the shared hand-rolled HTTP/1.1 wire layer, and
//! the offline criterion-style bench harness.

pub mod bench;
pub mod httpwire;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, median, pearson, percentile};

/// Repair a JSONL journal whose writer was killed mid-append: every
/// well-formed line ends in `\n`, so any bytes after the final newline
/// are a torn partial write. Truncates them (the whole file, when it
/// contains no newline at all) so re-opening for append cannot
/// concatenate a fresh record onto the torn tail and turn a
/// recoverable loss into interior corruption. Returns the number of
/// bytes trimmed; missing file is a no-op.
///
/// The scan runs *backwards* from the end in fixed-size `pread`
/// chunks: an intact journal (the overwhelmingly common case) proves
/// itself clean from its final byte alone, and even a torn one only
/// reads back to the last newline — never the whole file, which used
/// to make every open of a multi-megabyte store O(file) before any
/// indexing could help (DESIGN.md §14).
pub fn truncate_torn_tail(path: &std::path::Path) -> std::io::Result<u64> {
    use std::os::unix::fs::FileExt as _;

    let Ok(meta) = std::fs::metadata(path) else {
        return Ok(0);
    };
    let len = meta.len();
    if len == 0 {
        return Ok(0);
    }
    const CHUNK: u64 = 8 * 1024;
    let f = std::fs::File::open(path)?;
    let mut buf = [0u8; CHUNK as usize];
    // End (exclusive) of the last complete line: the byte after the
    // final `\n`, or 0 when the file holds none.
    let mut keep = 0u64;
    let mut hi = len;
    while hi > 0 {
        let lo = hi.saturating_sub(CHUNK);
        let chunk = &mut buf[..(hi - lo) as usize];
        f.read_exact_at(chunk, lo)?;
        if let Some(pos) = chunk.iter().rposition(|&b| b == b'\n') {
            keep = lo + pos as u64 + 1;
            break;
        }
        hi = lo;
    }
    drop(f);
    let torn = len - keep;
    if torn > 0 {
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(keep)?;
    }
    Ok(torn)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torn_tail_truncation() {
        let dir = std::env::temp_dir().join(format!("evo_torn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("j.jsonl");
        // Missing file: no-op.
        assert_eq!(truncate_torn_tail(&p).unwrap(), 0);
        // Clean journal: untouched.
        std::fs::write(&p, "{\"a\":1}\n{\"b\":2}\n").unwrap();
        assert_eq!(truncate_torn_tail(&p).unwrap(), 0);
        // Torn tail: trimmed back to the last complete line.
        std::fs::write(&p, "{\"a\":1}\n{\"b\":2}\n{\"c\":").unwrap();
        assert_eq!(truncate_torn_tail(&p).unwrap(), 5);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"a\":1}\n{\"b\":2}\n");
        // No newline at all: the whole file is one torn line.
        std::fs::write(&p, "{\"a\"").unwrap();
        assert_eq!(truncate_torn_tail(&p).unwrap(), 4);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 0);
        std::fs::remove_dir_all(dir).ok();
    }
}
