//! Hand-rolled HTTP/1.1 wire layer shared by the `http-provider`
//! client ([`crate::llm::http`]) and the distributed campaign plane
//! (`campaign serve` / `campaign work`, DESIGN.md §15).
//!
//! The build environment is offline — no HTTP crates in the pre-seeded
//! cache — so both halves live on `std::net`:
//!
//! * **response parsing** ([`parse_http_response`]): status line,
//!   Content-Length and chunked bodies, `Connection: close` semantics
//!   (EOF bounds everything else). Extracted verbatim from the
//!   provider client so the coordinator/worker plane and the LLM
//!   backend share one implementation;
//! * **client helper** ([`request_json`] over a [`Url`]): one request
//!   per TCP connection, JSON in / JSON out — exactly what a
//!   control-plane RPC needs and nothing more;
//! * **server** ([`Server`]): a single accept-loop thread answering
//!   `Content-Length`-framed requests serially. Serial is a feature:
//!   the campaign coordinator's handler mutates one shared grid state
//!   behind a mutex anyway, so per-connection threads would only add
//!   interleavings without adding throughput at control-plane rates
//!   (a few requests per trial boundary).
//!
//! Plain `http://` only; front a TLS endpoint with a local gateway.

use std::io::{BufRead as _, BufReader, Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs as _};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::json::Json;
use crate::{eyre, Result, WrapErr as _};

// ---------------------------------------------------------------------
// Response parsing (shared with the http-provider client)

/// Split a raw HTTP/1.1 response into (status, body text). Handles
/// Content-Length and chunked bodies (Connection: close means EOF
/// bounds everything else).
pub fn parse_http_response(raw: &[u8]) -> Result<(u16, String)> {
    let sep = find_subslice(raw, b"\r\n\r\n")
        .ok_or_else(|| eyre!("malformed HTTP response: no header/body separator"))?;
    let head = String::from_utf8_lossy(&raw[..sep]);
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| eyre!("malformed HTTP status line: `{status_line}`"))?;
    let mut chunked = false;
    let mut content_length: Option<usize> = None;
    for line in lines {
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("transfer-encoding:") {
            chunked = v.trim().contains("chunked");
        } else if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().ok();
        }
    }
    let body = &raw[sep + 4..];
    let body = if chunked {
        dechunk(body)?
    } else if let Some(len) = content_length {
        body.get(..len.min(body.len())).unwrap_or(body).to_vec()
    } else {
        body.to_vec()
    };
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn dechunk(mut body: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let pos = find_subslice(body, b"\r\n")
            .ok_or_else(|| eyre!("malformed chunked body: no size line"))?;
        let size_str = std::str::from_utf8(&body[..pos]).unwrap_or("");
        let size = usize::from_str_radix(
            size_str.split(';').next().unwrap_or("").trim(),
            16,
        )
        .map_err(|_| eyre!("malformed chunk size `{size_str}`"))?;
        body = &body[pos + 2..];
        if size == 0 {
            return Ok(out);
        }
        if body.len() < size + 2 {
            return Err(eyre!("truncated chunked body"));
        }
        out.extend_from_slice(&body[..size]);
        body = &body[size + 2..];
    }
}

// ---------------------------------------------------------------------
// Client

/// A split `http://host[:port]/path` base URL (the same shape the
/// provider client parses for `EVO_HTTP_BASE_URL`).
#[derive(Debug, Clone)]
pub struct Url {
    /// Host header value (host or host:port as written in the URL).
    pub host: String,
    /// `host:port` used for the TCP connect.
    pub authority: String,
    /// URL path prefix (e.g. `/v1`), no trailing slash.
    pub path: String,
}

/// Parse a plain-http base URL into its connect/Host/path parts.
pub fn split_url(url: &str) -> Result<Url> {
    let rest = url.strip_prefix("http://").ok_or_else(|| {
        eyre!("URL must be plain http:// (the offline client has no TLS): `{url}`")
    })?;
    let (hostport, path) = match rest.find('/') {
        Some(i) => (&rest[..i], rest[i..].trim_end_matches('/')),
        None => (rest, ""),
    };
    if hostport.is_empty() {
        return Err(eyre!("URL has no host: `{url}`"));
    }
    let authority = if hostport.contains(':') {
        hostport.to_string()
    } else {
        format!("{hostport}:80")
    };
    Ok(Url {
        host: hostport.to_string(),
        authority,
        path: path.to_string(),
    })
}

/// One JSON-over-HTTP exchange: connect, send `method` to
/// `base.path + path` with `body`, read to EOF (`Connection: close`),
/// return (status, body text). Each call is its own TCP connection —
/// the simplest framing that cannot desynchronize.
pub fn request_json(
    base: &Url,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> Result<(u16, String)> {
    let addr = base
        .authority
        .to_socket_addrs()
        .with_context(|| format!("resolving {}", base.authority))?
        .next()
        .ok_or_else(|| eyre!("no address for {}", base.authority))?;
    let mut stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("connecting to {}", base.authority))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let head = format!(
        "{method} {}{path} HTTP/1.1\r\nHost: {}\r\n\
         Content-Type: application/json\r\nAccept: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        base.path,
        base.host,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream
        .read_to_end(&mut raw)
        .with_context(|| format!("reading {method} {path} response"))?;
    parse_http_response(&raw)
}

// ---------------------------------------------------------------------
// Server

/// One parsed inbound request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// One outbound response: status code, content type, body. Most
/// control-plane endpoints answer JSON ([`Response::json`]); the
/// Prometheus-style `/metrics` scrape answers plain text
/// ([`Response::text`]).
#[derive(Debug, Clone)]
pub struct Response {
    pub code: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(code: u16, body: Json) -> Self {
        Self {
            code,
            content_type: "application/json",
            body: body.to_string(),
        }
    }

    pub fn text(code: u16, body: impl Into<String>) -> Self {
        Self {
            code,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into(),
        }
    }
}

/// Request handler: returns the full [`Response`].
pub type Handler = dyn Fn(&Request) -> Response + Send + Sync;

/// Minimal `std::net` HTTP/1.1 server: a single accept-loop thread
/// serving `Content-Length`-framed JSON requests one connection at a
/// time, `Connection: close` per exchange. A panicking handler answers
/// 500 instead of killing the accept loop.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start serving `handler` on a background thread.
    pub fn bind(addr: &str, handler: Arc<Handler>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match conn {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                if let Err(e) = serve_conn(stream, handler.as_ref()) {
                    eprintln!("warning: httpwire: dropped connection: {e:#}");
                }
            }
        });
        Ok(Self {
            addr: local,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound socket address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `http://host:port` base URL for [`request_json`] clients.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting and join the accept thread. Idempotent; also
    /// runs on drop.
    pub fn shutdown(&mut self) {
        if self.thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept() the loop is parked in.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        500 => "Internal Server Error",
        _ => "Status",
    }
}

fn serve_conn(stream: TcpStream, handler: &Handler) -> Result<()> {
    let timeout = Some(Duration::from_secs(30));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(eyre!("malformed request line: `{}`", request_line.trim_end()));
    }
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line == "\r\n" || line == "\n" || line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v
                .trim()
                .parse()
                .map_err(|_| eyre!("bad Content-Length `{}`", v.trim()))?;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading request body")?;
    let req = Request {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    };
    let resp = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        handler(&req)
    })) {
        Ok(resp) => resp,
        Err(_) => Response::json(
            500,
            Json::obj(vec![("error", Json::Str("handler panicked".into()))]),
        ),
    };
    let (code, body) = (resp.code, resp.body);
    let mut stream = stream;
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        resp.content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_responses_are_decoded() {
        let body = r#"{"choices":[{"message":{"content":"kernel c { }"}}]}"#;
        let (a, b) = body.split_at(body.len() / 2);
        let raw = format!(
            "HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n\
             {:x}\r\n{a}\r\n{:x}\r\n{b}\r\n0\r\n\r\n",
            a.len(),
            b.len()
        );
        let (status, text) = parse_http_response(raw.as_bytes()).unwrap();
        assert_eq!(status, 200);
        assert_eq!(text, body);
    }

    #[test]
    fn split_url_parses_ports_and_paths() {
        let u = split_url("http://127.0.0.1:8000/v1").unwrap();
        assert_eq!(u.authority, "127.0.0.1:8000");
        assert_eq!(u.host, "127.0.0.1:8000");
        assert_eq!(u.path, "/v1");
        let u = split_url("http://example.com").unwrap();
        assert_eq!(u.authority, "example.com:80");
        assert_eq!(u.path, "");
        assert!(split_url("https://x/v1").is_err());
        assert!(split_url("http:///v1").is_err());
    }

    #[test]
    fn server_roundtrip_and_routing() {
        let handler: Arc<Handler> = Arc::new(|req: &Request| {
            if req.path == "/v1/echo" && req.method == "POST" {
                Response::json(
                    200,
                    Json::obj(vec![("got", Json::Str(req.body.clone()))]),
                )
            } else if req.path == "/v1/plain" {
                Response::text(200, "metric_like 1\n")
            } else {
                Response::json(404, Json::obj(vec![("error", Json::Str("no route".into()))]))
            }
        });
        let mut server = Server::bind("127.0.0.1:0", handler).unwrap();
        let base = split_url(&server.url()).unwrap();
        let timeout = Duration::from_secs(5);
        let (code, text) =
            request_json(&base, "POST", "/v1/echo", "hello wire", timeout).unwrap();
        assert_eq!(code, 200);
        assert_eq!(text, "{\"got\":\"hello wire\"}");
        // Plain-text responses ride the same wire (the /metrics shape).
        let (code, text) = request_json(&base, "GET", "/v1/plain", "", timeout).unwrap();
        assert_eq!(code, 200);
        assert_eq!(text, "metric_like 1\n");
        let (code, _) = request_json(&base, "GET", "/nope", "", timeout).unwrap();
        assert_eq!(code, 404);
        // Serial but multi-request: a second exchange still works.
        let (code, _) =
            request_json(&base, "POST", "/v1/echo", "second", timeout).unwrap();
        assert_eq!(code, 200);
        server.shutdown();
        // Shutdown is effective: new connections are refused or hang up.
        assert!(request_json(&base, "POST", "/v1/echo", "x", timeout).is_err());
    }

    #[test]
    fn handler_panic_answers_500() {
        let handler: Arc<Handler> = Arc::new(|req: &Request| {
            if req.path == "/boom" {
                panic!("kaboom");
            }
            Response::json(200, Json::obj(vec![("ok", Json::Bool(true))]))
        });
        let mut server = Server::bind("127.0.0.1:0", handler).unwrap();
        let base = split_url(&server.url()).unwrap();
        let timeout = Duration::from_secs(5);
        let (code, text) = request_json(&base, "POST", "/boom", "", timeout).unwrap();
        assert_eq!(code, 500);
        assert!(text.contains("panicked"), "{text}");
        // The accept loop survived the panic.
        let (code, _) = request_json(&base, "GET", "/fine", "", timeout).unwrap();
        assert_eq!(code, 200);
        server.shutdown();
    }
}
