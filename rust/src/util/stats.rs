//! Statistics helpers used by the evaluation pipeline and metrics
//! (median speedups, Pass@1 rates, the Figure-9 correlation).

/// Median of a slice (copies; NaNs are ignored).
pub fn median(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient (the paper's Figure-9 r≈0.9).
///
/// Degenerate inputs — mismatched lengths, empty slices, or a
/// constant series (zero variance, for which r is mathematically
/// undefined) — return NaN rather than panicking or clamping to ~0.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.is_empty() {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let denom = (sxx * syy).sqrt();
    if denom == 0.0 {
        return f64::NAN;
    }
    sxy / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn median_ignores_nan() {
        assert_eq!(median(&[1.0, f64::NAN, 3.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_input_is_nan() {
        // Zero variance on either side: r is undefined, not ~0.
        assert!(pearson(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]).is_nan());
        assert!(pearson(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0]).is_nan());
        assert!(pearson(&[2.0, 2.0], &[2.0, 2.0]).is_nan());
    }

    #[test]
    fn pearson_length_mismatch_is_nan_not_panic() {
        assert!(pearson(&[1.0, 2.0], &[1.0, 2.0, 3.0]).is_nan());
        assert!(pearson(&[], &[1.0]).is_nan());
    }

    #[test]
    fn pearson_empty_is_nan() {
        assert!(pearson(&[], &[]).is_nan());
    }
}
