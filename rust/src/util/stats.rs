//! Statistics helpers used by the evaluation pipeline and metrics
//! (median speedups, Pass@1 rates, the Figure-9 correlation).

/// Median of a slice (copies; NaNs are ignored).
pub fn median(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient (the paper's Figure-9 r≈0.9).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let _ = n;
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn median_ignores_nan() {
        assert_eq!(median(&[1.0, f64::NAN, 3.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }
}
