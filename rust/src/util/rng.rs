//! Deterministic, dependency-free PRNG (xoshiro256** seeded by
//! SplitMix64). Every stochastic component of the system (input
//! generation, SimLLM sampling, measurement noise) derives its stream
//! from explicit seeds so campaigns are exactly reproducible — the
//! paper's "three independent runs" are three seeds.

/// xoshiro256** — public-domain generator by Blackman & Vigna.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Self {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    /// The u64 seed [`Rng::derive`] would expand for `label` — the
    /// whole child stream in one word. This is the provider seam
    /// (DESIGN.md §12): a [`crate::llm::GenerationRequest`] carries
    /// this seed, and `Rng::new(seed)` on the other side reproduces
    /// the exact stream `derive` would have handed out in-process.
    pub fn derive_seed(&self, label: &str) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h ^ self.s[0] ^ self.s[2].rotate_left(17)
    }

    /// Derive a child stream from a label — used to give every
    /// (op, trial, purpose) tuple its own independent stream.
    pub fn derive(&self, label: &str) -> Rng {
        Rng::new(self.derive_seed(label))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[1].wrapping_mul(5)).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform pick from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal multiplicative noise factor with sigma (of log).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_seed_reconstructs_the_derived_stream() {
        // The provider-seam contract: `derive(label)` and
        // `Rng::new(derive_seed(label))` are the same stream, so a
        // seed shipped in a GenerationRequest reproduces exactly what
        // the in-process derivation would have produced.
        let base = Rng::new(0xDEAD_BEEF).derive("session/x");
        for label in ["llm/0", "repair/3/1", ""] {
            let mut a = base.derive(label);
            let mut b = Rng::new(base.derive_seed(label));
            for _ in 0..32 {
                assert_eq!(a.next_u64(), b.next_u64(), "label {label:?}");
            }
        }
    }

    #[test]
    fn derive_independent() {
        let r = Rng::new(7);
        let mut a = r.derive("alpha");
        let mut b = r.derive("beta");
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
        // re-derivation reproduces the stream
        let mut a2 = r.derive("alpha");
        assert_eq!(xs[0], a2.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f32_range(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&y));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
