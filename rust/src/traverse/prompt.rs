//! Prompt engineering layer: renders a [`Guidance`] into the text the
//! LLM sees. Section markers are a stable mini-protocol (`## TASK`,
//! `## CURRENT KERNEL`, ...) — the SimLLM genuinely parses this text,
//! so information the guiding layer omits is *really* unavailable to
//! the generator, and style choices *really* cost tokens.

use super::{Guidance, GuidanceConfig, PromptStyle};
use crate::tasks::category_name;

/// Verbose-style boilerplate (the AI-CUDA-Engineer-like prompt mass the
/// paper's Figure 4 charges against token budgets).
const VERBOSE_PREAMBLE: &str = "\
You are an elite GPU performance engineer participating in an automated \
kernel optimization campaign. Your objective is to produce the fastest \
functionally-correct kernel for the operation described below. Consider \
memory coalescing, shared-memory staging and bank conflicts, register \
pressure and spilling, occupancy (threads per block, registers per \
thread, shared memory per block), software pipelining (double and \
triple buffering), loop unrolling, vectorized global loads (float2 / \
float4 packing), instruction-level parallelism, epilogue fusion to \
eliminate extra kernel launches and intermediate global-memory round \
trips, wave quantization effects, and L2 cache behaviour. The target \
device is an NVIDIA RTX 4090 (AD102, sm_89): 128 SMs, 16384 CUDA cores, \
24 GB GDDR6X at 1008 GB/s, 100 KB shared memory per SM, 65536 registers \
per SM, max 1536 resident threads per SM. Respond with a complete \
kernel definition in the KernelScript language and a one-line insight \
explaining your key optimization decision.\n\n";

const VERBOSE_ENSEMBLE: &str = "\
Consider three alternative optimization directions before committing: \
(a) improve data reuse through larger staged tiles, (b) improve \
bandwidth through wider vector loads and better layout, (c) improve \
latency hiding through pipelining and occupancy. Evaluate the trade-offs \
against the profiling data and historical solutions above, then emit \
the single kernel you judge fastest.\n\n";

/// Render the prompt for one trial.
pub fn render(cfg: &GuidanceConfig, g: &Guidance) -> String {
    let mut out = String::with_capacity(1024);

    if cfg.style == PromptStyle::Verbose {
        out.push_str(VERBOSE_PREAMBLE);
    }

    // -- I1: task context (always present; Table 2 "all methods
    // incorporate basic task context").
    out.push_str("## TASK\n");
    out.push_str(&format!("op: {}\n", g.task.name));
    out.push_str(&format!(
        "category: {} ({})\n",
        g.task.category,
        category_name(g.task.category)
    ));
    out.push_str(&format!("flops: {:.3e}\n", g.task.flops));
    out.push_str(&format!("bytes: {:.3e}\n", g.task.bytes_moved));
    out.push_str(&format!("baseline_time_us: {:.2}\n", g.baseline_us));
    match cfg.style {
        PromptStyle::Minimal => {
            out.push_str("objective: minimize time; must compile and match reference\n");
        }
        _ => {
            out.push_str(
                "objective: minimize kernel execution time\nconstraints: the kernel must \
                 compile (resource limits: 99KB shared memory per block, 255 registers per \
                 thread, threads per block a multiple of 32 up to 1024) and must produce \
                 output matching the reference implementation on all test cases\n",
            );
        }
    }
    out.push('\n');

    if let Some(parent) = g.parent {
        out.push_str("## CURRENT KERNEL\n");
        out.push_str(&format!("speedup: {:.3}\n", parent.speedup));
        out.push_str(&format!("valid: {}\n", parent.valid()));
        out.push_str(&parent.src);
        if !parent.src.ends_with('\n') {
            out.push('\n');
        }
        out.push('\n');
    }

    // -- I2: historical solutions.
    if cfg.n_history > 0 && !g.history.is_empty() {
        out.push_str("## HISTORY\n");
        for (i, h) in g.history.iter().take(cfg.n_history).enumerate() {
            out.push_str(&format!("### solution {} (speedup {:.3})\n", i + 1, h.speedup));
            out.push_str(&h.src);
            if !h.src.ends_with('\n') {
                out.push('\n');
            }
        }
        out.push('\n');
    }

    // -- I3: optimization insights.
    if cfg.n_insights > 0 && !g.insights.is_empty() {
        out.push_str("## INSIGHTS\n");
        for ins in g.insights.iter().take(cfg.n_insights) {
            out.push_str(&format!("- {} [{:+.2}x]\n", ins.text, ins.delta));
        }
        out.push('\n');
    }

    if cfg.profiling {
        if let Some(p) = &g.profiling {
            out.push_str("## PROFILING\n");
            out.push_str(p);
            out.push('\n');
            out.push('\n');
        }
    }

    if cfg.style == PromptStyle::Verbose {
        out.push_str(VERBOSE_ENSEMBLE);
    }

    out.push_str("## INSTRUCTION\n");
    out.push_str(&g.instruction);
    out.push('\n');
    out
}

/// Profiling feedback line for a timing (what the evaluator would print
/// from nsight-style counters).
pub fn profiling_line(t: &crate::costmodel::Timing) -> String {
    format!(
        "bound: {:?}; occupancy: {:.2}; eff_bw: {:.2}; eff_compute: {:.2}; \
         traffic_bytes: {:.3e}; launches: {}",
        t.bound, t.occupancy, t.eff_bw, t.eff_compute, t.traffic, t.launches
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::population::Candidate;
    use crate::tasks::{ArgSpec, OpTask};

    fn task() -> OpTask {
        OpTask {
            name: "matmul_64".into(),
            category: 1,
            family: "matmul".into(),
            args: vec![ArgSpec { shape: vec![64, 64], gen: "uniform".into() }],
            out_shape: vec![64, 64],
            flops: 5.24e5,
            bytes_moved: 4.9e4,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.85,
            algo_penalty: 1.0,
            atol: 1e-4,
            rtol: 1e-3,
            artifacts: Default::default(),
        }
    }

    fn cand() -> Candidate {
        Candidate {
            src: crate::dsl::print(&crate::dsl::KernelSpec::baseline("matmul_64")),
            spec: Some(crate::dsl::KernelSpec::baseline("matmul_64")),
            compiled: true,
            correct: true,
            speedup: 1.7,
            pytorch_speedup: 0.9,
            true_speedup: 1.7,
            true_pytorch_speedup: 0.9,
            insight: None,
            trial: 3,
        }
    }

    #[test]
    fn sections_reflect_config() {
        let t = task();
        let c = cand();
        let ins = super::super::InsightRecord { text: "raise tile_n to 64".into(), delta: 0.4 };
        let g = Guidance {
            task: &t,
            baseline_us: 12.0,
            parent: Some(&c),
            history: vec![&c],
            insights: vec![&ins],
            profiling: Some("bound: Memory".into()),
            instruction: "Improve the current kernel.".into(),
        };
        let free = render(&GuidanceConfig::free(), &g);
        assert!(free.contains("## TASK"));
        assert!(free.contains("## CURRENT KERNEL"));
        assert!(!free.contains("## HISTORY"));
        assert!(!free.contains("## INSIGHTS"));
        assert!(!free.contains("## PROFILING"));

        let full = render(&GuidanceConfig::full(), &g);
        assert!(full.contains("## HISTORY"));
        assert!(full.contains("## INSIGHTS"));
        assert!(full.contains("raise tile_n"));

        let ai = render(&GuidanceConfig::aicuda(), &g);
        assert!(ai.contains("## PROFILING"));
        assert!(ai.len() > full.len(), "verbose should cost more tokens");
    }

    #[test]
    fn minimal_is_cheapest() {
        let t = task();
        let g = Guidance {
            task: &t,
            baseline_us: 1.0,
            parent: None,
            history: vec![],
            insights: vec![],
            profiling: None,
            instruction: "Write a kernel.".into(),
        };
        let free = render(&GuidanceConfig::free(), &g).len();
        let ai = render(&GuidanceConfig::aicuda(), &g).len();
        assert!(ai > 3 * free);
    }
}
