//! Traverse techniques — the paper's two-layer design (§4.1.1).
//!
//! * **Solution guiding layer** ([`GuidanceConfig`], [`Guidance`]):
//!   *what* closed-world information enters the prompt — I1 task
//!   context, I2 historical high-quality solutions, I3 optimization
//!   insights (plus the AI-CUDA-Engineer-style profiling extra).
//! * **Prompt engineering layer** ([`prompt`]): *how* that strategy is
//!   communicated — section structure, verbosity, formatting.
//!
//! The separation is enforced by the types: methods choose a
//! `GuidanceConfig` (strategy); only `prompt::render` decides the text.

pub mod prompt;

use crate::population::Candidate;
use crate::tasks::OpTask;

/// Prompt-engineering-layer style knob. `Verbose` reproduces the
//  AI-CUDA-Engineer behaviour the paper criticizes: heavyweight prompts
/// whose token cost is not repaid by speedup (Figure 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptStyle {
    /// Terse section headers, no prose (EvoEngineer-Free).
    Minimal,
    /// Structured sections with brief guidance (EvoEngineer-Insight/Full).
    Structured,
    /// Long boilerplate, ensemble directives, embedded documentation
    /// (AI CUDA Engineer replication).
    Verbose,
}

/// Solution-guiding-layer configuration: which information types are
/// used (paper Table 3 — the EvoEngineer configuration matrix).
#[derive(Debug, Clone, Copy)]
pub struct GuidanceConfig {
    /// I2: number of historical solutions to include (0 = unused).
    pub n_history: usize,
    /// I3: number of optimization insights to include (0 = unused).
    pub n_insights: usize,
    /// Include profiling feedback (AI CUDA Engineer extra).
    pub profiling: bool,
    /// Prompt engineering layer selection.
    pub style: PromptStyle,
}

impl GuidanceConfig {
    /// EvoEngineer-Free: task context only (Table 3 row 1).
    pub fn free() -> Self {
        Self { n_history: 0, n_insights: 0, profiling: false, style: PromptStyle::Minimal }
    }

    /// EvoEngineer-Insight: task context + insights (Table 3 row 2).
    pub fn insight() -> Self {
        Self { n_history: 0, n_insights: 4, profiling: false, style: PromptStyle::Structured }
    }

    /// EvoEngineer-Full: history + insights (Table 3 row 4).
    pub fn full() -> Self {
        Self { n_history: 3, n_insights: 4, profiling: false, style: PromptStyle::Structured }
    }

    /// EoH: 2-3 historical solutions, insight pairs generated but not
    /// explicitly leveraged (Table 2).
    pub fn eoh() -> Self {
        Self { n_history: 3, n_insights: 0, profiling: false, style: PromptStyle::Structured }
    }

    /// FunSearch: minimal — two historical solutions, nothing else.
    pub fn funsearch() -> Self {
        Self { n_history: 2, n_insights: 0, profiling: false, style: PromptStyle::Minimal }
    }

    /// AI CUDA Engineer optimize stage: >5 solutions, profiling,
    /// verbose ensemble prompting (Table 2 + §A.8).
    pub fn aicuda() -> Self {
        Self { n_history: 5, n_insights: 0, profiling: true, style: PromptStyle::Verbose }
    }
}

/// One insight with its observed effect (the method records the
/// speedup delta when the insight's candidate was evaluated — this is
/// what "explicitly leveraging" insights means for EvoEngineer, vs
/// EoH/AI-CUDA-E which generate but ignore them, Table 2 footnote).
#[derive(Debug, Clone)]
pub struct InsightRecord {
    pub text: String,
    pub delta: f64,
}

/// Everything the solution guiding layer assembled for one trial.
#[derive(Debug, Clone)]
pub struct Guidance<'a> {
    pub task: &'a OpTask,
    /// Baseline kernel time in microseconds (task context detail).
    pub baseline_us: f64,
    /// The solution to improve upon (absent for from-scratch trials).
    pub parent: Option<&'a Candidate>,
    /// I2: historical high-quality solutions, best first.
    pub history: Vec<&'a Candidate>,
    /// I3: optimization insights, most useful first.
    pub insights: Vec<&'a InsightRecord>,
    /// Profiling feedback line for the parent (if enabled & available).
    pub profiling: Option<String>,
    /// Operator-specific directive (EoH E1/E2/M1/M2, stage names...).
    pub instruction: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_match_table3() {
        let free = GuidanceConfig::free();
        assert_eq!((free.n_history, free.n_insights), (0, 0));
        let insight = GuidanceConfig::insight();
        assert_eq!(insight.n_history, 0);
        assert!(insight.n_insights > 0);
        let full = GuidanceConfig::full();
        assert!(full.n_history > 0 && full.n_insights > 0);
        let ai = GuidanceConfig::aicuda();
        assert!(ai.n_history >= 5 && ai.profiling);
        assert_eq!(ai.style, PromptStyle::Verbose);
    }
}
