//! Profile-guided feedback subsystem (DESIGN.md §17): closes the
//! generate → measure → re-prompt loop the paper's own prompt template
//! centers (its `prof_string` feeds the incumbent's runtime and
//! profiling counters back into every generation request).
//!
//! Two cooperating pieces:
//!
//! * [`ProfileReport`] — a per-candidate performance profile assembled
//!   from the evaluator's [`EvalOutcome`] (noise-free timing, roofline
//!   bound, occupancy, traffic) plus guard diagnostics for rejected
//!   candidates, rendered deterministically into the structured
//!   `## PERFORMANCE PROFILE` prompt section. Every number in the
//!   rendering derives from journaled eval records, so a replayed
//!   campaign re-renders byte-identical prompts with zero live calls.
//! * [`Goal`] / [`Objective`] — the `--goal speedup|memory|balanced`
//!   axis: a multi-objective fitness scalar used for best-candidate
//!   selection, archive ranking and bandit rewards, plus a one-line
//!   prompt emphasis. `Goal::Speedup` is the identity fitness, so the
//!   default configuration is bit-for-bit the historical behaviour.
//!
//! Determinism contract: [`ProfileReport::render`] uses fixed-width
//! formatting of noise-free quantities only (`true_speedup`, the
//! stored [`Timing`]), never the measured (noise-bearing) values —
//! same record, same section bytes, on every replay.

use crate::costmodel::{Gpu, Timing};
use crate::evals::EvalOutcome;
use crate::tasks::OpTask;

/// The search objective (`--goal`). The snippet-3 `goal` knob: same
/// ops, same provider, materially different search behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Goal {
    /// Maximize measured speedup (the paper's default objective).
    #[default]
    Speedup,
    /// Prefer candidates that keep DRAM pressure low: speedup scaled
    /// down by the memory-bound fraction of the modeled runtime.
    Memory,
    /// The validity/performance balance the paper centers: speedup
    /// scaled by achieved hardware utilization.
    Balanced,
}

/// A search objective: prompt emphasis plus the fitness scalar used
/// for archive ranking, best-candidate selection and bandit rewards.
pub trait Objective {
    /// Stable objective name (the `--goal` token).
    fn name(&self) -> &'static str;

    /// One-line prompt emphasis rendered under `## OPTIMIZATION GOAL`.
    fn emphasis(&self) -> &'static str;

    /// Fitness scalar for a candidate with measured `speedup` and the
    /// evaluator's noise-free `timing` (absent for candidates whose
    /// timing was never journaled, e.g. archive entries re-seeded from
    /// a checkpoint). MUST be the identity on `speedup` for the
    /// default objective — archive and best-candidate comparisons are
    /// bit-identical to pre-feedback behaviour under `--goal speedup`.
    fn fitness(&self, speedup: f64, timing: Option<&Timing>) -> f64;
}

impl Objective for Goal {
    fn name(&self) -> &'static str {
        match self {
            Goal::Speedup => "speedup",
            Goal::Memory => "memory",
            Goal::Balanced => "balanced",
        }
    }

    fn emphasis(&self) -> &'static str {
        match self {
            Goal::Speedup => {
                "Minimize kernel execution time above all else."
            }
            Goal::Memory => {
                "Minimize DRAM traffic and memory pressure: prefer staged reuse, \
                 fused epilogues and narrower working sets, even at a small cost \
                 in raw execution time."
            }
            Goal::Balanced => {
                "Balance execution time against hardware utilization: prefer \
                 schedules that keep occupancy and achieved bandwidth/compute \
                 efficiency high while still reducing time."
            }
        }
    }

    fn fitness(&self, speedup: f64, timing: Option<&Timing>) -> f64 {
        match (self, timing) {
            // Identity: `--goal speedup` comparisons are bitwise the
            // historical `speedup > best.speedup`.
            (Goal::Speedup, _) => speedup,
            (Goal::Memory, Some(t)) => {
                // Memory-bound fraction of the modeled runtime; a
                // kernel that shifted work off DRAM ranks above an
                // equally-fast one that saturates it.
                let mem_fraction = if t.time > 0.0 {
                    (t.t_mem / t.time).clamp(0.0, 1.0)
                } else {
                    1.0
                };
                speedup / (1.0 + mem_fraction)
            }
            (Goal::Balanced, Some(t)) => {
                let utilization = t.eff_bw.max(t.eff_compute).clamp(0.0, 1.0);
                speedup * (0.75 + 0.25 * utilization)
            }
            // No journaled timing (checkpoint-reseeded archive entry):
            // fall back to the raw speedup.
            (_, None) => speedup,
        }
    }
}

/// Parsed `--goal` configuration: the objective plus whether the
/// rendered performance profile is attached to generation requests.
/// `memory` and `balanced` imply the profile (the objective is defined
/// in terms of it); `speedup+profile` turns the profile on while
/// keeping the default fitness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FeedbackConfig {
    pub goal: Goal,
    /// Attach the rendered `## PERFORMANCE PROFILE` section to every
    /// generation request that has a measured predecessor.
    pub profile: bool,
}

impl FeedbackConfig {
    /// Parse a `--goal` CLI value:
    /// `speedup` | `speedup+profile` | `memory` | `balanced`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "" | "speedup" => Ok(FeedbackConfig { goal: Goal::Speedup, profile: false }),
            "speedup+profile" => Ok(FeedbackConfig { goal: Goal::Speedup, profile: true }),
            "memory" => Ok(FeedbackConfig { goal: Goal::Memory, profile: true }),
            "balanced" => Ok(FeedbackConfig { goal: Goal::Balanced, profile: true }),
            other => Err(crate::eyre!(
                "unknown --goal `{other}` (speedup|speedup+profile|memory|balanced)"
            )),
        }
    }

    /// Stable label recorded with every run (round-trips through
    /// [`Self::parse`]).
    pub fn label(&self) -> String {
        match (self.goal, self.profile) {
            (Goal::Speedup, false) => "speedup".into(),
            (Goal::Speedup, true) => "speedup+profile".into(),
            (goal, _) => goal.name().into(),
        }
    }

    /// The legacy configuration: default objective, no profile. Runs
    /// under it are byte-identical to pre-feedback builds.
    pub fn is_default(&self) -> bool {
        *self == FeedbackConfig::default()
    }
}

/// Per-candidate performance profile: what the evaluator measured,
/// assembled for re-prompting. Built from the *previous* trial's
/// outcome and attached to the next trial's [`GenerationRequest`]
/// (`engine.rs` captures it at trial finish), so speculative prefetch
/// requests — which cannot see the in-flight outcome — hash-miss
/// rather than silently carrying a stale profile.
///
/// [`GenerationRequest`]: crate::llm::GenerationRequest
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub op: String,
    /// Outcome bucket label ("ok", "guard_reject", "compile_fail",
    /// "functional_fail", "runtime_fail").
    pub outcome: String,
    /// Noise-free speedup vs the op baseline (valid candidates only;
    /// 1.0 otherwise — never the noise-bearing measured value).
    pub true_speedup: f64,
    /// Noise-free modeled kernel time and roofline counters (valid
    /// candidates only).
    pub timing: Option<Timing>,
    /// Arithmetic intensity of the op (FLOP/byte) vs the card's ridge.
    pub intensity: f64,
    pub ridge: f64,
    /// Failure findings: guard diagnostics, compile errors, numeric
    /// mismatches — what the next generation should fix.
    pub findings: Vec<String>,
}

impl ProfileReport {
    /// Assemble the profile for one evaluated candidate.
    pub fn from_outcome(task: &OpTask, outcome: &EvalOutcome, gpu: &Gpu) -> Self {
        let intensity = if task.bytes_moved > 0.0 {
            task.flops / task.bytes_moved
        } else {
            0.0
        };
        let mut report = ProfileReport {
            op: task.name.clone(),
            outcome: outcome_bucket(outcome).into(),
            true_speedup: 1.0,
            timing: None,
            intensity,
            ridge: gpu.ridge(),
            findings: Vec::new(),
        };
        match outcome {
            EvalOutcome::Ok(s) => {
                report.true_speedup = s.true_speedup;
                report.timing = Some(s.timing.clone());
            }
            EvalOutcome::GuardReject { diagnostics } => {
                for d in diagnostics {
                    report.findings.push(format!("{}: {}", d.code, d.message));
                }
            }
            EvalOutcome::CompileFail { error } => {
                report.findings.push(format!("compile: {}", one_line(error)));
            }
            EvalOutcome::FunctionalFail { max_abs_diff } => {
                report
                    .findings
                    .push(format!("wrong numerics: max_abs_diff {max_abs_diff:.3e}"));
            }
            EvalOutcome::RuntimeFail { error } => {
                report.findings.push(format!("runtime: {}", one_line(error)));
            }
        }
        report
    }

    /// Render the `## PERFORMANCE PROFILE` section body (without the
    /// header — the request composes it). Deterministic: fixed-width
    /// formatting of noise-free quantities only.
    pub fn render(&self, goal: Goal) -> String {
        let mut out = String::with_capacity(256);
        out.push_str(&format!("op: {}\n", self.op));
        out.push_str(&format!("outcome: {}\n", self.outcome));
        if let Some(t) = &self.timing {
            out.push_str(&format!("speedup_vs_baseline: {:.3}\n", self.true_speedup));
            out.push_str(&format!("time_us: {:.3}\n", t.time * 1e6));
            out.push_str(&format!(
                "bound: {:?}; occupancy: {:.2}; eff_bw: {:.2}; eff_compute: {:.2}; \
                 traffic_bytes: {:.3e}; launches: {}\n",
                t.bound, t.occupancy, t.eff_bw, t.eff_compute, t.traffic, t.launches
            ));
            out.push_str(&format!(
                "memory_time_fraction: {:.2}\n",
                if t.time > 0.0 { (t.t_mem / t.time).clamp(0.0, 1.0) } else { 1.0 }
            ));
        }
        out.push_str(&format!(
            "arithmetic_intensity: {:.2} flop/byte (roofline ridge {:.1})\n",
            self.intensity, self.ridge
        ));
        for f in &self.findings {
            out.push_str(&format!("finding: {f}\n"));
        }
        if goal != Goal::Speedup {
            out.push_str(&format!("objective: {}\n", goal.name()));
        }
        out
    }
}

/// Outcome bucket label for the profile (mirrors the event journal's
/// outcome labels).
fn outcome_bucket(outcome: &EvalOutcome) -> &'static str {
    match outcome {
        EvalOutcome::Ok(_) => "ok",
        EvalOutcome::GuardReject { .. } => "guard_reject",
        EvalOutcome::CompileFail { .. } => "compile_fail",
        EvalOutcome::FunctionalFail { .. } => "functional_fail",
        EvalOutcome::RuntimeFail { .. } => "runtime_fail",
    }
}

/// First line of a multi-line error, bounded (profiles are prompt
/// payload — a pathological error string must not blow the token
/// budget).
fn one_line(s: &str) -> String {
    let line = s.lines().next().unwrap_or("");
    let mut end = line.len().min(160);
    while !line.is_char_boundary(end) {
        end -= 1;
    }
    if end < line.len() {
        format!("{}...", &line[..end])
    } else {
        line.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::BoundKind;

    fn timing() -> Timing {
        Timing {
            time: 12.5e-6,
            t_compute: 2.0e-6,
            t_mem: 9.5e-6,
            t_overhead: 1.0e-6,
            traffic: 4.2e6,
            occupancy: 0.67,
            eff_compute: 0.21,
            eff_bw: 0.84,
            launches: 1,
            bound: BoundKind::Memory,
        }
    }

    #[test]
    fn parse_label_roundtrip() {
        for label in ["speedup", "speedup+profile", "memory", "balanced"] {
            let cfg = FeedbackConfig::parse(label).unwrap();
            assert_eq!(cfg.label(), label);
        }
        assert!(FeedbackConfig::parse("latency").is_err());
        assert!(FeedbackConfig::parse("").unwrap().is_default());
        // memory/balanced imply the profile.
        assert!(FeedbackConfig::parse("memory").unwrap().profile);
        assert!(FeedbackConfig::parse("balanced").unwrap().profile);
        assert!(!FeedbackConfig::parse("speedup").unwrap().profile);
    }

    #[test]
    fn speedup_fitness_is_the_identity() {
        let t = timing();
        for s in [0.5, 1.0, 1.7318, 42.0] {
            assert_eq!(Goal::Speedup.fitness(s, Some(&t)), s);
            assert_eq!(Goal::Speedup.fitness(s, None), s);
        }
    }

    #[test]
    fn memory_fitness_penalizes_dram_dominated_kernels() {
        let mem_heavy = timing();
        let mut compute_heavy = timing();
        compute_heavy.t_mem = 1.0e-6;
        compute_heavy.t_compute = 10.5e-6;
        compute_heavy.bound = BoundKind::Compute;
        let f_mem = Goal::Memory.fitness(2.0, Some(&mem_heavy));
        let f_cmp = Goal::Memory.fitness(2.0, Some(&compute_heavy));
        assert!(f_cmp > f_mem, "compute-shifted kernel must rank higher: {f_cmp} vs {f_mem}");
        // Timing-less fallback is the raw speedup.
        assert_eq!(Goal::Memory.fitness(2.0, None), 2.0);
    }

    #[test]
    fn balanced_fitness_rewards_utilization() {
        let high_util = timing(); // eff_bw 0.84
        let mut low_util = timing();
        low_util.eff_bw = 0.10;
        low_util.eff_compute = 0.05;
        let hi = Goal::Balanced.fitness(2.0, Some(&high_util));
        let lo = Goal::Balanced.fitness(2.0, Some(&low_util));
        assert!(hi > lo, "{hi} vs {lo}");
    }

    #[test]
    fn render_is_deterministic_and_noise_free() {
        let task = crate::tasks::OpTask {
            name: "matmul_64".into(),
            category: 1,
            family: "matmul".into(),
            args: vec![],
            out_shape: vec![64, 64],
            flops: 5.24e5,
            bytes_moved: 4.9e4,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.85,
            algo_penalty: 1.0,
            atol: 1e-4,
            rtol: 1e-3,
            artifacts: Default::default(),
        };
        let outcome = EvalOutcome::Ok(crate::evals::EvalSuccess {
            time: 99.0, // measured (noisy) — must NOT appear in the render
            speedup: 99.0,
            pytorch_speedup: 99.0,
            true_speedup: 1.75,
            true_pytorch_speedup: 0.9,
            timing: timing(),
        });
        let gpu = Gpu::rtx4090();
        let a = ProfileReport::from_outcome(&task, &outcome, &gpu).render(Goal::Memory);
        let b = ProfileReport::from_outcome(&task, &outcome, &gpu).render(Goal::Memory);
        assert_eq!(a, b);
        assert!(a.contains("outcome: ok"));
        assert!(a.contains("speedup_vs_baseline: 1.750"));
        assert!(a.contains("bound: Memory"));
        assert!(a.contains("objective: memory"));
        assert!(!a.contains("99"), "measured (noisy) values leaked into the render:\n{a}");
        // The default objective renders no objective line.
        let plain = ProfileReport::from_outcome(&task, &outcome, &gpu).render(Goal::Speedup);
        assert!(!plain.contains("objective:"));
    }

    #[test]
    fn failure_profiles_carry_findings() {
        let task = crate::tasks::OpTask {
            name: "relu_64".into(),
            category: 3,
            family: "relu".into(),
            args: vec![],
            out_shape: vec![64],
            flops: 64.0,
            bytes_moved: 512.0,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.85,
            algo_penalty: 1.0,
            atol: 1e-4,
            rtol: 1e-3,
            artifacts: Default::default(),
        };
        let gpu = Gpu::rtx4090();
        let outcome = EvalOutcome::CompileFail { error: "unknown field `warp`\nmore".into() };
        let r = ProfileReport::from_outcome(&task, &outcome, &gpu);
        assert_eq!(r.outcome, "compile_fail");
        assert!(r.timing.is_none());
        let text = r.render(Goal::Speedup);
        assert!(text.contains("finding: compile: unknown field `warp`"));
        assert!(!text.contains("more"), "only the first error line is rendered");
    }
}
