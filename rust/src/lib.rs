//! # EvoEngineer — LLM-based CUDA kernel code evolution (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *"EvoEngineer:
//! Mastering Automated CUDA Kernel Code Evolution with Large Language
//! Models"* (Guo et al., 2025). See DESIGN.md for the system inventory
//! and the substitution table (the paper's RTX-4090/CUDA/LLM-API stack
//! is replaced by a KernelScript DSL + analytical GPU cost model +
//! SimLLM generator, with *functional truth* coming from AOT-lowered
//! JAX/Pallas HLO artifacts executed live on PJRT CPU).
//!
//! ## Layer map
//! * [`dsl`] / [`ir`] — the code space `S_text`: KernelScript parsing,
//!   printing, validation and lowering (the "nvcc" substrate).
//! * [`tasks`] — the 91-operation dataset + artifact manifest.
//! * [`runtime`] — sharded PJRT executor pool for the AOT HLO artifacts.
//! * [`guard`] — stage-0 static validity guard (shape/rank inference,
//!   structured diagnostics) that runs before any compile.
//! * [`evals`] — the paper's two-stage evaluation pipeline, fronted by
//!   the stage-0 guard when a repair policy is active.
//! * [`feedback`] — profile-guided feedback: per-candidate performance
//!   profiles rendered into prompts, plus the multi-objective `--goal`
//!   axis (DESIGN.md §17).
//! * [`costmodel`] — RTX-4090 analytical timing of candidate schedules.
//! * [`llm`] — the pluggable provider seam (typed generation/repair
//!   requests; sim, transcript-replay and HTTP backends) with the
//!   SimLLM as the default prompt-conditioned stochastic generator.
//! * [`traverse`] — the two-layer traverse technique (solution-guiding
//!   layer + prompt-engineering layer, paper §4.1.1).
//! * [`population`] — population management strategies (paper §4.1.2).
//! * [`methods`] — EvoEngineer-{Free,Insight,Full}, EoH, FunSearch,
//!   AI CUDA Engineer (paper §4.2, Appendix A.8).
//! * [`campaign`] — the method × model × op × seed sweep behind the
//!   transport-abstracted `WorkPlane` seam (DESIGN.md §15): an
//!   in-process std::thread pool, or a `campaign serve` HTTP/JSON
//!   coordinator feeding `campaign work` processes, both with
//!   checkpoint/resume journaling (DESIGN.md §8).
//! * [`store`] — persistent content-addressed evaluation cache and
//!   the provider-call transcript journal.
//! * [`bank`] — persistent cross-campaign kernel knowledge bank:
//!   elite deposits, retrieval-seeded prompts, warm-started campaigns
//!   (DESIGN.md §18).
//! * [`metrics`] / [`report`] — every table & figure of the paper.

pub mod bank;
pub mod campaign;
pub mod costmodel;
pub mod dsl;
pub mod evals;
pub mod feedback;
pub mod guard;
pub mod ir;
pub mod llm;
pub mod metrics;
pub mod methods;
pub mod population;
pub mod report;
pub mod runtime;
pub mod store;
pub mod tasks;
pub mod traverse;
pub mod util;

pub use anyhow::{anyhow as eyre, Context as WrapErr, Result};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// The paper's per-kernel optimization budget (trials).
pub const TRIAL_BUDGET: usize = 45;
