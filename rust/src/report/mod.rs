//! Report generators: one function per table/figure of the paper
//! (DESIGN.md §6 experiment index). Each renders an ASCII view of the
//! same rows/series the paper prints, from saved campaign records.
//!
//! Every generator aggregates whatever records it is given — a partial
//! or resumed checkpoint journal (DESIGN.md §8) renders the same way a
//! completed campaign does, just with fewer cells behind each number.

use std::fmt::Write as _;

use crate::methods::KernelRunRecord;
use crate::metrics;
use crate::tasks::{category_name, TaskRegistry};
use crate::util::pearson;

fn hr(width: usize) -> String {
    "-".repeat(width)
}

/// `report events` — aggregate a trial-event journal (`events.jsonl`,
/// DESIGN.md §13) into the engine's summary table.
pub fn events(events: &[crate::store::TrialEvent]) -> String {
    metrics::events_table(&metrics::EventStats::from_events(events))
}

/// Table 4 — overall results: speedup count, median speedup rate,
/// compilation success and functional correctness per category.
pub fn table4(records: &[KernelRunRecord]) -> String {
    let data = metrics::table4(records);
    let mut out = String::new();
    writeln!(out, "TABLE 4 — Overall results (per category 1..6 + overall)").unwrap();
    let mut current_model = String::new();
    // group rows by model (the paper's block structure)
    let mut keys: Vec<&metrics::GroupKey> = data.keys().collect();
    keys.sort_by(|a, b| (&a.1, &a.0).cmp(&(&b.1, &b.0)));
    for section in ["Speedup Count", "Median Speedup Rate", "Compile %", "Functional %"] {
        writeln!(out, "\n== {section} ==").unwrap();
        writeln!(
            out,
            "{:<14} {:<28} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>8}",
            "Model", "Method", "1", "2", "3", "4", "5", "6", "Overall"
        )
        .unwrap();
        writeln!(out, "{}", hr(102)).unwrap();
        current_model.clear();
        for key in &keys {
            let cells = &data[*key];
            let (method, model) = (&key.0, &key.1);
            if *model != current_model {
                current_model = model.clone();
            }
            let field = |c: &metrics::Table4Cell| -> f64 {
                match section {
                    "Speedup Count" => c.speedup_count,
                    "Median Speedup Rate" => c.median_speedup,
                    "Compile %" => c.compile_rate,
                    _ => c.correct_rate,
                }
            };
            write!(out, "{:<14} {:<28}", model, method).unwrap();
            for c in cells.iter() {
                write!(out, " {:>7.2}", field(c)).unwrap();
            }
            writeln!(out).unwrap();
        }
    }
    out
}

/// Stage-aware validity breakdown (DESIGN.md §11): per category and
/// overall, the share of trials rejected at stage 0 by the static
/// guard / repaired by the LLM loop / rejected at the compile gate /
/// compiled-but-incorrect / fully correct.
pub fn validity(records: &[KernelRunRecord]) -> String {
    let data = metrics::validity_table(records);
    let policies: std::collections::BTreeSet<&str> =
        records.iter().map(|r| r.repair_policy.as_str()).collect();
    let mut out = String::new();
    writeln!(
        out,
        "VALIDITY — trial outcomes by stage, % of evaluated trials \
         (per category 1..6 + overall)"
    )
    .unwrap();
    writeln!(
        out,
        "repair policy: {}",
        policies.into_iter().collect::<Vec<_>>().join(", ")
    )
    .unwrap();
    let mut keys: Vec<&metrics::GroupKey> = data.keys().collect();
    keys.sort_by(|a, b| (&a.1, &a.0).cmp(&(&b.1, &b.0)));
    for section in [
        "Stage-0 rejected %",
        "Repaired %",
        "Compile-failed %",
        "Incorrect %",
        "Correct %",
    ] {
        writeln!(out, "\n== {section} ==").unwrap();
        writeln!(
            out,
            "{:<14} {:<28} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>8}",
            "Model", "Method", "1", "2", "3", "4", "5", "6", "Overall"
        )
        .unwrap();
        writeln!(out, "{}", hr(102)).unwrap();
        for key in &keys {
            let cells = &data[*key];
            let field = |c: &metrics::ValidityCell| -> f64 {
                match section {
                    "Stage-0 rejected %" => c.stage0_pct,
                    "Repaired %" => c.repaired_pct,
                    "Compile-failed %" => c.compile_fail_pct,
                    "Incorrect %" => c.incorrect_pct,
                    _ => c.correct_pct,
                }
            };
            write!(out, "{:<14} {:<28}", key.1, key.0).unwrap();
            for c in cells.iter() {
                write!(out, " {:>7.2}", field(c)).unwrap();
            }
            writeln!(out).unwrap();
        }
    }
    out
}

/// Per-provider/model token usage, modeled API cost, and the quality
/// side of the frontier — median speedup and correctness per row, so
/// cost and quality read off one table (the provider seam's accounting
/// view, DESIGN.md §12/§16; pricing per paper Table 6). When any
/// record ran a multi-member ensemble, the learned bandit arm weights
/// are appended.
/// Per-goal breakdown (DESIGN.md §17): one row per `--goal` label a
/// record ran under — validity and speedup side by side, so the legs
/// of a multi-objective campaign compare in one table.
pub fn goals(records: &[KernelRunRecord]) -> String {
    let rows = metrics::goal_table(records);
    let mut out = String::new();
    writeln!(out, "GOALS — runs and validity per search objective").unwrap();
    writeln!(
        out,
        "{:<18} {:>6} {:>7} {:>9} {:>10} {:>8} {:>14}",
        "Goal", "Runs", "Valid", "Median x", "Correct %", "Guard -", "Tokens"
    )
    .unwrap();
    writeln!(out, "{}", hr(78)).unwrap();
    for row in &rows {
        writeln!(
            out,
            "{:<18} {:>6} {:>7} {:>9.2} {:>10.1} {:>8} {:>14}",
            row.goal,
            row.runs,
            row.valid_runs,
            row.median_speedup,
            row.correct_pct,
            row.guard_rejected,
            row.prompt_tokens + row.completion_tokens,
        )
        .unwrap();
    }
    if rows.len() < 2 {
        writeln!(
            out,
            "(single-objective sweep — run legs with different --goal values to compare)"
        )
        .unwrap();
    }
    out
}

pub fn tokens(records: &[KernelRunRecord]) -> String {
    let rows = metrics::token_cost_table(records);
    let mut out = String::new();
    writeln!(out, "TOKENS — cost/quality frontier per provider x model").unwrap();
    writeln!(
        out,
        "{:<10} {:<16} {:>6} {:>14} {:>14} {:>12} {:>9} {:>10}",
        "Provider", "Model", "Runs", "Prompt tok", "Compl. tok", "Cost USD", "Median x", "Correct %"
    )
    .unwrap();
    writeln!(out, "{}", hr(98)).unwrap();
    let mut total_tokens = 0u64;
    let mut total_cost = 0.0f64;
    let mut any_unpriced = false;
    for row in &rows {
        let cost = match row.cost_usd {
            Some(c) => {
                total_cost += c;
                format!("{c:.2}")
            }
            None => {
                any_unpriced = true;
                "n/a".to_string()
            }
        };
        total_tokens += row.total_tokens();
        writeln!(
            out,
            "{:<10} {:<16} {:>6} {:>14} {:>14} {:>12} {:>9.2} {:>10.1}",
            row.provider,
            row.model,
            row.runs,
            row.prompt_tokens,
            row.completion_tokens,
            cost,
            row.median_speedup,
            row.correct_pct
        )
        .unwrap();
    }
    writeln!(
        out,
        "total: {} tokens, ${:.2}{}",
        total_tokens,
        total_cost,
        if any_unpriced { " (+ unpriced models)" } else { "" }
    )
    .unwrap();
    let arms = metrics::arm_weight_table(records);
    if !arms.is_empty() {
        writeln!(out).unwrap();
        writeln!(out, "ARM WEIGHTS — learned ensemble routing (DESIGN.md §16)").unwrap();
        writeln!(
            out,
            "{:<12} {:<14} {:<16} {:>7} {:>12}",
            "Member", "Operator", "Category", "Pulls", "Mean reward"
        )
        .unwrap();
        writeln!(out, "{}", hr(65)).unwrap();
        for a in &arms {
            writeln!(
                out,
                "{:<12} {:<14} {:<16} {:>7} {:>12.3}",
                a.member, a.operator, a.category, a.pulls, a.mean_reward
            )
            .unwrap();
        }
    }
    out
}

/// Table 5 — dataset composition.
pub fn table5(registry: &TaskRegistry) -> String {
    let mut out = String::new();
    writeln!(out, "TABLE 5 — Kernel classification by computational complexity").unwrap();
    writeln!(out, "{:<30} {:>6} {:>8}", "Category", "Count", "Percent").unwrap();
    writeln!(out, "{}", hr(48)).unwrap();
    let total = registry.ops.len();
    for (cat, count) in registry.category_counts() {
        writeln!(
            out,
            "{:<30} {:>6} {:>7.1}%",
            category_name(cat),
            count,
            100.0 * count as f64 / total as f64
        )
        .unwrap();
    }
    writeln!(out, "{:<30} {:>6} {:>7.1}%", "Total", total, 100.0).unwrap();
    out
}

/// Figure 1 — speedup vs functional-correctness trade-off scatter.
pub fn fig1(records: &[KernelRunRecord]) -> String {
    let mut pts = metrics::tradeoff_points(records);
    pts.sort_by(|a, b| {
        b.median_speedup
            .partial_cmp(&a.median_speedup)
            .unwrap()
            .then(a.method.cmp(&b.method))
    });
    let mut out = String::new();
    writeln!(out, "FIGURE 1 — Speedup / correctness trade-off (one point per method x model)")
        .unwrap();
    writeln!(
        out,
        "{:<28} {:<14} {:>14} {:>12}",
        "Method", "Model", "MedianSpeedup", "Functional%"
    )
    .unwrap();
    writeln!(out, "{}", hr(72)).unwrap();
    for p in &pts {
        writeln!(
            out,
            "{:<28} {:<14} {:>14.2} {:>12.1}",
            p.method, p.model, p.median_speedup, p.correct_rate
        )
        .unwrap();
    }
    // Pareto front (dominance illustration, as the figure shows).
    writeln!(out, "\nPareto-dominant points (no other point better on both axes):").unwrap();
    for p in &pts {
        let dominated = pts.iter().any(|q| {
            (q.median_speedup > p.median_speedup && q.correct_rate >= p.correct_rate)
                || (q.median_speedup >= p.median_speedup && q.correct_rate > p.correct_rate)
        });
        if !dominated {
            writeln!(out, "  * {} / {}", p.method, p.model).unwrap();
        }
    }
    out
}

/// Figure 4 (and 6, 7 via model filter) — token usage vs speedup and
/// validity.
pub fn fig4(records: &[KernelRunRecord], model_filter: &str) -> String {
    let filtered: Vec<KernelRunRecord> = records
        .iter()
        .filter(|r| model_filter.is_empty() || r.model.to_ascii_lowercase()
            .starts_with(&model_filter.to_ascii_lowercase()))
        .cloned()
        .collect();
    let pts = metrics::tradeoff_points(&filtered);
    let runs_per_group = |method: &str, model: &str| {
        filtered
            .iter()
            .filter(|r| r.method == *method && r.model == *model)
            .count()
            .max(1) as u64
    };
    let mut out = String::new();
    writeln!(
        out,
        "FIGURE 4 — Token usage vs performance/validity{}",
        if model_filter.is_empty() { String::new() } else { format!(" ({model_filter})") }
    )
    .unwrap();
    writeln!(
        out,
        "{:<28} {:<14} {:>14} {:>14} {:>12}",
        "Method", "Model", "MTok/kernel", "MedianSpeedup", "Functional%"
    )
    .unwrap();
    writeln!(out, "{}", hr(88)).unwrap();
    let mut pts = pts;
    pts.sort_by(|a, b| a.total_tokens.cmp(&b.total_tokens));
    for p in pts {
        let per_kernel =
            p.total_tokens as f64 / runs_per_group(&p.method, &p.model) as f64 / 1.0e6;
        writeln!(
            out,
            "{:<28} {:<14} {:>14.4} {:>14.2} {:>12.1}",
            p.method, p.model, per_kernel, p.median_speedup, p.correct_rate
        )
        .unwrap();
    }
    out
}

/// Figure 5 — operations with >2x speedup over PyTorch; max speedup and
/// winning method per op.
pub fn fig5(records: &[KernelRunRecord]) -> String {
    let best = metrics::pytorch_best_per_op(records);
    let over2: Vec<&metrics::PytorchBest> =
        best.iter().filter(|b| b.speedup > 2.0).collect();
    let evo_wins = over2
        .iter()
        .filter(|b| b.method.starts_with("EvoEngineer"))
        .count();
    let mut out = String::new();
    writeln!(out, "FIGURE 5 — Ops with >2x speedup vs PyTorch (max across methods & models)")
        .unwrap();
    writeln!(out, "{:<24} {:>4} {:>9}  {:<28} {:<14}", "Op", "Cat", "Speedup", "Method", "Model")
        .unwrap();
    writeln!(out, "{}", hr(84)).unwrap();
    for b in &over2 {
        writeln!(
            out,
            "{:<24} {:>4} {:>8.2}x  {:<28} {:<14}",
            b.op, b.category, b.speedup, b.method, b.model
        )
        .unwrap();
    }
    writeln!(
        out,
        "\n{} ops >2x; EvoEngineer variants win {} ({:.1}%)",
        over2.len(),
        evo_wins,
        100.0 * evo_wins as f64 / over2.len().max(1) as f64
    )
    .unwrap();
    if let Some(best_all) = best.first() {
        writeln!(
            out,
            "max speedup over PyTorch: {:.2}x ({} via {})",
            best_all.speedup, best_all.op, best_all.method
        )
        .unwrap();
    }
    out
}

/// Table 7 — distribution of speedup ranges vs PyTorch.
pub fn table7(records: &[KernelRunRecord]) -> String {
    let data = metrics::speedup_range_distribution(records);
    let mut out = String::new();
    writeln!(out, "TABLE 7 — Distribution of PyTorch-relative speedup ranges").unwrap();
    writeln!(
        out,
        "{:<14} {:<28} {:>6} {:>8} {:>8} {:>9} {:>6}",
        "Model", "Method", "<1.0", "1.0~2.0", "2.0~5.0", "5.0~10.0", ">10.0"
    )
    .unwrap();
    writeln!(out, "{}", hr(84)).unwrap();
    let mut keys: Vec<&metrics::GroupKey> = data.keys().collect();
    keys.sort_by(|a, b| (&a.1, &a.0).cmp(&(&b.1, &b.0)));
    for key in keys {
        let b = &data[key];
        writeln!(
            out,
            "{:<14} {:<28} {:>6} {:>8} {:>8} {:>9} {:>6}",
            key.1, key.0, b[0], b[1], b[2], b[3], b[4]
        )
        .unwrap();
    }
    out
}

/// Figure 8 — speedup distribution five-number summaries per method.
pub fn fig8(records: &[KernelRunRecord]) -> String {
    let dists = metrics::method_distributions(records);
    let mut out = String::new();
    writeln!(out, "FIGURE 8 — PyTorch-relative speedup distributions per method").unwrap();
    writeln!(
        out,
        "{:<28} {:>7} {:>7} {:>7} {:>7} {:>8} {:>5}",
        "Method", "min", "p25", "median", "p75", "max", "n"
    )
    .unwrap();
    writeln!(out, "{}", hr(75)).unwrap();
    for d in dists {
        writeln!(
            out,
            "{:<28} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>8.2} {:>5}",
            d.method, d.min, d.p25, d.median, d.p75, d.max, d.n
        )
        .unwrap();
    }
    out
}

/// Table 8 — AI CUDA Engineer replication summary.
pub fn table8(records: &[KernelRunRecord]) -> String {
    let s = metrics::replication_summary(records, "AI CUDA Engineer");
    let mut out = String::new();
    writeln!(out, "TABLE 8 — AI CUDA Engineer replication (ours)").unwrap();
    writeln!(out, "{}", hr(48)).unwrap();
    writeln!(out, "{:<34} {:>8.2}", "Median speedup (all)", s.median_speedup_all).unwrap();
    writeln!(out, "{:<34} {:>8.2}", "Median speedup (success)", s.median_speedup_success)
        .unwrap();
    writeln!(
        out,
        "{:<34} {:>5}/{:<3}",
        "Successful tasks (>1x)", s.successful_tasks, s.n_ops
    )
    .unwrap();
    out
}

/// Figure 9 — correlation between two independent replication runs.
pub fn fig9(records: &[KernelRunRecord]) -> String {
    let (xs, ys) = metrics::replication_pairs(records, "AI CUDA Engineer", 0, 1);
    let r = pearson(&xs, &ys);
    let mut out = String::new();
    writeln!(out, "FIGURE 9 — Replication correlation (AI CUDA Engineer)").unwrap();
    writeln!(
        out,
        "paired ops: {}  |  Pearson r (log speedups, seed 0 vs seed 1): {:.3}",
        xs.len(),
        r
    )
    .unwrap();
    writeln!(
        out,
        "(paper: r = 0.9 between their implementation and Sakana's released archive;\n\
         here the two axes are two independent replication runs — see EXPERIMENTS.md)"
    )
    .unwrap();
    out
}

/// Convergence view (framework analysis): mean best-so-far speedup per
/// trial, per method — how fast each traverse/population configuration
/// climbs within the 45-trial budget.
pub fn convergence(records: &[KernelRunRecord]) -> String {
    use std::collections::BTreeMap;
    let mut by_method: BTreeMap<&str, (Vec<f64>, Vec<usize>)> = BTreeMap::new();
    let mut max_len = 0usize;
    for r in records {
        let (sums, counts) = by_method.entry(r.method.as_str()).or_default();
        max_len = max_len.max(r.trajectory.len());
        if sums.len() < r.trajectory.len() {
            sums.resize(r.trajectory.len(), 0.0);
            counts.resize(r.trajectory.len(), 0);
        }
        for (i, s) in r.trajectory.iter().enumerate() {
            sums[i] += s;
            counts[i] += 1;
        }
    }
    let checkpoints: Vec<usize> = [0usize, 4, 9, 14, 19, 29, 44]
        .into_iter()
        .filter(|&i| i < max_len.max(1))
        .collect();
    let mut out = String::new();
    writeln!(out, "CONVERGENCE — mean best-so-far speedup after trial t").unwrap();
    write!(out, "{:<28}", "Method").unwrap();
    for c in &checkpoints {
        write!(out, " {:>8}", format!("t={}", c + 1)).unwrap();
    }
    writeln!(out).unwrap();
    writeln!(out, "{}", hr(28 + 9 * checkpoints.len())).unwrap();
    for (method, (sums, counts)) in &by_method {
        write!(out, "{method:<28}").unwrap();
        for &c in &checkpoints {
            if c < sums.len() && counts[c] > 0 {
                write!(out, " {:>8.2}", sums[c] / counts[c] as f64).unwrap();
            } else {
                write!(out, " {:>8}", "-").unwrap();
            }
        }
        writeln!(out).unwrap();
    }
    out
}

/// Tables 1–3 — qualitative method/configuration matrix, encoded from
/// the method definitions.
pub fn methods_table() -> String {
    let mut out = String::new();
    writeln!(out, "TABLE 2/3 — Framework analysis of methods (I1 task context, I2 history,").unwrap();
    writeln!(out, "I3 insights, I4 open-world; population strategy)").unwrap();
    writeln!(
        out,
        "{:<28} {:>3} {:>3} {:>3} {:>3}  {:<12} {:<10}",
        "Method", "I1", "I2", "I3", "I4", "Population", "Prompt"
    )
    .unwrap();
    writeln!(out, "{}", hr(72)).unwrap();
    let rows = [
        ("AI CUDA Engineer", "Y", "Y(5)", "gen*", "inter-op", "elite(5)", "verbose"),
        ("FunSearch", "Y", "Y(2)", "-", "-", "islands(5)", "minimal"),
        ("EvoEngineer-Solution (EoH)", "Y", "Y(3)", "gen*", "-", "elite(4)", "structured"),
        ("EvoEngineer-Free", "Y", "-", "-", "-", "single-best", "minimal"),
        ("EvoEngineer-Insight", "Y", "-", "Y(4)", "-", "single-best", "structured"),
        ("EvoEngineer-Full", "Y", "Y(3)", "Y(4)", "-", "elite(4)", "structured"),
    ];
    for (m, i1, i2, i3, i4, pop, style) in rows {
        writeln!(
            out,
            "{:<28} {:>3} {:>4} {:>4} {:>8}  {:<12} {:<10}",
            m, i1, i2, i3, i4, pop, style
        )
        .unwrap();
    }
    writeln!(out, "* insights generated with each solution but not fed back (Table 2 note)")
        .unwrap();
    out
}

/// Work-plane summary for a distributed sweep (`campaign serve`,
/// DESIGN.md §15): how the grid was claimed, streamed and merged.
pub fn plane(stats: &metrics::PlaneStats) -> String {
    let mut out = String::new();
    writeln!(out, "WORK-PLANE SUMMARY").unwrap();
    writeln!(out, "{}", hr(44)).unwrap();
    let rows: [(&str, u64); 11] = [
        ("grid cells offered", stats.grid as u64),
        ("resumed from checkpoint", stats.resumed as u64),
        ("claims handed out", stats.claims),
        ("cells released + re-offered", stats.reclaims),
        ("completions accepted", stats.completions),
        ("duplicate/stale completions", stats.duplicate_completions),
        ("event batches accepted", stats.event_batches),
        ("event batches rejected stale", stats.stale_event_batches),
        ("trial events journaled", stats.events),
        ("eval-cache lines merged", stats.eval_lines_merged),
        ("transcript lines merged", stats.transcript_lines_merged),
    ];
    for (label, n) in rows {
        writeln!(out, "{label:<32} {n:>10}").unwrap();
    }
    out
}

/// `report bank` — cross-campaign knowledge-bank health (DESIGN.md
/// §18): the journal's per-op / per-goal aggregates, plus — when
/// campaign records are supplied — a trials-to-best table, so a cold
/// and a warm-started run of the same slice compare with one diff.
pub fn bank(stats: &crate::bank::BankStats, records: &[KernelRunRecord]) -> String {
    let mut out = String::new();
    writeln!(out, "KERNEL BANK — cross-campaign elite journal").unwrap();
    writeln!(out, "{}", hr(60)).unwrap();
    out.push_str(&crate::bank::stats_report(stats));
    if records.is_empty() {
        return out;
    }
    // Trials-to-best: the first trial whose best-so-far trajectory
    // reaches the run's final best. Warm-started runs that inherit a
    // strong elite converge in strictly fewer trials on ops the bank
    // covers — exactly the number the nightly cold-vs-warm job diffs.
    let mut by_op: std::collections::BTreeMap<&str, (Vec<usize>, f64)> =
        std::collections::BTreeMap::new();
    for r in records {
        let to_best = r
            .trajectory
            .iter()
            .position(|&s| s >= r.best_speedup - 1e-9)
            .map(|i| i + 1)
            .unwrap_or(r.trials);
        let slot = by_op.entry(r.op.as_str()).or_default();
        slot.0.push(to_best);
        slot.1 = slot.1.max(r.best_speedup);
    }
    writeln!(out, "\nTRIALS-TO-BEST — trials until each run's final best first appears").unwrap();
    writeln!(out, "{:<24} {:>6} {:>16} {:>12}", "Op", "Runs", "Median trials", "Best speedup")
        .unwrap();
    writeln!(out, "{}", hr(62)).unwrap();
    let mut all: Vec<usize> = Vec::new();
    for (op, (mut trials, best)) in by_op {
        trials.sort_unstable();
        all.extend_from_slice(&trials);
        let median = trials[trials.len() / 2];
        writeln!(out, "{:<24} {:>6} {:>16} {:>11.2}x", op, trials.len(), median, best).unwrap();
    }
    all.sort_unstable();
    writeln!(out, "{}", hr(62)).unwrap();
    writeln!(out, "{:<24} {:>6} {:>16}", "overall", all.len(), all[all.len() / 2]).unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn records() -> Vec<KernelRunRecord> {
        let mut v = Vec::new();
        for (m, speed, pt) in [
            ("EvoEngineer-Free", 2.5, 3.0),
            ("AI CUDA Engineer", 1.3, 0.8),
        ] {
            for seed in 0..2 {
                v.push(KernelRunRecord {
                    method: m.into(),
                    model: "GPT-4.1".into(),
                    op: "matmul_64".into(),
                    category: 1,
                    seed,
                    trials: 45,
                    budget: 45,
                    compiled_trials: 36,
                    correct_trials: 27,
                    guard_rejected_trials: 4,
                    repaired_trials: 2,
                    repair_attempts: 3,
                    repair_policy: "repair:2".into(),
                    goal: "speedup".into(),
                    provider: "sim".into(),
                    best_speedup: speed,
                    best_pytorch_speedup: pt,
                    any_valid: true,
                    prompt_tokens: 1000,
                    completion_tokens: 400,
                    trajectory: vec![],
                    arms: vec![],
                    best_src: None,
                });
            }
        }
        v
    }

    #[test]
    fn reports_render() {
        let recs = records();
        for text in [
            table4(&recs),
            fig1(&recs),
            fig4(&recs, ""),
            fig5(&recs),
            table7(&recs),
            fig8(&recs),
            table8(&recs),
            fig9(&recs),
            methods_table(),
            validity(&recs),
            tokens(&recs),
            goals(&recs),
        ] {
            assert!(!text.is_empty());
        }
        assert!(fig5(&recs).contains("matmul_64"));
        assert!(table7(&recs).contains("AI CUDA Engineer"));
    }

    #[test]
    fn bank_report_renders_stats_and_trials_to_best() {
        let stats = crate::bank::BankStats {
            entries: 2,
            journal_lines: 3,
            dup_lines: 1,
            file_bytes: 512,
            per_op: vec![("matmul_64".into(), 2, 2.5, 2.5)],
            per_goal: vec![("speedup".into(), 2)],
            index: None,
        };
        // Stats-only view (no records): just the journal aggregates.
        let text = bank(&stats, &[]);
        assert!(text.contains("KERNEL BANK"), "{text}");
        assert!(text.contains("2 entries"), "{text}");
        assert!(!text.contains("TRIALS-TO-BEST"), "{text}");
        // With records: the convergence half appears. Record 0 reaches
        // its final best (2.5x) at trial 2 of its trajectory; records
        // with empty trajectories fall back to their trial count.
        let mut recs = records();
        recs[0].trajectory = vec![1.0, 2.5, 2.5];
        let text = bank(&stats, &recs);
        assert!(text.contains("TRIALS-TO-BEST"), "{text}");
        assert!(text.contains("matmul_64"), "{text}");
        assert!(text.contains("overall"), "{text}");
    }

    #[test]
    fn token_report_prices_known_models() {
        let text = tokens(&records());
        assert!(text.contains("Provider"), "{text}");
        assert!(text.contains("sim"), "{text}");
        assert!(text.contains("GPT-4.1"), "{text}");
        // 4 runs x (1000 prompt + 400 completion) tokens priced at
        // Table 6 rates: a nonzero dollar figure must appear.
        assert!(text.contains("total: 5600 tokens"), "{text}");
        assert!(!text.contains("n/a"), "{text}");
        // No record carries bandit arms, so the routing section is absent.
        assert!(!text.contains("ARM WEIGHTS"), "{text}");
    }

    #[test]
    fn token_report_appends_arm_weights_for_ensemble_runs() {
        let mut recs = records();
        recs[0].provider = "ensemble:[sim@0.5,sim#alt@0.5,x=0.25]".into();
        recs[0].arms = vec![crate::llm::ArmWeight {
            member: "alt".into(),
            operator: "rewrite".into(),
            category: "matmul".into(),
            pulls: 7,
            mean_reward: 1.25,
        }];
        let text = tokens(&recs);
        assert!(text.contains("ARM WEIGHTS"), "{text}");
        assert!(text.contains("alt"), "{text}");
        assert!(text.contains("rewrite"), "{text}");
        assert!(text.contains("1.250"), "{text}");
        assert!(text.contains("Median x"), "{text}");
        assert!(text.contains("Correct %"), "{text}");
    }

    #[test]
    fn validity_report_breaks_out_stages() {
        let text = validity(&records());
        assert!(text.contains("Stage-0 rejected %"), "{text}");
        assert!(text.contains("Repaired %"), "{text}");
        assert!(text.contains("Compile-failed %"), "{text}");
        assert!(text.contains("Incorrect %"), "{text}");
        assert!(text.contains("Correct %"), "{text}");
        assert!(text.contains("repair policy: repair:2"), "{text}");
        assert!(text.contains("EvoEngineer-Free"), "{text}");
    }

    #[test]
    fn goals_report_breaks_out_objectives() {
        let mut recs = records();
        recs[2].goal = "balanced".into();
        recs[3].goal = "balanced".into();
        let text = goals(&recs);
        assert!(text.contains("GOALS"), "{text}");
        assert!(text.contains("speedup"), "{text}");
        assert!(text.contains("balanced"), "{text}");
        // Two goals present: the single-objective hint is absent.
        assert!(!text.contains("single-objective"), "{text}");
        // One goal present: the hint shows.
        let text = goals(&records());
        assert!(text.contains("single-objective"), "{text}");
    }

    #[test]
    fn fig9_reports_correlation() {
        let text = fig9(&records());
        assert!(text.contains("Pearson"));
    }

    #[test]
    fn convergence_averages_trajectories() {
        let mut recs = records();
        for r in &mut recs {
            r.trajectory = vec![1.0, 1.5, 2.0, 2.0, 2.5];
        }
        let text = convergence(&recs);
        assert!(text.contains("t=1"));
        assert!(text.contains("t=5"));
        assert!(text.contains("2.50"));
        assert!(text.contains("EvoEngineer-Free"));
    }
}
