//! Lowering: KernelScript AST → ExecutionPlan (the back half of the
//! compile gate). Resolves the program against the artifact manifest —
//! a hallucinated semantics variant fails here with an
//! "undefined symbol"-style error, exactly like CUDA link failures the
//! paper's Compilation Check catches.

use crate::dsl::{self, KernelSpec};
use crate::tasks::{OpTask, TaskRegistry};

/// A fully-resolved, legal candidate: everything the evaluator needs.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub spec: KernelSpec,
    /// Artifact path (relative to the registry root) for the variant.
    pub artifact: String,
    /// Derived resource facts (recorded for profiling feedback).
    pub smem_bytes: u64,
    pub est_registers: u32,
}

/// Why a candidate failed to compile (stage 1 of the paper's two-stage
/// evaluation). The distinction matters for metrics: all of these count
/// against Compilation Success Pass@1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// Lexer/parser rejection.
    Syntax(String),
    /// Schedule legality rejection (resource limits).
    Validation(String),
    /// Program names an op that is not the task under optimization.
    WrongOp { expected: String, found: String },
    /// Semantics variant has no artifact (LLM hallucination).
    UnknownVariant(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Syntax(m) => write!(f, "syntax error: {m}"),
            CompileError::Validation(m) => write!(f, "validation error: {m}"),
            CompileError::WrongOp { expected, found } => {
                write!(f, "kernel implements `{found}` but task is `{expected}`")
            }
            CompileError::UnknownVariant(v) => {
                write!(f, "undefined semantics variant `{v}` (no such artifact)")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// Full compile: text → parse → validate → resolve. This is the
/// real-program-analysis path every SimLLM emission goes through.
pub fn compile(
    src: &str,
    task: &OpTask,
    registry: &TaskRegistry,
) -> Result<ExecutionPlan, CompileError> {
    let spec = dsl::parse(src).map_err(|e| CompileError::Syntax(e.to_string()))?;
    lower(spec, task, registry)
}

/// Lower an already-parsed spec (used by tests and by the baseline
/// bootstrap which constructs ASTs directly).
pub fn lower(
    spec: KernelSpec,
    task: &OpTask,
    registry: &TaskRegistry,
) -> Result<ExecutionPlan, CompileError> {
    dsl::validate(&spec).map_err(|e| CompileError::Validation(e.to_string()))?;
    if spec.op != task.name {
        return Err(CompileError::WrongOp {
            expected: task.name.clone(),
            found: spec.op.clone(),
        });
    }
    let artifact = task
        .artifacts
        .get(&spec.semantics)
        .cloned()
        .ok_or_else(|| CompileError::UnknownVariant(spec.semantics.clone()))?;
    let _ = registry; // resolution uses the task's own manifest entry
    let smem_bytes = spec.schedule.smem_bytes();
    let est_registers = spec.schedule.est_registers();
    Ok(ExecutionPlan { spec, artifact, smem_bytes, est_registers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::printer::print;

    fn fixture() -> (TaskRegistry, OpTask) {
        let reg = TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        let op = reg.get("matmul_64").unwrap().clone();
        (reg, op)
    }

    #[test]
    fn compiles_baseline() {
        let (reg, op) = fixture();
        let src = print(&KernelSpec::baseline("matmul_64"));
        let plan = compile(&src, &op, &reg).unwrap();
        assert!(plan.artifact.contains("opt"));
    }

    #[test]
    fn hallucinated_variant_fails() {
        let (reg, op) = fixture();
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.semantics = "turbo_v2".into();
        let err = lower(spec, &op, &reg).unwrap_err();
        assert!(matches!(err, CompileError::UnknownVariant(_)), "{err}");
    }

    #[test]
    fn wrong_op_fails() {
        let (reg, op) = fixture();
        let spec = KernelSpec::baseline("softmax_64");
        let err = lower(spec, &op, &reg).unwrap_err();
        assert!(matches!(err, CompileError::WrongOp { .. }), "{err}");
    }

    #[test]
    fn syntax_error_reported() {
        let (reg, op) = fixture();
        let err = compile("kernel matmul_64 { semantics ref; }", &op, &reg).unwrap_err();
        assert!(matches!(err, CompileError::Syntax(_)), "{err}");
    }
}
