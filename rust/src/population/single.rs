//! Single-solution strategy: only the current best valid candidate is
//! retained (EvoEngineer-Free / -Insight in Table 3: "best solution
//! maintaining"). If nothing valid exists yet, the most recent
//! candidate is offered as the parent so the search can repair it.

use super::{Candidate, Population};
use crate::util::Rng;

#[derive(Debug, Clone, Default)]
pub struct SingleBest {
    best: Option<Candidate>,
    last: Option<Candidate>,
}

impl SingleBest {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Population for SingleBest {
    fn insert(&mut self, cand: Candidate) {
        if cand.valid()
            && self
                .best
                .as_ref()
                .map(|b| cand.fitness() > b.fitness())
                .unwrap_or(true)
        {
            self.best = Some(cand.clone());
        }
        self.last = Some(cand);
    }

    fn parent(&mut self, _rng: &mut Rng) -> Option<Candidate> {
        self.best.clone().or_else(|| self.last.clone())
    }

    fn history(&self, k: usize) -> Vec<Candidate> {
        if k == 0 {
            return vec![];
        }
        self.best.iter().cloned().collect()
    }

    fn best(&self) -> Option<Candidate> {
        self.best.clone()
    }

    fn name(&self) -> &'static str {
        "single-best"
    }

    fn snapshot(&self) -> Box<dyn Population> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_candidate;
    use super::*;

    #[test]
    fn keeps_only_best_valid() {
        let mut p = SingleBest::new();
        let mut rng = Rng::new(1);
        p.insert(test_candidate(1.5, true, 0));
        p.insert(test_candidate(3.0, true, 1));
        p.insert(test_candidate(2.0, true, 2));
        assert_eq!(p.best().unwrap().speedup, 3.0);
        assert_eq!(p.parent(&mut rng).unwrap().speedup, 3.0);
        assert_eq!(p.history(5).len(), 1);
    }

    #[test]
    fn invalid_never_becomes_best() {
        let mut p = SingleBest::new();
        p.insert(test_candidate(10.0, false, 0));
        assert!(p.best().is_none());
    }

    #[test]
    fn falls_back_to_last_when_nothing_valid() {
        let mut p = SingleBest::new();
        let mut rng = Rng::new(1);
        p.insert(test_candidate(10.0, false, 0));
        let parent = p.parent(&mut rng).unwrap();
        assert_eq!(parent.trial, 0);
    }

    #[test]
    fn empty_population_has_no_parent() {
        let mut p = SingleBest::new();
        let mut rng = Rng::new(1);
        assert!(p.parent(&mut rng).is_none());
        assert!(p.history(3).is_empty());
    }
}
