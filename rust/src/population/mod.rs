//! Population management (paper §4.1.2): how candidate solutions are
//! maintained, selected and evolved across generations. The paper's
//! three strategy classes are implemented behind one trait:
//!
//! * [`SingleBest`] — keep only the current best solution
//!   (EvoEngineer-Free / -Insight).
//! * [`Elite`] — keep a small set of high performers
//!   (EvoEngineer-Full, EoH).
//! * [`Islands`] — diversity maintenance via independent sub-populations
//!   with periodic resets (FunSearch).

pub mod elite;
pub mod islands;
pub mod single;

pub use elite::Elite;
pub use islands::Islands;
pub use single::SingleBest;

use crate::dsl::KernelSpec;
use crate::util::Rng;

/// One evaluated candidate program.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Raw emitted text (the point in `S_text`).
    pub src: String,
    /// Parsed spec, if it compiled.
    pub spec: Option<KernelSpec>,
    pub compiled: bool,
    pub correct: bool,
    /// *Measured* speedup vs the op baseline (1.0 when invalid — the
    /// paper's failure convention). Selection operates on this noisy
    /// value, reproducing the paper's §A.7 mis-selection risk.
    pub speedup: f64,
    /// Measured speedup vs the modeled PyTorch implementation (0.0
    /// when invalid).
    pub pytorch_speedup: f64,
    /// Noise-free speedup vs baseline (final-report value).
    pub true_speedup: f64,
    /// Noise-free speedup vs PyTorch (final-report value).
    pub true_pytorch_speedup: f64,
    /// The optimization insight the LLM attached (I3 raw material).
    pub insight: Option<String>,
    /// Trial index within the 45-trial budget.
    pub trial: usize,
}

impl Candidate {
    /// Valid = compiled + functionally correct (constraint g(p)=0).
    pub fn valid(&self) -> bool {
        self.compiled && self.correct
    }

    /// Fitness used for selection: speedup if valid, else 0.
    pub fn fitness(&self) -> f64 {
        if self.valid() {
            self.speedup
        } else {
            0.0
        }
    }
}

/// Population management strategy interface.
pub trait Population: Send {
    /// Record an evaluated candidate.
    fn insert(&mut self, cand: Candidate);

    /// Pick the candidate the next prompt should improve upon.
    fn parent(&mut self, rng: &mut Rng) -> Option<Candidate>;

    /// Up to `k` historical high-quality solutions for the prompt's
    /// I2 section (best first).
    fn history(&self, k: usize) -> Vec<Candidate>;

    /// Best valid candidate found so far.
    fn best(&self) -> Option<Candidate>;

    /// Strategy label (for reports).
    fn name(&self) -> &'static str;

    /// Deep copy of the current state. The trial engine's speculative
    /// prefetch assembles *hypothetical* future prompts on a snapshot
    /// so stateful strategies (the island cursor) are never mutated
    /// off the real trial sequence.
    fn snapshot(&self) -> Box<dyn Population>;
}

#[cfg(test)]
pub(crate) fn test_candidate(speedup: f64, valid: bool, trial: usize) -> Candidate {
    Candidate {
        src: format!("kernel x {{ semantics: opt; }} # {trial}"),
        spec: Some(KernelSpec::baseline("x")),
        compiled: valid,
        correct: valid,
        speedup,
        pytorch_speedup: speedup * 0.5,
        true_speedup: speedup,
        true_pytorch_speedup: speedup * 0.5,
        insight: Some(format!("insight {trial}")),
        trial,
    }
}
