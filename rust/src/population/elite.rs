//! Elite-preservation strategy: the top-k valid candidates survive
//! across generations (EvoEngineer-Full and EoH in Table 3: "elite
//! preservation strategy"). Parents are sampled from the elites with
//! rank weighting, which is how EoH's population of 4 behaves.

use super::{Candidate, Population};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct Elite {
    capacity: usize,
    elites: Vec<Candidate>, // sorted best-first
    last: Option<Candidate>,
}

impl Elite {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { capacity, elites: Vec::new(), last: None }
    }

    pub fn elites(&self) -> &[Candidate] {
        &self.elites
    }
}

impl Population for Elite {
    fn insert(&mut self, cand: Candidate) {
        if cand.valid() {
            // Deduplicate by source text: re-discovering the same
            // program must not crowd out diversity.
            if !self.elites.iter().any(|e| e.src == cand.src) {
                self.elites.push(cand.clone());
                self.elites
                    .sort_by(|a, b| b.fitness().partial_cmp(&a.fitness()).unwrap());
                self.elites.truncate(self.capacity);
            }
        }
        self.last = Some(cand);
    }

    fn parent(&mut self, rng: &mut Rng) -> Option<Candidate> {
        if self.elites.is_empty() {
            return self.last.clone();
        }
        // Rank-weighted pick: rank r gets weight (n - r).
        let n = self.elites.len();
        let total: usize = (1..=n).sum();
        let mut ticket = rng.below(total);
        for (r, e) in self.elites.iter().enumerate() {
            let w = n - r;
            if ticket < w {
                return Some(e.clone());
            }
            ticket -= w;
        }
        self.elites.first().cloned()
    }

    fn history(&self, k: usize) -> Vec<Candidate> {
        self.elites.iter().take(k).cloned().collect()
    }

    fn best(&self) -> Option<Candidate> {
        self.elites.first().cloned()
    }

    fn name(&self) -> &'static str {
        "elite"
    }

    fn snapshot(&self) -> Box<dyn Population> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_candidate;
    use super::*;

    #[test]
    fn truncates_to_capacity_best_first() {
        let mut p = Elite::new(3);
        for (i, s) in [1.0, 5.0, 2.0, 4.0, 3.0].iter().enumerate() {
            let mut c = test_candidate(*s, true, i);
            c.src = format!("src {i}");
            p.insert(c);
        }
        let h: Vec<f64> = p.history(10).iter().map(|c| c.speedup).collect();
        assert_eq!(h, vec![5.0, 4.0, 3.0]);
    }

    #[test]
    fn duplicates_not_inserted() {
        let mut p = Elite::new(4);
        let c = test_candidate(2.0, true, 0);
        p.insert(c.clone());
        p.insert(c);
        assert_eq!(p.elites().len(), 1);
    }

    #[test]
    fn parent_prefers_high_rank() {
        let mut p = Elite::new(4);
        for (i, s) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            let mut c = test_candidate(*s, true, i);
            c.src = format!("src {i}");
            p.insert(c);
        }
        let mut rng = Rng::new(9);
        let mut hits_best = 0;
        for _ in 0..1000 {
            if p.parent(&mut rng).unwrap().speedup == 4.0 {
                hits_best += 1;
            }
        }
        // weight 4/10 = 0.4 expected
        assert!((300..500).contains(&hits_best), "{hits_best}");
    }

    #[test]
    fn invalid_only_population_offers_last() {
        let mut p = Elite::new(2);
        let mut rng = Rng::new(3);
        p.insert(test_candidate(9.0, false, 7));
        assert!(p.best().is_none());
        assert_eq!(p.parent(&mut rng).unwrap().trial, 7);
    }
}
