//! Island (diversity-maintenance) strategy: FunSearch's population
//! model (paper §A.4: "for FunSearch, we set the number of islands to
//! 5"). Each island is a small independent elite pool; sampling
//! round-robins across islands, and periodically the worst island is
//! reset and reseeded from the best island's champion — FunSearch's
//! island-reset mechanism.

use super::elite::Elite;
use super::{Candidate, Population};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct Islands {
    islands: Vec<Elite>,
    /// Which island receives the next insert / supplies the next parent.
    cursor: usize,
    inserts: usize,
    reset_every: usize,
    /// Most recent insert (fallback parent while islands are empty).
    last: Option<Candidate>,
}

impl Islands {
    pub fn new(n_islands: usize, per_island: usize, reset_every: usize) -> Self {
        assert!(n_islands > 0);
        Self {
            islands: (0..n_islands).map(|_| Elite::new(per_island)).collect(),
            cursor: 0,
            inserts: 0,
            reset_every: reset_every.max(1),
            last: None,
        }
    }

    /// FunSearch defaults from the paper's parameter setting.
    pub fn funsearch() -> Self {
        Self::new(5, 2, 15)
    }

    pub fn n_islands(&self) -> usize {
        self.islands.len()
    }

    fn island_best_fitness(&self, i: usize) -> f64 {
        self.islands[i].best().map(|c| c.fitness()).unwrap_or(0.0)
    }

    fn reset_worst(&mut self) {
        let (mut worst, mut best) = (0usize, 0usize);
        for i in 0..self.islands.len() {
            if self.island_best_fitness(i) < self.island_best_fitness(worst) {
                worst = i;
            }
            if self.island_best_fitness(i) > self.island_best_fitness(best) {
                best = i;
            }
        }
        if worst == best {
            return;
        }
        let seed = self.islands[best].best();
        let cap = self.islands[worst].elites().len().max(2);
        self.islands[worst] = Elite::new(cap);
        if let Some(champ) = seed {
            self.islands[worst].insert(champ);
        }
    }
}

impl Population for Islands {
    fn insert(&mut self, cand: Candidate) {
        self.last = Some(cand.clone());
        self.islands[self.cursor].insert(cand);
        self.inserts += 1;
        if self.inserts % self.reset_every == 0 {
            self.reset_worst();
        }
    }

    fn parent(&mut self, rng: &mut Rng) -> Option<Candidate> {
        // Advance to the next island (round-robin sampling). Islands
        // that have not received programs yet fall back to the global
        // champion (FunSearch seeds empty islands from the best).
        self.cursor = (self.cursor + 1) % self.islands.len();
        self.islands[self.cursor]
            .parent(rng)
            .or_else(|| self.best())
            .or_else(|| self.last.clone())
    }

    fn history(&self, k: usize) -> Vec<Candidate> {
        // FunSearch prompts draw from the *current* island only.
        self.islands[self.cursor].history(k)
    }

    fn best(&self) -> Option<Candidate> {
        self.islands
            .iter()
            .filter_map(|i| i.best())
            .max_by(|a, b| a.fitness().partial_cmp(&b.fitness()).unwrap())
    }

    fn name(&self) -> &'static str {
        "islands"
    }

    fn snapshot(&self) -> Box<dyn Population> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_candidate;
    use super::*;

    #[test]
    fn best_spans_islands() {
        let mut p = Islands::new(3, 2, 100);
        let mut rng = Rng::new(1);
        for i in 0..6 {
            let mut c = test_candidate(i as f64 + 1.0, true, i);
            c.src = format!("src {i}");
            let _ = p.parent(&mut rng); // rotate cursor like the real loop
            p.insert(c);
        }
        assert_eq!(p.best().unwrap().speedup, 6.0);
    }

    #[test]
    fn reset_reseeds_worst_island() {
        let mut p = Islands::new(2, 2, 4);
        let mut rng = Rng::new(2);
        // island rotation: insert strong candidates into one island,
        // weak into the other.
        for i in 0..4 {
            let _ = p.parent(&mut rng);
            let speed = if p.cursor == 0 { 10.0 } else { 1.0 };
            let mut c = test_candidate(speed, true, i);
            c.src = format!("src {i} {speed}");
            p.insert(c);
        }
        // after reset_every inserts, the weak island contains the champion
        let champs: Vec<f64> = p
            .islands
            .iter()
            .filter_map(|i| i.best().map(|c| c.speedup))
            .collect();
        assert!(champs.contains(&10.0));
        assert_eq!(champs.len(), 2);
        assert!(champs.iter().all(|&s| s == 10.0), "{champs:?}");
    }

    #[test]
    fn funsearch_shape() {
        let p = Islands::funsearch();
        assert_eq!(p.n_islands(), 5);
    }
}
