//! Analytical RTX-4090 cost model — the substitute for the paper's
//! wall-clock kernel timing (DESIGN.md §2).
//!
//! The paper measures real CUDA kernels on an RTX 4090; we price a
//! candidate's *schedule* against a roofline model of the same card.
//! What must be preserved for the reproduction to be meaningful is the
//! *search landscape*, not absolute nanoseconds:
//!
//! * improvements are available but non-obvious (tile reuse, vector
//!   width, layout/coalescing, occupancy, pipelining interact);
//! * the landscape is family-dependent (GEMM-like ops reward data
//!   reuse; element-wise ops only reward bandwidth efficiency and
//!   fusion; cumulative ops are serial-limited — the paper's own
//!   category-6 observation);
//! * unfused composite ops pay eager-PyTorch-style extra passes and
//!   launches, which is where the paper's >10x wins live;
//! * measurements are noisy (the paper's §A.7 stochasticity threat),
//!   modeled as lognormal noise on every timing event.
//!
//! Dataset tensors are deliberately small (they must execute on
//! CPU-PJRT for functional truth), so the model prices each op at a
//! *deployment scale*: the dataset shape batch-tiled to ~4M outputs
//! (`work_scale`), matching the magnitude of KernelBench workloads.

pub mod gpu;
pub mod price;

pub use gpu::Gpu;
pub use price::{baseline_schedule, price, price_baseline, price_pytorch, BoundKind, Timing};

use crate::tasks::OpTask;
use crate::util::Rng;

/// Deployment batch-tiling factor (see module docs).
pub fn work_scale(task: &OpTask) -> f64 {
    let out = task.out_numel().max(1) as f64;
    (4.0 * 1024.0 * 1024.0 / out).clamp(1.0, 8192.0)
}

/// One noisy timing measurement: median of `runs` lognormal draws,
/// collapsed analytically (median of n lognormal(sigma) samples is
/// lognormal with sigma ~ 1.2533 * sigma / sqrt(n)).
pub fn measure(true_time: f64, runs: usize, rng: &mut Rng) -> f64 {
    let sigma = gpu::MEASURE_SIGMA * 1.2533 / (runs.max(1) as f64).sqrt();
    true_time * rng.lognormal(sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::TaskRegistry;

    fn reg() -> TaskRegistry {
        TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap()
    }

    #[test]
    fn work_scale_inversely_proportional() {
        let reg = reg();
        let small = reg.get("mse_64").unwrap(); // (1,1) output
        let big = reg.get("relu_big").unwrap(); // 32768 outputs
        assert!(work_scale(small) > work_scale(big));
        assert_eq!(work_scale(small), 8192.0);
    }

    #[test]
    fn measurement_noise_is_small_for_many_runs() {
        let mut rng = Rng::new(1);
        let t = 1e-3;
        for _ in 0..100 {
            let m = measure(t, 100, &mut rng);
            assert!((m / t - 1.0).abs() < 0.05, "{m}");
        }
    }

    #[test]
    fn noise_shrinks_with_runs() {
        let mut rng = Rng::new(2);
        let spread = |runs: usize, rng: &mut Rng| -> f64 {
            let xs: Vec<f64> = (0..500).map(|_| measure(1.0, runs, rng)).collect();
            let m = crate::util::mean(&xs);
            xs.iter().map(|x| (x - m).abs()).sum::<f64>() / xs.len() as f64
        };
        assert!(spread(100, &mut rng) < spread(1, &mut rng));
    }
}
