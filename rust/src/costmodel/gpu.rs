//! RTX 4090 (AD102, sm_89) hardware model — the card from the paper's
//! §A.2 experimental setup.

/// Hardware description used by the pricing model.
#[derive(Debug, Clone)]
pub struct Gpu {
    /// FP32 peak throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM/GDDR bandwidth (B/s).
    pub mem_bw: f64,
    /// Streaming multiprocessors.
    pub sms: u32,
    /// Max resident threads per SM.
    pub max_threads_per_sm: u32,
    /// Register file per SM (32-bit registers).
    pub regs_per_sm: u32,
    /// Shared memory per SM (bytes).
    pub smem_per_sm: u64,
    /// Kernel launch overhead (seconds).
    pub launch_overhead: f64,
}

impl Gpu {
    /// NVIDIA RTX 4090: 16384 cores @ ~2.52 GHz boost → 82.6 TFLOP/s
    /// FP32; 24 GB GDDR6X @ 1008 GB/s; 128 SMs; ~3 µs launch overhead
    /// (paper §A.2: "CPU performance directly impacts kernel launch
    /// overhead").
    pub fn rtx4090() -> Self {
        Gpu {
            peak_flops: 82.6e12,
            mem_bw: 1008.0e9,
            sms: 128,
            max_threads_per_sm: 1536,
            regs_per_sm: 65536,
            smem_per_sm: 100 * 1024,
            launch_overhead: 3.0e-6,
        }
    }

    /// Roofline ridge point (FLOP/byte): below this, memory-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }
}

/// Per-measurement lognormal noise sigma (the paper's §A.7
/// "stochasticity of performance measurement": clocks, cache state,
/// system load). ~3% single-run spread matches typical 4090 jitter.
pub const MEASURE_SIGMA: f64 = 0.03;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_point_is_sane() {
        let g = Gpu::rtx4090();
        // 4090 ridge ~ 82 FLOP/B
        assert!((g.ridge() - 82.0).abs() < 5.0, "{}", g.ridge());
    }
}
