//! Candidate pricing: schedule × workload → modeled RTX-4090 time.

use crate::dsl::{Layout, Schedule};
use crate::tasks::OpTask;

use super::gpu::Gpu;
use super::work_scale;

/// Which roofline wall the kernel sits against (reported back to the
/// search as profiling feedback, like the paper's AI-CUDA-Engineer
/// profiling prompts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundKind {
    Compute,
    Memory,
    Launch,
}

/// Full pricing breakdown for one candidate on one op.
#[derive(Debug, Clone)]
pub struct Timing {
    /// End-to-end modeled time (seconds), noise-free.
    pub time: f64,
    pub t_compute: f64,
    pub t_mem: f64,
    pub t_overhead: f64,
    /// HBM traffic after reuse modeling (bytes).
    pub traffic: f64,
    /// Achieved occupancy (0..1].
    pub occupancy: f64,
    pub eff_compute: f64,
    pub eff_bw: f64,
    pub launches: u32,
    pub bound: BoundKind,
}

fn geomean(a: f64, b: f64) -> f64 {
    (a * b).sqrt()
}

/// Occupancy: resident blocks limited by threads, shared memory and
/// register file — the classic CUDA occupancy calculation.
fn occupancy(s: &Schedule, gpu: &Gpu) -> f64 {
    let by_threads = gpu.max_threads_per_sm / s.threads_per_block.max(1);
    let by_regs = gpu.regs_per_sm / (s.regs_per_thread.max(1) * s.threads_per_block.max(1));
    let by_smem = if s.smem_bytes() > 0 {
        (gpu.smem_per_sm / s.smem_bytes()) as u32
    } else {
        u32::MAX
    };
    let blocks = by_threads.min(by_regs).min(by_smem).max(0);
    if blocks == 0 {
        return 0.05; // one straggler block via fallback carve-out
    }
    ((blocks * s.threads_per_block) as f64 / gpu.max_threads_per_sm as f64).min(1.0)
}

/// Families whose landscape rewards on-chip data reuse (GEMM-like).
fn is_reuse_family(task: &OpTask) -> bool {
    matches!(task.family.as_str(), "matmul" | "conv")
}

/// Effective memory bandwidth fraction for this schedule.
fn bw_efficiency(s: &Schedule, task: &OpTask, occ: f64) -> f64 {
    // Vector packing: float1 load streams hit ~55% of peak; float4/8
    // saturate the memory pipes.
    let vw = (s.vector_width as f64).log2(); // 0,1,2,3
    let mut eff = 0.55 + 0.15 * vw;
    // Coalescing: row-major traversal matches the last-axis layout of
    // every dataset op; col-major strides kill coalescing for
    // element-wise/rowwise ops, GEMM tolerates it via staging.
    eff *= match (s.layout, is_reuse_family(task)) {
        (Layout::RowMajor, _) => 1.0,
        (Layout::Tiled, true) => 1.02,
        (Layout::Tiled, false) => 0.92,
        (Layout::ColMajor, true) => 0.85,
        (Layout::ColMajor, false) => 0.50,
    };
    // Latency hiding needs parallelism.
    eff *= 0.55 + 0.45 * occ;
    // Register spill writes back through memory.
    if s.est_registers() > s.regs_per_thread {
        eff *= 0.75;
    }
    // Cumulative ops (paper Table 5: "sequence dependent, hard to
    // parallelize"): a naive kernel walks the carry chain serially and
    // crawls; a staged block scan (Blelloch through shared memory)
    // unlocks reasonable bandwidth but still trails other families.
    // This is why the paper's category-6 speedups are all-or-nothing.
    if task.family == "scan" {
        if s.smem_staging && s.stages >= 2 && s.vector_width >= 4 {
            // Fully staged, pipelined, vectorized block scan.
            eff = eff.min(0.60);
        } else if s.smem_staging {
            // Staged but the carry chain still stalls the pipeline.
            eff = eff.min(0.16);
        } else {
            eff *= 0.06;
        }
    }
    // Interaction: tiled staging layouts only pay off when operands
    // are actually staged.
    if s.layout == Layout::Tiled && !s.smem_staging {
        eff *= 0.85;
    }
    eff.clamp(0.02, 0.97)
}

/// Effective compute fraction (MXU/FMA pipes) for this schedule.
fn compute_efficiency(s: &Schedule, task: &OpTask, occ: f64) -> f64 {
    let mut eff: f64 = 0.45;
    // Tensor-core-friendly tiles: multiples of 16 map onto MMA shapes.
    if s.tile_m % 16 == 0 && s.tile_n % 16 == 0 {
        eff *= 1.25;
    } else if s.tile_m < 16 || s.tile_n < 16 {
        eff *= 0.7 + 0.3 * (s.tile_m.min(s.tile_n) as f64 / 16.0);
    }
    // Software pipelining hides operand latency once staged.
    eff *= match s.stages {
        1 => 0.80,
        2 => 1.00,
        3 => 1.03,
        _ => 0.97,
    };
    // Moderate unrolling feeds the pipes; extremes thrash the icache.
    eff *= match s.unroll {
        1 => 0.88,
        2..=4 => 1.0,
        5..=8 => 0.97,
        _ => 0.88,
    };
    eff *= 0.5 + 0.5 * occ;
    if s.est_registers() > s.regs_per_thread {
        eff *= 0.55; // spill
    }
    if task.family == "scan" {
        eff = if s.smem_staging { eff.min(0.25) } else { eff.min(0.04) };
    }
    eff.clamp(0.02, 0.92)
}

/// HBM traffic after data-reuse modeling.
fn traffic_bytes(s: &Schedule, task: &OpTask, base_bytes: f64) -> f64 {
    if !is_reuse_family(task) {
        return base_bytes;
    }
    // GEMM-like ops re-read operand panels once per output tile; the
    // re-read factor shrinks with the staged tile footprint
    // (the CUDA-smem / TPU-VMEM blocking identity).
    const REUSE_COEF: f64 = 8.0;
    let reuse = if s.smem_staging {
        geomean(s.tile_m as f64, s.tile_n as f64).max(1.0)
    } else {
        // Register-only blocking caps out quickly.
        (s.tile_m.min(s.tile_n) as f64).min(4.0).max(1.0)
    };
    base_bytes * (1.0 + REUSE_COEF / reuse)
}

/// Price a candidate schedule on an op.
pub fn price(s: &Schedule, task: &OpTask, gpu: &Gpu) -> Timing {
    let scale = work_scale(task);
    let flops = task.flops * scale;
    let base_bytes = task.bytes_moved * scale;

    let occ = occupancy(s, gpu);
    let eff_bw = bw_efficiency(s, task, occ);
    let eff_c = compute_efficiency(s, task, occ);
    let traffic = traffic_bytes(s, task, base_bytes);

    let t_compute = flops / (gpu.peak_flops * eff_c);
    let t_mem = traffic / (gpu.mem_bw * eff_bw);
    // Roofline with mild overlap slack.
    let mut t_kernel = t_compute.max(t_mem) + 0.25 * t_compute.min(t_mem);

    // Unfused composite ops replay the eager multi-pass pattern.
    let mut launches = 1u32;
    if !s.fuse_epilogue && task.pt_launches > 1 {
        let extra_passes = (task.pt_passes - 1.0).max(0.0);
        t_kernel += extra_passes * base_bytes / (gpu.mem_bw * eff_bw);
        launches = task.pt_launches;
    }

    let t_overhead = launches as f64 * gpu.launch_overhead;
    let time = t_kernel + t_overhead;

    let bound = if t_overhead > t_kernel {
        BoundKind::Launch
    } else if t_compute > t_mem {
        BoundKind::Compute
    } else {
        BoundKind::Memory
    };

    Timing {
        time,
        t_compute,
        t_mem,
        t_overhead,
        traffic,
        occupancy: occ,
        eff_compute: eff_c,
        eff_bw,
        launches,
        bound,
    }
}

/// The initial kernel shipped with each dataset op (paper §5.1: "an
/// initial C++/CUDA implementation to serve as the starting point").
///
/// Real starting kernels vary in quality — some ops ship near-optimal
/// code (nothing for the search to find, which is why the paper's
/// per-category Speedup Counts sit below the op counts), some ship
/// mediocre code, some are naive. The tier is a deterministic function
/// of the op name, so every method/model/seed faces the same starting
/// point for the same op, exactly like the fixed dataset in the paper.
pub fn baseline_schedule(task: &OpTask) -> Schedule {
    let mut rng = crate::util::Rng::new(0xBA5E_11E5).derive(&task.name);
    let tier = rng.f64();
    // Convolutions mostly ship decent initial kernels (the paper's
    // category-2 medians hover near 1.1x); cumulative ops ship naive
    // serial scans (the paper's category-6 medians explode to 10-38x
    // when a method finds the staged scan).
    let (p_good, p_med) = match task.category {
        2 => (0.45, 0.40),
        6 => (0.0, 0.0),
        _ => (0.25, 0.45),
    };
    let gemm_like = matches!(task.family.as_str(), "matmul" | "conv");
    let mut s = Schedule::default();
    if tier < p_good {
        // Near-optimal. Half of these are effectively at the roofline
        // already (vw 8, big staged tiles) — the search can find
        // nothing better, which is what keeps the paper's Speedup
        // Counts below the op counts; the other half leave a small
        // vectorization gap.
        let fully_tuned = rng.chance(0.5);
        s.vector_width = if fully_tuned { 8 } else { 4 };
        s.fuse_epilogue = true;
        s.threads_per_block = 256;
        s.unroll = 2;
        if gemm_like {
            s.smem_staging = true;
            s.stages = 2;
            let t = if fully_tuned { 64 } else { 32 };
            s.tile_m = t;
            s.tile_n = t;
            s.tile_k = 32.min(t);
            s.layout = Layout::Tiled;
        }
    } else if tier < p_good + p_med {
        // Mediocre: some vectorization, no staging/fusion.
        s.vector_width = 2;
        s.threads_per_block = 256;
        if gemm_like {
            s.tile_m = 16;
            s.tile_n = 16;
        }
    }
    s
}

/// Baseline timing: the tiered initial kernel priced like any other.
pub fn price_baseline(task: &OpTask, gpu: &Gpu) -> Timing {
    price(&baseline_schedule(task), task, gpu)
}

/// Modeled eager-PyTorch library time (cuBLAS/cuDNN-backed primitives
/// plus one launch per primitive) — the Figure-5 / Table-7 baseline.
pub fn price_pytorch(task: &OpTask, gpu: &Gpu) -> f64 {
    let scale = work_scale(task);
    let flops = task.flops * scale;
    let bytes = task.bytes_moved * scale;
    let eff = task.pt_efficiency.max(0.05);
    let t_mem = task.pt_passes * bytes / (gpu.mem_bw * eff);
    let t_compute = flops / (gpu.peak_flops * eff);
    t_mem.max(t_compute) * task.algo_penalty
        + task.pt_launches as f64 * gpu.launch_overhead
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::TaskRegistry;

    fn reg() -> TaskRegistry {
        TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap()
    }

    fn tuned_matmul() -> Schedule {
        Schedule {
            tile_m: 64,
            tile_n: 64,
            tile_k: 32,
            vector_width: 4,
            unroll: 2,
            stages: 2,
            smem_staging: true,
            fuse_epilogue: true,
            layout: Layout::Tiled,
            threads_per_block: 256,
            regs_per_thread: 96,
            ..Schedule::default()
        }
    }

    #[test]
    fn tuned_beats_naive_on_matmul() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("matmul_128").unwrap();
        let naive = price(&Schedule::default(), task, &gpu);
        let tuned = price(&tuned_matmul(), task, &gpu);
        assert!(
            tuned.time < naive.time * 0.7,
            "tuned {:.3e} vs naive {:.3e}",
            tuned.time,
            naive.time
        );
    }

    #[test]
    fn fusion_helps_composite_ops() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("linear_silu_64").unwrap(); // 3 eager launches
        let mut unfused = Schedule::default();
        unfused.vector_width = 4;
        let mut fused = unfused.clone();
        fused.fuse_epilogue = true;
        assert!(price(&fused, task, &gpu).time < price(&unfused, task, &gpu).time);
    }

    #[test]
    fn scan_needs_staged_block_scan() {
        // Category 6: naive serial scan crawls; the staged (smem)
        // block scan unlocks a large all-or-nothing speedup — the
        // paper's category-6 signature.
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("cumsum_rows_64").unwrap();
        let naive = price_baseline(task, &gpu).time;
        let mut staged = Schedule::default();
        staged.smem_staging = true;
        staged.stages = 2;
        staged.vector_width = 4;
        let t_staged = price(&staged, task, &gpu).time;
        let ratio = naive / t_staged;
        assert!(ratio > 4.0, "staged scan should unlock a big win, got {ratio}");
        // Without staging, schedule tweaks barely move the needle.
        let mut unstaged = Schedule::default();
        unstaged.vector_width = 8;
        unstaged.threads_per_block = 256;
        let r2 = naive / price(&unstaged, task, &gpu).time;
        assert!(r2 < 2.0, "unstaged scan speedup should stay small, got {r2}");
    }

    #[test]
    fn baseline_tiers_are_deterministic_and_varied() {
        let reg = reg();
        let mut distinct = std::collections::HashSet::new();
        for op in &reg.ops {
            let a = baseline_schedule(op);
            let b = baseline_schedule(op);
            assert_eq!(a, b, "{} baseline must be stable", op.name);
            distinct.insert((a.vector_width, a.smem_staging, a.fuse_epilogue));
        }
        assert!(distinct.len() >= 3, "expected multiple baseline tiers");
        // cumulative ops always ship the naive serial scan
        for op in reg.by_category(6) {
            assert!(!baseline_schedule(op).smem_staging, "{}", op.name);
        }
    }

    #[test]
    fn vector_width_monotone_for_elementwise() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("relu_big").unwrap();
        let mut prev = f64::INFINITY;
        for vw in [1u32, 2, 4, 8] {
            let mut s = Schedule::default();
            s.vector_width = vw;
            let t = price(&s, task, &gpu).time;
            assert!(t <= prev, "vw={vw} slower");
            prev = t;
        }
    }

    #[test]
    fn col_major_hurts_elementwise() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("gelu_big").unwrap();
        let mut s = Schedule::default();
        let row = price(&s, task, &gpu).time;
        s.layout = Layout::ColMajor;
        assert!(price(&s, task, &gpu).time > row * 1.5);
    }

    #[test]
    fn spill_is_penalized() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("matmul_128").unwrap();
        let mut s = tuned_matmul();
        let good = price(&s, task, &gpu).time;
        s.regs_per_thread = 16; // force est_registers > budget
        assert!(price(&s, task, &gpu).time > good);
    }

    #[test]
    fn pytorch_hard_to_beat_on_dense_gemm() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("matmul_128").unwrap();
        let pt = price_pytorch(task, &gpu);
        let best = price(&tuned_matmul(), task, &gpu).time;
        let ratio = pt / best;
        assert!(
            (0.5..2.5).contains(&ratio),
            "dense GEMM vs cuBLAS should be near parity, got {ratio}"
        );
    }

    #[test]
    fn pytorch_beatable_on_unfused_chains() {
        let reg = reg();
        let gpu = Gpu::rtx4090();
        let task = reg.get("huber_64").unwrap(); // 5 eager launches
        let pt = price_pytorch(task, &gpu);
        let mut s = Schedule::default();
        s.vector_width = 8;
        s.fuse_epilogue = true;
        let best = price(&s, task, &gpu).time;
        assert!(pt / best > 2.0, "got {}", pt / best);
    }

    #[test]
    fn occupancy_in_range() {
        let gpu = Gpu::rtx4090();
        for tpb in [32u32, 128, 256, 1024] {
            let mut s = Schedule::default();
            s.threads_per_block = tpb;
            let o = occupancy(&s, &gpu);
            assert!((0.0..=1.0).contains(&o), "{o}");
        }
    }
}
