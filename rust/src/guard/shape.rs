//! Shape / rank inference for the stage-0 guard: derive the iteration-
//! space facts of an op from its [`ArgSpec`](crate::tasks::ArgSpec)s
//! and output spec, then check a candidate schedule against them.
//!
//! The discipline mirrors what a static CUDA checker can prove without
//! compiling: a block tile wider than the hardware tile quantum must
//! fit inside *some* operand axis, a vector load cannot be wider than
//! the largest operand, a zero-extent operand cannot be staged, and a
//! scalar (rank-0) output cannot be partitioned into more than one
//! tile. Everything here is a pure function of (schedule, op spec) —
//! same inputs, same diagnostics, in the same order.

use crate::dsl::{Layout, Schedule};
use crate::tasks::OpTask;

use super::{GuardCode, GuardDiagnostic};

/// Hardware tile quantum (lanes): tiles up to this extent are realizable
/// on any operand via masking/padding; beyond it the tile must fit an
/// actual operand axis. 64 = two sm_89 warps, the MMA macro-tile width —
/// also the padding quantum the AOT pipeline lowers shapes to, so the
/// shipped baseline kernels (which tile up to 64 regardless of op size)
/// always pass.
pub const TILE_QUANTUM: usize = 64;

/// Inferred iteration-space facts for one op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeFacts {
    /// Rank of the declared output.
    pub out_rank: usize,
    /// Total output elements (product of `out_shape`; 1 for rank 0).
    pub out_numel: usize,
    /// Largest axis extent across all args and the output (>= 1).
    pub max_extent: usize,
    /// Largest single-operand element count (0 when the op has no args).
    pub max_arg_numel: usize,
    /// Indices of args whose shape contains a zero extent.
    pub zero_args: Vec<usize>,
}

impl ShapeFacts {
    /// Largest tile extent a schedule may request for this op: the
    /// padded operand extent, floored at the hardware tile quantum.
    pub fn tile_bound(&self) -> usize {
        self.max_extent.next_power_of_two().max(TILE_QUANTUM)
    }
}

/// Infer [`ShapeFacts`] from an op's manifest entry.
pub fn infer(task: &OpTask) -> ShapeFacts {
    let mut max_extent = 1usize;
    let mut max_arg_numel = 0usize;
    let mut zero_args = Vec::new();
    for (i, arg) in task.args.iter().enumerate() {
        if arg.shape.iter().any(|&d| d == 0) {
            zero_args.push(i);
        }
        for &d in &arg.shape {
            max_extent = max_extent.max(d);
        }
        max_arg_numel = max_arg_numel.max(arg.numel());
    }
    for &d in &task.out_shape {
        max_extent = max_extent.max(d);
    }
    ShapeFacts {
        out_rank: task.out_shape.len(),
        out_numel: task.out_numel(),
        max_extent,
        max_arg_numel,
        zero_args,
    }
}

/// Shape-mismatch diagnostics: the schedule references more data than
/// the op's [`ArgSpec`]s declare.
pub fn shape_checks(s: &Schedule, task: &OpTask, facts: &ShapeFacts) -> Vec<GuardDiagnostic> {
    let mut out = Vec::new();
    for &i in &facts.zero_args {
        out.push(GuardDiagnostic {
            code: GuardCode::ShapeMismatch,
            field: format!("arg{i}"),
            message: format!(
                "argument {i} of `{}` has a zero-size shape {:?} — nothing to stage",
                task.name, task.args[i].shape
            ),
            hint: None,
        });
    }
    let bound = facts.tile_bound();
    for (name, val) in [
        ("tile_m", s.tile_m),
        ("tile_n", s.tile_n),
        ("tile_k", s.tile_k),
    ] {
        if val as usize > bound {
            out.push(GuardDiagnostic {
                code: GuardCode::ShapeMismatch,
                field: name.to_string(),
                message: format!(
                    "{name}={val} exceeds every operand extent of `{}` \
                     (largest axis {}, padded tile bound {bound})",
                    task.name, facts.max_extent
                ),
                hint: Some((
                    name.to_string(),
                    bound.min(crate::dsl::validate::MAX_TILE as usize).max(1).to_string(),
                )),
            });
        }
    }
    if !task.args.is_empty() && s.vector_width as usize > facts.max_arg_numel {
        out.push(GuardDiagnostic {
            code: GuardCode::ShapeMismatch,
            field: "vector_width".to_string(),
            message: format!(
                "vector_width={} is wider than the largest operand of `{}` ({} elements)",
                s.vector_width, task.name, facts.max_arg_numel
            ),
            hint: Some(("vector_width".to_string(), "1".to_string())),
        });
    }
    out
}

/// Output-spec diagnostics: the schedule's output partitioning is
/// incompatible with the declared `out_shape`.
pub fn output_checks(s: &Schedule, task: &OpTask, facts: &ShapeFacts) -> Vec<GuardDiagnostic> {
    let mut out = Vec::new();
    if facts.out_numel == 0 {
        out.push(GuardDiagnostic {
            code: GuardCode::OutputSpecViolation,
            field: "out".to_string(),
            message: format!(
                "`{}` declares a zero-element output {:?} — the kernel can produce nothing",
                task.name, task.out_shape
            ),
            hint: None,
        });
    }
    if facts.out_rank < 2 && s.layout == Layout::ColMajor {
        out.push(GuardDiagnostic {
            code: GuardCode::OutputSpecViolation,
            field: "layout".to_string(),
            message: format!(
                "col_major staging needs a second output axis, but `{}` has output rank {}",
                task.name, facts.out_rank
            ),
            hint: Some(("layout".to_string(), "row_major".to_string())),
        });
    }
    if facts.out_rank == 0 {
        for (name, val) in [("tile_m", s.tile_m), ("tile_n", s.tile_n)] {
            if val > 1 {
                out.push(GuardDiagnostic {
                    code: GuardCode::OutputSpecViolation,
                    field: name.to_string(),
                    message: format!(
                        "scalar (rank-0) output of `{}` cannot be partitioned: {name}={val}",
                        task.name
                    ),
                    hint: Some((name.to_string(), "1".to_string())),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::ArgSpec;

    fn task(args: Vec<Vec<usize>>, out: Vec<usize>) -> OpTask {
        OpTask {
            name: "synthetic".into(),
            category: 1,
            family: "x".into(),
            args: args
                .into_iter()
                .map(|shape| ArgSpec { shape, gen: "uniform".into() })
                .collect(),
            out_shape: out,
            flops: 1.0,
            bytes_moved: 1.0,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.5,
            algo_penalty: 1.0,
            atol: 1e-4,
            rtol: 1e-3,
            artifacts: Default::default(),
        }
    }

    #[test]
    fn facts_cover_args_and_output() {
        let t = task(vec![vec![64, 64], vec![64, 64]], vec![64, 64]);
        let f = infer(&t);
        assert_eq!(f.out_rank, 2);
        assert_eq!(f.out_numel, 4096);
        assert_eq!(f.max_extent, 64);
        assert_eq!(f.max_arg_numel, 4096);
        assert!(f.zero_args.is_empty());
        assert_eq!(f.tile_bound(), 64);
    }

    #[test]
    fn small_ops_keep_the_quantum_bound() {
        // conv2d-style op: extents 16, but the tile bound floors at the
        // hardware quantum so shipped 64-wide baselines stay legal.
        let t = task(vec![vec![8, 16, 16]], vec![8, 16, 16]);
        assert_eq!(infer(&t).tile_bound(), TILE_QUANTUM);
    }

    #[test]
    fn zero_extent_args_and_outputs_are_flagged() {
        let t = task(vec![vec![64, 0]], vec![0, 4]);
        let f = infer(&t);
        assert_eq!(f.zero_args, vec![0]);
        assert_eq!(f.out_numel, 0);
        let s = Schedule::default();
        assert!(shape_checks(&s, &t, &f)
            .iter()
            .any(|d| d.code == GuardCode::ShapeMismatch && d.field == "arg0"));
        assert!(output_checks(&s, &t, &f)
            .iter()
            .any(|d| d.code == GuardCode::OutputSpecViolation && d.field == "out"));
    }

    #[test]
    fn rank0_output_rules() {
        let t = task(vec![vec![64, 64]], vec![]);
        let f = infer(&t);
        assert_eq!(f.out_rank, 0);
        assert_eq!(f.out_numel, 1);
        let mut s = Schedule::default(); // tile 8x8
        let d = output_checks(&s, &t, &f);
        assert_eq!(d.len(), 2, "{d:?}"); // tile_m and tile_n both > 1
        assert!(d.iter().all(|x| x.code == GuardCode::OutputSpecViolation));
        s.tile_m = 1;
        s.tile_n = 1;
        assert!(output_checks(&s, &t, &f).is_empty());
        // col_major on a rank-0 output is also a violation.
        s.layout = Layout::ColMajor;
        assert_eq!(output_checks(&s, &t, &f).len(), 1);
    }

    #[test]
    fn oversized_tiles_are_shape_mismatches_with_hints() {
        let t = task(vec![vec![64, 64], vec![64, 64]], vec![64, 64]);
        let f = infer(&t);
        let mut s = Schedule::default();
        s.tile_m = 128; // legal per resource limits, too big for the op
        let d = shape_checks(&s, &t, &f);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].code, GuardCode::ShapeMismatch);
        assert_eq!(d[0].hint, Some(("tile_m".into(), "64".into())));
        s.tile_m = 64;
        assert!(shape_checks(&s, &t, &f).is_empty());
    }

    #[test]
    fn vector_width_wider_than_any_operand_is_flagged() {
        let t = task(vec![vec![2]], vec![2]);
        let f = infer(&t);
        let mut s = Schedule::default();
        s.vector_width = 4;
        let d = shape_checks(&s, &t, &f);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].hint, Some(("vector_width".into(), "1".into())));
    }
}
