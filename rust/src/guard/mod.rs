//! Stage-0 static validity guard (DESIGN.md §11).
//!
//! The paper's two-stage pipeline (§4.3) discovers invalidity only
//! *after* paying the most expensive step — a full compile per
//! candidate. Following the tiered-verification lesson of "Towards
//! Robust Agentic CUDA Kernel Benchmarking" (Lange et al., 2025) and
//! CUDA-LLM's front-loaded static checks, this module runs a pure
//! static pipeline over the candidate *before* anything touches the
//! compile gate or the PJRT runtime pool:
//!
//! 1. **Syntax** — lex/parse (the text must be a program at all);
//! 2. **Shadowed bindings** — duplicate schedule-field assignments
//!    (last-wins shadowing the parser silently accepts);
//! 3. **Undefined refs** — the kernel names an op other than the task
//!    under optimization, or a semantics variant with no artifact;
//! 4. **Non-terminating constructs** — zero-step loop controls (zero
//!    tiles / unroll / stages / threads) that can never make progress;
//! 5. **Shape mismatches** — schedule vs the op's [`ArgSpec`]s, via
//!    [`shape`] inference (oversized tiles, over-wide vector loads,
//!    zero-extent operands);
//! 6. **Output-spec violations** — output partitioning incompatible
//!    with the declared `out_shape` (rank/layout/tiling);
//! 7. **Resource limits** — every violated sm_89 limit from
//!    [`dsl::validate::schedule_violations`], exhaustively.
//!
//! The result is a [`GuardReport`]: an ordered list of structured
//! [`GuardDiagnostic`]s, each carrying a machine-readable code, the
//! offending field, a human message, and (where a targeted fix exists)
//! a repair hint the LLM repair loop ([`crate::llm::repair`]) can
//! apply. The whole check is a pure function of (source text, op spec):
//! same inputs produce byte-identical diagnostics in the same order,
//! which is what lets guard verdicts be journaled in the eval cache and
//! replayed bit-identically.
//!
//! [`ArgSpec`]: crate::tasks::ArgSpec

pub mod shape;

use std::fmt;

use crate::dsl::{self, lexer, validate, KernelSpec};
use crate::tasks::OpTask;

/// Machine-readable diagnostic class (the taxonomy of DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GuardCode {
    /// Lexer/parser rejection — the text is not a program.
    Syntax,
    /// Duplicate schedule-field assignment (last-wins shadowing).
    ShadowedBinding,
    /// Reference to an op or semantics variant that does not exist.
    UndefinedRef,
    /// Zero-step loop construct that can never terminate/progress.
    NonTerminating,
    /// Schedule references more data than the op's ArgSpecs declare.
    ShapeMismatch,
    /// Output partitioning incompatible with the declared out_shape.
    OutputSpecViolation,
    /// Hardware resource limit violated (sm_89 model).
    ResourceLimit,
}

impl GuardCode {
    pub fn as_str(self) -> &'static str {
        match self {
            GuardCode::Syntax => "syntax",
            GuardCode::ShadowedBinding => "shadowed_binding",
            GuardCode::UndefinedRef => "undefined_ref",
            GuardCode::NonTerminating => "non_terminating",
            GuardCode::ShapeMismatch => "shape_mismatch",
            GuardCode::OutputSpecViolation => "output_spec_violation",
            GuardCode::ResourceLimit => "resource_limit",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        Some(match s {
            "syntax" => GuardCode::Syntax,
            "shadowed_binding" => GuardCode::ShadowedBinding,
            "undefined_ref" => GuardCode::UndefinedRef,
            "non_terminating" => GuardCode::NonTerminating,
            "shape_mismatch" => GuardCode::ShapeMismatch,
            "output_spec_violation" => GuardCode::OutputSpecViolation,
            "resource_limit" => GuardCode::ResourceLimit,
            _ => return None,
        })
    }
}

impl fmt::Display for GuardCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One structured stage-0 finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GuardDiagnostic {
    pub code: GuardCode,
    /// Field/symbol the diagnostic anchors to ("" = whole program).
    pub field: String,
    pub message: String,
    /// Targeted repair: set `hint.0` to `hint.1` (`op` / `semantics` /
    /// a schedule field). `None` when no single-field fix exists.
    pub hint: Option<(String, String)>,
}

impl fmt::Display for GuardDiagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.field.is_empty() {
            write!(f, "[{}] {}", self.code, self.message)
        } else {
            write!(f, "[{}] {}: {}", self.code, self.field, self.message)
        }
    }
}

/// The guard's verdict for one candidate: empty = pass.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct GuardReport {
    pub diagnostics: Vec<GuardDiagnostic>,
}

impl GuardReport {
    pub fn pass(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// The diagnostics as the error text a repair prompt would carry.
    pub fn summary(&self) -> String {
        self.diagnostics
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Does any diagnostic carry this code?
    pub fn has(&self, code: GuardCode) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }
}

/// Stage-0 check of a raw candidate emission against `task`. Pure and
/// deterministic; never touches the compile gate or the runtime pool.
pub fn check_source(src: &str, task: &OpTask) -> GuardReport {
    let spec = match dsl::parse(src) {
        Ok(spec) => spec,
        Err(e) => {
            return GuardReport {
                diagnostics: vec![GuardDiagnostic {
                    code: GuardCode::Syntax,
                    field: String::new(),
                    message: format!("not a parseable program: {e}"),
                    hint: None,
                }],
            }
        }
    };
    let mut diagnostics = shadowed_bindings(src);
    diagnostics.extend(check_spec(&spec, task).diagnostics);
    GuardReport { diagnostics }
}

/// Stage-0 check of an already-parsed program (source-level checks —
/// syntax, shadowed bindings — are skipped).
pub fn check_spec(spec: &KernelSpec, task: &OpTask) -> GuardReport {
    let mut d = Vec::new();

    // --- undefined refs -------------------------------------------------
    if spec.op != task.name {
        d.push(GuardDiagnostic {
            code: GuardCode::UndefinedRef,
            field: "kernel".to_string(),
            message: format!(
                "kernel implements `{}` but the task under optimization is `{}`",
                spec.op, task.name
            ),
            hint: Some(("op".to_string(), task.name.clone())),
        });
    }
    if !task.artifacts.contains_key(&spec.semantics) {
        let hint = ["opt", "ref"]
            .iter()
            .find(|v| task.artifacts.contains_key(**v))
            .map(|v| ("semantics".to_string(), (*v).to_string()));
        d.push(GuardDiagnostic {
            code: GuardCode::UndefinedRef,
            field: "semantics".to_string(),
            message: format!(
                "undefined semantics variant `{}` (no such artifact for `{}`)",
                spec.semantics, task.name
            ),
            hint,
        });
    }

    // --- non-terminating constructs ------------------------------------
    let s = &spec.schedule;
    for (name, val, reset) in [
        ("tile_m", s.tile_m, "8"),
        ("tile_n", s.tile_n, "8"),
        ("tile_k", s.tile_k, "8"),
        ("unroll", s.unroll, "1"),
        ("stages", s.stages, "1"),
        ("threads_per_block", s.threads_per_block, "128"),
    ] {
        if val == 0 {
            d.push(GuardDiagnostic {
                code: GuardCode::NonTerminating,
                field: name.to_string(),
                message: format!(
                    "{name}=0 is a zero-step loop construct — the kernel can never make progress"
                ),
                hint: Some((name.to_string(), reset.to_string())),
            });
        }
    }

    // --- shape / output-spec inference ----------------------------------
    let facts = shape::infer(task);
    d.extend(shape::shape_checks(s, task, &facts));
    d.extend(shape::output_checks(s, task, &facts));

    // --- resource limits (exhaustive structured validate) ---------------
    for v in validate::schedule_violations(s) {
        // Zero-valued fields were already reported as non-terminating;
        // the duplicate range message adds no information.
        if matches!(v.kind, validate::ViolationKind::TileRange) && tile_value(s, v.field) == 0 {
            continue;
        }
        let hint = resource_hint(&v);
        d.push(GuardDiagnostic {
            code: GuardCode::ResourceLimit,
            field: v.field.to_string(),
            message: v.message,
            hint,
        });
    }

    GuardReport { diagnostics: d }
}

fn tile_value(s: &crate::dsl::Schedule, field: &str) -> u32 {
    match field {
        "tile_m" => s.tile_m,
        "tile_n" => s.tile_n,
        "tile_k" => s.tile_k,
        _ => 1,
    }
}

/// Targeted single-field fix for a resource violation, when one exists.
fn resource_hint(v: &validate::Violation) -> Option<(String, String)> {
    use validate::ViolationKind as K;
    let value = match v.kind {
        K::TileRange => validate::MAX_TILE.to_string(),
        K::VectorWidth => "4".to_string(),
        K::Unroll => "4".to_string(),
        K::Stages => "2".to_string(),
        K::StagingRequired => "true".to_string(),
        K::ThreadsPerBlock => "256".to_string(),
        K::RegsRange => "128".to_string(),
        // Multi-field rebalances: no single assignment fixes these.
        K::SmemOverflow | K::RegPressure => return None,
    };
    Some((v.field.to_string(), value))
}

/// Scan the schedule block for duplicate field assignments — bindings
/// the parser silently resolves last-wins, which almost always means
/// the emitter contradicted itself.
fn shadowed_bindings(src: &str) -> Vec<GuardDiagnostic> {
    let Ok(toks) = lexer::lex(src) else {
        return Vec::new(); // unparseable text is reported as Syntax
    };
    let mut out = Vec::new();
    let mut seen: Vec<&str> = Vec::new();
    let mut reported: Vec<&str> = Vec::new();
    let mut in_schedule = false;
    let mut i = 0;
    while i < toks.len() {
        match &toks[i].tok {
            lexer::Tok::Ident(name) if !in_schedule && name == "schedule" => {
                if matches!(toks.get(i + 1).map(|t| &t.tok), Some(lexer::Tok::LBrace)) {
                    in_schedule = true;
                    i += 2;
                    continue;
                }
            }
            lexer::Tok::RBrace if in_schedule => {
                in_schedule = false;
            }
            lexer::Tok::Ident(name) if in_schedule => {
                if matches!(toks.get(i + 1).map(|t| &t.tok), Some(lexer::Tok::Colon)) {
                    if seen.contains(&name.as_str()) {
                        if !reported.contains(&name.as_str()) {
                            reported.push(name.as_str());
                            out.push(GuardDiagnostic {
                                code: GuardCode::ShadowedBinding,
                                field: name.clone(),
                                message: format!(
                                    "schedule field `{name}` is assigned more than once \
                                     (the last assignment shadows the earlier ones)"
                                ),
                                hint: None,
                            });
                        }
                    } else {
                        seen.push(name.as_str());
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// [`check_source`] over a batch of candidates, fanned out across a
/// scoped worker pool. Results come back in input order, and because
/// `check_source` is a pure function of `(src, task)`, the reports are
/// *identical* — verdicts, diagnostic ordering, messages, hints — at
/// any worker count, including the sequential `workers <= 1` path
/// (`tests/guard_parallel.rs` proves this over every baseline op).
/// `workers == 0` sizes the pool from available parallelism.
pub fn check_batch(items: &[(&str, &OpTask)], workers: usize) -> Vec<GuardReport> {
    let workers = if workers == 0 {
        std::thread::available_parallelism().map_or(1, |n| n.get()).min(items.len().max(1))
    } else {
        workers.min(items.len().max(1))
    };
    if workers <= 1 || items.len() <= 1 {
        return items.iter().map(|(src, task)| check_source(src, task)).collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, GuardReport)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some((src, task)) = items.get(i) else { break };
                // A dropped receiver can't happen while we hold slots,
                // but a send error must not panic a worker.
                if tx.send((i, check_source(src, task))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<GuardReport>> = vec![None; items.len()];
        for (i, report) in rx {
            slots[i] = Some(report);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every index claimed exactly once"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{print, KernelSpec};
    use crate::tasks::{ArgSpec, OpTask};
    use std::collections::HashMap;

    fn task() -> OpTask {
        let mut artifacts = HashMap::new();
        for v in ["ref", "opt", "bug_scale", "bug_offset"] {
            artifacts.insert(v.to_string(), format!("matmul_64/{v}.hlo.txt"));
        }
        OpTask {
            name: "matmul_64".into(),
            category: 1,
            family: "matmul".into(),
            args: vec![
                ArgSpec { shape: vec![64, 64], gen: "uniform".into() },
                ArgSpec { shape: vec![64, 64], gen: "uniform".into() },
            ],
            out_shape: vec![64, 64],
            flops: 524288.0,
            bytes_moved: 49152.0,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.8,
            algo_penalty: 1.0,
            atol: 5e-4,
            rtol: 1e-3,
            artifacts,
        }
    }

    #[test]
    fn valid_baseline_passes() {
        let t = task();
        let report = check_source(&print(&KernelSpec::baseline("matmul_64")), &t);
        assert!(report.pass(), "{}", report.summary());
    }

    #[test]
    fn syntax_garbage_is_one_structured_diagnostic() {
        let report = check_source("__global__ void k() {}", &task());
        assert_eq!(report.diagnostics.len(), 1);
        assert_eq!(report.diagnostics[0].code, GuardCode::Syntax);
        assert!(report.has(GuardCode::Syntax));
    }

    #[test]
    fn undefined_refs_are_flagged_with_hints() {
        let t = task();
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.semantics = "turbo_v9".into();
        let report = check_spec(&spec, &t);
        assert!(report.has(GuardCode::UndefinedRef), "{}", report.summary());
        let diag = &report.diagnostics[0];
        assert_eq!(diag.field, "semantics");
        assert_eq!(diag.hint, Some(("semantics".into(), "opt".into())));

        let wrong_op = KernelSpec::baseline("softmax_64");
        let report = check_spec(&wrong_op, &t);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == GuardCode::UndefinedRef && d.field == "kernel"));
    }

    #[test]
    fn zero_step_constructs_are_non_terminating() {
        let t = task();
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.schedule.tile_k = 0;
        spec.schedule.unroll = 0;
        let report = check_spec(&spec, &t);
        let nt: Vec<&GuardDiagnostic> = report
            .diagnostics
            .iter()
            .filter(|d| d.code == GuardCode::NonTerminating)
            .collect();
        assert_eq!(nt.len(), 2, "{}", report.summary());
        // The zero values are not double-reported as tile-range limits.
        assert!(
            !report.diagnostics.iter().any(|d| d.code == GuardCode::ResourceLimit
                && d.field == "tile_k"),
            "{}",
            report.summary()
        );
        // unroll=0 still appears exactly once.
        assert_eq!(
            report.diagnostics.iter().filter(|d| d.field == "unroll").count(),
            2, // NonTerminating + the unroll range ResourceLimit
        );
    }

    #[test]
    fn resource_limits_collected_exhaustively() {
        let t = task();
        let mut spec = KernelSpec::baseline("matmul_64");
        spec.schedule.vector_width = 3;
        spec.schedule.threads_per_block = 100;
        let report = check_spec(&spec, &t);
        let rl: Vec<&GuardDiagnostic> = report
            .diagnostics
            .iter()
            .filter(|d| d.code == GuardCode::ResourceLimit)
            .collect();
        assert_eq!(rl.len(), 2, "{}", report.summary());
        assert_eq!(rl[0].hint, Some(("vector_width".into(), "4".into())));
        assert_eq!(rl[1].hint, Some(("threads_per_block".into(), "256".into())));
    }

    #[test]
    fn shadowed_bindings_detected_once_per_field() {
        let src = "kernel matmul_64 { semantics: opt; schedule { \
                   tile_m: 8; tile_m: 16; tile_m: 32; tile_n: 8; } }";
        let report = check_source(src, &task());
        let shadowed: Vec<&GuardDiagnostic> = report
            .diagnostics
            .iter()
            .filter(|d| d.code == GuardCode::ShadowedBinding)
            .collect();
        assert_eq!(shadowed.len(), 1, "{}", report.summary());
        assert_eq!(shadowed[0].field, "tile_m");
        // A clean program has none.
        assert!(check_source(
            "kernel matmul_64 { semantics: opt; schedule { tile_m: 8; tile_n: 8; } }",
            &task()
        )
        .pass());
    }

    #[test]
    fn diagnostics_are_stable() {
        // Same source → byte-identical diagnostic list, every time.
        let src = "kernel matmul_64 { semantics: turbo; schedule { \
                   tile_m: 8; tile_m: 16; vector_width: 3; unroll: 0; } }";
        let t = task();
        let a = check_source(src, &t);
        let b = check_source(src, &t);
        let c = check_source(src, &t);
        assert_eq!(a, b);
        assert_eq!(b, c);
        assert!(a.diagnostics.len() >= 4, "{}", a.summary());
    }

    #[test]
    fn check_batch_is_order_preserving_and_worker_count_invariant() {
        let t = task();
        let sources: Vec<String> = vec![
            print(&KernelSpec::baseline("matmul_64")),
            "__global__ void k() {}".into(),
            "kernel matmul_64 { semantics: turbo; schedule { tile_m: 8; tile_m: 16; } }".into(),
            "kernel matmul_64 { semantics: opt; schedule { tile_k: 0; } }".into(),
            print(&KernelSpec::baseline("softmax_64")),
        ];
        let items: Vec<(&str, &OpTask)> = sources.iter().map(|s| (s.as_str(), &t)).collect();
        let sequential: Vec<GuardReport> =
            items.iter().map(|(s, t)| check_source(s, t)).collect();
        for workers in [0usize, 1, 2, 4, 8] {
            assert_eq!(check_batch(&items, workers), sequential, "workers={workers}");
        }
        assert!(sequential[0].pass());
        assert!(sequential[1].has(GuardCode::Syntax));
        assert!(sequential[2].has(GuardCode::ShadowedBinding));
        // Empty batch is fine at any worker count.
        assert!(check_batch(&[], 4).is_empty());
    }

    #[test]
    fn code_roundtrip() {
        for code in [
            GuardCode::Syntax,
            GuardCode::ShadowedBinding,
            GuardCode::UndefinedRef,
            GuardCode::NonTerminating,
            GuardCode::ShapeMismatch,
            GuardCode::OutputSpecViolation,
            GuardCode::ResourceLimit,
        ] {
            assert_eq!(GuardCode::from_str(code.as_str()), Some(code));
        }
        assert_eq!(GuardCode::from_str("nope"), None);
    }
}
