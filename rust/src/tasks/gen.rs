//! Deterministic test-input generation (the paper's "five random test
//! cases", §4.3). Generator kinds mirror python/compile/model.py's
//! ArgSpec.gen; each (op, test-case index) pair gets its own derived
//! RNG stream so functional verdicts are reproducible and memoizable.

use crate::tasks::{ArgSpec, OpTask};
use crate::util::Rng;

/// Number of functional test cases per candidate (paper §4.3).
pub const NUM_TEST_CASES: usize = 5;

/// Generate one input tensor for `spec` from `rng`.
pub fn gen_arg(rng: &mut Rng, spec: &ArgSpec) -> Vec<f32> {
    let n = spec.numel();
    match spec.gen.as_str() {
        "positive" => (0..n).map(|_| rng.f32_range(0.1, 1.1)).collect(),
        "sign" => (0..n)
            .map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 })
            .collect(),
        "near_one" => (0..n).map(|_| rng.f32_range(0.8, 1.2)).collect(),
        "prob" => {
            let mut v: Vec<f32> = (0..n).map(|_| rng.f32_range(0.1, 1.0)).collect();
            normalize_rows(&mut v, last_dim(spec));
            v
        }
        "logprob" => {
            let mut v: Vec<f32> = (0..n).map(|_| rng.f32_range(0.1, 1.0)).collect();
            normalize_rows(&mut v, last_dim(spec));
            v.iter_mut().for_each(|x| *x = x.ln());
            v
        }
        // default: uniform in [-1, 1)
        _ => (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
    }
}

fn last_dim(spec: &ArgSpec) -> usize {
    *spec.shape.last().unwrap_or(&1)
}

fn normalize_rows(v: &mut [f32], cols: usize) {
    if cols == 0 {
        return;
    }
    for row in v.chunks_mut(cols) {
        let s: f32 = row.iter().sum();
        if s > 0.0 {
            row.iter_mut().for_each(|x| *x /= s);
        }
    }
}

/// All inputs for one functional test case of `op`.
///
/// The stream label makes the case reproducible from (op name, case
/// index) alone, independent of call order.
pub fn gen_case(op: &OpTask, case: usize) -> Vec<Vec<f32>> {
    let base = Rng::new(0xE70E_61EE).derive(&format!("inputs/{}/{case}", op.name));
    op.args
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let mut r = base.derive(&format!("arg{i}"));
            gen_arg(&mut r, a)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], gen: &str) -> ArgSpec {
        ArgSpec { shape: shape.to_vec(), gen: gen.to_string() }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        let v = gen_arg(&mut r, &spec(&[32, 32], "uniform"));
        assert_eq!(v.len(), 1024);
        assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn prob_rows_sum_to_one() {
        let mut r = Rng::new(2);
        let v = gen_arg(&mut r, &spec(&[8, 16], "prob"));
        for row in v.chunks(16) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "{s}");
            assert!(row.iter().all(|x| *x > 0.0));
        }
    }

    #[test]
    fn logprob_is_log_of_prob() {
        let mut r = Rng::new(3);
        let v = gen_arg(&mut r, &spec(&[4, 8], "logprob"));
        for row in v.chunks(8) {
            let s: f32 = row.iter().map(|x| x.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "{s}");
        }
    }

    #[test]
    fn sign_is_pm_one() {
        let mut r = Rng::new(4);
        let v = gen_arg(&mut r, &spec(&[100], "sign"));
        assert!(v.iter().all(|x| *x == 1.0 || *x == -1.0));
        assert!(v.iter().any(|x| *x == 1.0) && v.iter().any(|x| *x == -1.0));
    }

    #[test]
    fn near_one_bounds() {
        let mut r = Rng::new(5);
        let v = gen_arg(&mut r, &spec(&[64], "near_one"));
        assert!(v.iter().all(|x| (0.8..1.2).contains(x)));
    }
}
