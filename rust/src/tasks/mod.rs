//! The 91-operation dataset (paper §5.1, Table 5): manifest loading,
//! category metadata, and deterministic input generation.
//!
//! The manifest is produced by `python -m compile.aot` (L2). It carries
//! the op inventory, per-variant HLO artifact paths, input shapes with
//! generator kinds, and the workload metadata the cost model prices.

pub mod gen;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as Context};

/// One kernel input: static shape + generator kind (mirrors ArgSpec).
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub gen: String,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One dataset operation (a row of the paper's 91-kernel dataset).
#[derive(Debug, Clone)]
pub struct OpTask {
    pub name: String,
    pub category: u8,
    pub family: String,
    pub args: Vec<ArgSpec>,
    pub out_shape: Vec<usize>,
    pub flops: f64,
    pub bytes_moved: f64,
    pub pt_launches: u32,
    pub pt_passes: f64,
    pub pt_efficiency: f64,
    pub algo_penalty: f64,
    pub atol: f64,
    pub rtol: f64,
    /// variant name -> HLO text path relative to the artifact dir
    pub artifacts: HashMap<String, String>,
}

impl OpTask {
    pub fn out_numel(&self) -> usize {
        self.out_shape.iter().product()
    }

    /// All semantic variants available for this op (sorted for
    /// determinism: bug_*, opt, ref).
    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Human label for Table-5-style output.
    pub fn category_name(&self) -> &'static str {
        category_name(self.category)
    }
}

pub fn category_name(cat: u8) -> &'static str {
    match cat {
        1 => "Matrix Multiplication",
        2 => "Convolution",
        3 => "Activation & Pooling",
        4 => "Normalization & Reduction",
        5 => "Loss Functions",
        6 => "Cumulative Operations",
        _ => "Unknown",
    }
}

fn need<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).ok_or_else(|| eyre!("manifest: missing key `{key}`"))
}

fn parse_op(v: &Json) -> Result<OpTask> {
    let name = need(v, "name")?.as_str().unwrap_or_default().to_string();
    // A missing or malformed category must be a load error, not a
    // silent `0`: ops with category outside 1..=6 vanish from every
    // per-category table (metrics iterate 1..=6) and would corrupt the
    // Table-4/5 denominators without anyone noticing.
    let category = need(v, "category")
        .and_then(|c| c.as_u64().ok_or_else(|| eyre!("category is not an integer")))
        .and_then(|c| {
            if (1..=6).contains(&c) {
                Ok(c as u8)
            } else {
                Err(eyre!("category {c} is outside 1..=6"))
            }
        })
        .with_context(|| format!("manifest: op `{name}` has a missing or invalid category"))?;
    let args = need(v, "args")?
        .as_arr()
        .ok_or_else(|| eyre!("args not an array"))?
        .iter()
        .map(|a| -> Result<ArgSpec> {
            Ok(ArgSpec {
                shape: need(a, "shape")?
                    .as_arr()
                    .ok_or_else(|| eyre!("shape not an array"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                gen: need(a, "gen")?.as_str().unwrap_or("uniform").to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let artifacts = match need(v, "artifacts")? {
        Json::Obj(m) => m
            .iter()
            .map(|(k, p)| (k.clone(), p.as_str().unwrap_or_default().to_string()))
            .collect(),
        _ => return Err(eyre!("artifacts not an object")),
    };
    Ok(OpTask {
        name,
        category,
        family: need(v, "family")?.as_str().unwrap_or_default().to_string(),
        args,
        out_shape: need(v, "out_shape")?
            .as_arr()
            .ok_or_else(|| eyre!("out_shape not an array"))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect(),
        flops: need(v, "flops")?.as_f64().unwrap_or(0.0),
        bytes_moved: need(v, "bytes_moved")?.as_f64().unwrap_or(0.0),
        pt_launches: need(v, "pt_launches")?.as_u64().unwrap_or(1) as u32,
        pt_passes: need(v, "pt_passes")?.as_f64().unwrap_or(1.0),
        pt_efficiency: need(v, "pt_efficiency")?.as_f64().unwrap_or(0.8),
        algo_penalty: need(v, "algo_penalty")?.as_f64().unwrap_or(1.0),
        atol: need(v, "atol")?.as_f64().unwrap_or(5e-4),
        rtol: need(v, "rtol")?.as_f64().unwrap_or(1e-3),
        artifacts,
    })
}

/// The loaded dataset: ops in manifest order plus name index.
#[derive(Debug, Clone)]
pub struct TaskRegistry {
    pub root: PathBuf,
    pub ops: Vec<OpTask>,
    index: HashMap<String, usize>,
}

impl TaskRegistry {
    /// Load `<dir>/manifest.json` produced by `make artifacts`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let data = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc = json::parse(&data).map_err(|e| eyre!("parsing manifest: {e}"))?;
        let version = need(&doc, "version")?.as_u64().unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let ops = need(&doc, "ops")?
            .as_arr()
            .ok_or_else(|| eyre!("ops not an array"))?
            .iter()
            .map(parse_op)
            .collect::<Result<Vec<_>>>()?;
        let index = ops
            .iter()
            .enumerate()
            .map(|(i, o)| (o.name.clone(), i))
            .collect();
        Ok(Self { root, ops, index })
    }

    pub fn get(&self, name: &str) -> Option<&OpTask> {
        self.index.get(name).map(|&i| &self.ops[i])
    }

    pub fn by_category(&self, cat: u8) -> Vec<&OpTask> {
        self.ops.iter().filter(|o| o.category == cat).collect()
    }

    /// Absolute path of an op's variant artifact.
    pub fn artifact_path(&self, op: &OpTask, variant: &str) -> Option<PathBuf> {
        op.artifacts.get(variant).map(|rel| self.root.join(rel))
    }

    /// Category -> count, for the Table-5 report.
    pub fn category_counts(&self) -> Vec<(u8, usize)> {
        let mut counts = [0usize; 7];
        for op in &self.ops {
            counts[op.category as usize] += 1;
        }
        (1..=6).map(|c| (c as u8, counts[c])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_manifest() {
        let reg = TaskRegistry::load(artifacts_dir()).unwrap();
        assert_eq!(reg.ops.len(), 91);
        assert_eq!(
            reg.category_counts(),
            vec![(1, 18), (2, 28), (3, 21), (4, 14), (5, 6), (6, 4)]
        );
    }

    #[test]
    fn op_lookup_and_variants() {
        let reg = TaskRegistry::load(artifacts_dir()).unwrap();
        let op = reg.get("matmul_64").expect("matmul_64");
        assert_eq!(op.category, 1);
        assert_eq!(op.out_shape, vec![64, 64]);
        let vs = op.variants();
        for needed in ["ref", "opt", "bug_scale", "bug_offset"] {
            assert!(vs.contains(&needed), "{needed} missing: {vs:?}");
        }
        let p = reg.artifact_path(op, "ref").unwrap();
        assert!(p.exists(), "{p:?}");
        assert_eq!(op.args.len(), 2);
        assert_eq!(op.args[0].shape, vec![64, 64]);
    }

    #[test]
    fn metadata_sane() {
        let reg = TaskRegistry::load(artifacts_dir()).unwrap();
        for op in &reg.ops {
            assert!(op.flops > 0.0, "{}", op.name);
            assert!(op.bytes_moved > 0.0, "{}", op.name);
            assert!(op.pt_launches >= 1, "{}", op.name);
            assert!((0.0..=1.0).contains(&op.pt_efficiency), "{}", op.name);
            assert!(op.algo_penalty >= 1.0, "{}", op.name);
            assert!(!op.args.is_empty(), "{}", op.name);
            assert!(op.atol > 0.0 && op.rtol > 0.0, "{}", op.name);
        }
    }

    fn op_json(category: &str) -> String {
        format!(
            r#"{{"name": "weird_op", "category": {category}, "family": "x",
                 "args": [{{"shape": [4], "gen": "uniform"}}], "out_shape": [4],
                 "flops": 1.0, "bytes_moved": 1.0, "pt_launches": 1,
                 "pt_passes": 1.0, "pt_efficiency": 0.5, "algo_penalty": 1.0,
                 "atol": 0.0001, "rtol": 0.0001,
                 "artifacts": {{"ref": "weird_op/ref.hlo.txt"}}}}"#
        )
    }

    #[test]
    fn out_of_range_category_is_a_load_error_naming_the_op() {
        for bad in ["0", "7", "200"] {
            let doc = json::parse(&op_json(bad)).unwrap();
            let err = parse_op(&doc).expect_err(bad);
            let msg = format!("{err:#}");
            assert!(msg.contains("weird_op"), "{msg}");
            assert!(msg.contains("category"), "{msg}");
        }
    }

    #[test]
    fn malformed_category_is_a_load_error_naming_the_op() {
        let doc = json::parse(&op_json("\"three\"")).unwrap();
        let err = parse_op(&doc).expect_err("string category");
        let msg = format!("{err:#}");
        assert!(msg.contains("weird_op"), "{msg}");
        // Missing entirely: same treatment.
        let doc = json::parse(&op_json("1").replacen("\"category\": 1,", "", 1)).unwrap();
        let err = parse_op(&doc).expect_err("missing category");
        let msg = format!("{err:#}");
        assert!(msg.contains("weird_op"), "{msg}");
    }

    #[test]
    fn valid_category_still_loads() {
        let doc = json::parse(&op_json("6")).unwrap();
        let op = parse_op(&doc).unwrap();
        assert_eq!(op.category, 6);
        assert_eq!(op.name, "weird_op");
    }

    #[test]
    fn by_category_filters() {
        let reg = TaskRegistry::load(artifacts_dir()).unwrap();
        let losses = reg.by_category(5);
        assert_eq!(losses.len(), 6);
        assert!(losses.iter().all(|o| o.family == "loss"));
    }
}
