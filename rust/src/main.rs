//! `repro` — the EvoEngineer reproduction CLI (L3 leader entrypoint).
//!
//! ```text
//! repro smoke                          # PJRT + artifact sanity check
//! repro optimize matmul_64 --method evoengineer-full --model claude
//! repro campaign --seeds 3 --out results/records.jsonl
//! repro campaign --resume              # continue an interrupted sweep
//! repro campaign serve --bind 127.0.0.1:7717   # coordinator daemon
//! repro campaign work http://127.0.0.1:7717    # claim cells from it
//! repro report table4 --records results/records.jsonl
//! repro cache stats                    # persistent eval-cache health
//! ```
//!
//! (Arg parsing is hand-rolled: the build environment is offline and
//! clap is not in the pre-seeded crate cache.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use evoengineer::campaign::{coordinator, results, wire, CampaignConfig};
use evoengineer::evals::Evaluator;
use evoengineer::feedback::FeedbackConfig;
use evoengineer::llm::{
    profile, provider, GenerationRequest, Provider, ProviderConfig, ProviderSpec,
};
use evoengineer::methods::engine::{self, EngineOpts, EventSink};
use evoengineer::methods::{
    self, Archive, JournalSink, KernelRunRecord, ProgressSink, RepairPolicy, RunCtx,
};
use evoengineer::runtime::Runtime;
use evoengineer::store::events::EventJournal;
use evoengineer::store::EvalStore;
use evoengineer::tasks::TaskRegistry;
use evoengineer::{eyre, report, Result};

const USAGE: &str = "\
repro — EvoEngineer reproduction (rust+JAX+Pallas)

USAGE:
  repro [--artifacts DIR] <command> [options]

COMMANDS:
  smoke                      load artifacts and execute on PJRT (sanity)
      --runtime-shards N     PJRT executor shards (default 0 = CPUs)
      --repair MODE          also demo the stage-0 guard: off|diagnose|
                             repair|repair:K (default off)
      --provider P           generation backend for the guard demo:
                             sim|replay:<path>|http|ensemble:[...]
                             (default sim)
  optimize <op>              one optimization run, verbose
      --method NAME          (default evoengineer-full)
      --model NAME           (default gpt)
      --seed N               (default 0)
      --budget N             (default 45)
      --repair MODE          stage-0 guard policy: off|diagnose|repair|
                             repair:K (default off; repair = repair:2)
      --goal G               search objective + profile feedback:
                             speedup|speedup+profile|memory|balanced
                             (default speedup = pre-profile behaviour,
                             byte-identical records; the other modes
                             inject a PERFORMANCE PROFILE section into
                             every follow-up prompt and re-rank the
                             archive/bandit by the objective's fitness)
      --provider P           generation backend: sim|replay:<path>|http|
                             ensemble:[m@w,m#alias@w,...,x=R]|
                             ensemble:@<file.json> (default sim; http
                             needs the http-provider build feature +
                             EVO_HTTP_* env; a multi-member ensemble
                             routes each call by a seed-deterministic
                             bandit, exploration ratio R)
      --transcripts PATH     record every provider call to a journal
                             (default off for single runs)
      --events PATH          append structured per-trial events to a
                             journal (default off; stderr always shows
                             live per-trial progress)
      --prefetch N           speculative generation-prefetch workers:
                             provider calls for predicted future trials
                             overlap with compile+bench (default 0 =
                             off; byte-identical records either way)
      --cache PATH           persistent eval cache (default off)
      --bank PATH            deposit every new per-run best into a
                             persistent kernel bank (default off;
                             attaching one never changes the record)
      --warm-start PATH      seed the population and a PRIOR ELITES
                             prompt section from a bank journal
                             (default off)
      --runtime-shards N     PJRT executor shards (default 0 = CPUs)
  campaign                   run the method x model x op x seed sweep
      --methods A,B          (default: all six)
      --models A,B           (default: all three)
      --seeds N              independent runs, seeds 0..N (default 3)
      --ops SUBSTR           op-name filter
      --max-ops N            stratified cap on ops (default 0 = all 91)
      --budget N             trials per run (default 45)
      --repair MODE          stage-0 guard policy for every cell:
                             off|diagnose|repair|repair:K (default off)
      --goal G               search objective for every cell:
                             speedup|speedup+profile|memory|balanced
                             (default speedup)
      --provider P           generation backend for every cell:
                             sim|replay:<path>|http|ensemble:[...]
                             (default sim)
      --transcripts PATH|off provider-call journal; a recorded campaign
                             replays bit-identically with zero live
                             generation via --provider replay:<path>
                             (default <artifacts>/transcripts.jsonl)
      --concurrency N        workers (default: CPUs)
      --runtime-shards N     PJRT executor shards (default 0 = CPUs)
      --out PATH             (default results/records.jsonl)
      --checkpoint PATH      cell journal (default <out>.checkpoint.jsonl)
      --resume               skip cells already in the checkpoint;
                             half-finished cells replay their completed
                             trials warm (eval cache + transcripts) and
                             continue live at trial granularity
      --events PATH|off      per-trial event journal (default off);
                             uploaded nightly by CI, rendered by
                             `report events`, verified on --resume
      --prefetch N           speculative generation-prefetch workers
                             per cell (default 0 = off)
      --quiet                suppress progress lines
      --cache PATH|off       persistent eval cache
                             (default <artifacts>/eval_cache.jsonl)
      --bank PATH|off        persistent cross-campaign kernel bank:
                             every candidate that beats its run's
                             incumbent is journaled with provenance
                             (default <artifacts>/bank.jsonl; deposits
                             never change records or events)
      --warm-start PATH      read-only bank snapshot consumed at start:
                             seeds each cell's archive/population and
                             injects a PRIOR ELITES few-shot section
                             into generation prompts (default off; an
                             empty bank is byte-identical to cold)
  campaign serve             coordinate the sweep over HTTP for
                             `campaign work` processes; takes the same
                             sweep flags as `campaign` (--cache is the
                             merged store worker uploads land in), plus:
      --bind HOST:PORT       listen address (default 127.0.0.1:7717);
                             GET /metrics serves Prometheus-style text
                             counters while the sweep runs; with
                             --warm-start, GET /bank ships the snapshot
                             to every worker so the distributed sweep
                             warm-starts identically to a local one
  campaign work URL          claim cells from a coordinator until the
                             sweep drains (engine knobs mirror /config;
                             warm-start state always comes from the
                             coordinator, never a local flag)
      --provider P           optional assertion only: the worker always
                             runs the coordinator's resolved provider
                             spec from /config; passing a different one
                             here is a startup error
      --transcripts PATH|off worker-local provider journal, uploaded to
                             the coordinator (default off; never point
                             it at the coordinator's own file)
      --cache PATH|off       worker-local eval cache, uploaded
                             (default off; same sharing caveat)
      --bank PATH|off        worker-local kernel bank for elite
                             deposits (default off; merge shards later
                             with `bank import`)
      --concurrency N        worker threads (default 1)
      --stop-after-trials N  simulated mid-cell worker death (testing):
                             release claimed cells and exit
      --quiet                suppress progress lines
  campaign watch TARGET      live sweep dashboard; TARGET is an event
                             journal path (tailed like `tail -f`) or a
                             coordinator URL (GET /status polled)
      --interval SECS        refresh period (default 2)
      --once                 render one snapshot and exit (CI)
  report <which>             regenerate a table/figure from records
      which: table4|table5|table7|table8|fig1|fig4|fig5|fig8|fig9|
             validity|tokens|goals|convergence|methods|events|bank|all
      --records PATH         (default results/records.jsonl; a partial
                             checkpoint journal also works)
      --events PATH          event journal for `report events`
                             (default results/events.jsonl)
      --bank PATH            bank journal for `report bank`
                             (default <artifacts>/bank.jsonl)
      --model NAME           model filter for fig4 (fig6/7 = other models)
  cache <stats|gc>           inspect / compact the persistent eval cache
      --cache PATH           (default <artifacts>/eval_cache.jsonl)
  bank <action>              inspect / maintain the persistent kernel
                             bank (DESIGN.md §18)
      action: stats          entries per op/goal, journal health
              export         print the canonical journal (torn tails
                             repaired, duplicates collapsed) to stdout
              import FILE    merge another bank journal's entries in
                             (content-key dedup)
              gc             compact the journal in place
              top OP         show the retrieval-ranked elites for an op
                             exactly as a prompt would cite them
      --bank PATH            (default <artifacts>/bank.jsonl)
      --k N                  elites shown by `top` (default 3)
";

/// Flags that take no value (presence = true).
const BOOL_FLAGS: &[&str] = &["resume", "quiet", "once"];

/// Tiny flag parser: positional args + `--key value` pairs, plus the
/// bare boolean flags in [`BOOL_FLAGS`].
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| eyre!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    fn has(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| eyre!("bad numeric value for --{key}: {v}")),
        }
    }
}

fn split_csv(s: &str) -> Vec<String> {
    s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(String::from).collect()
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv)?;
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let cmd = args
        .positional
        .first()
        .ok_or_else(|| eyre!("missing command\n{USAGE}"))?
        .as_str();

    let runtime_shards = args.get_num("runtime-shards", 0usize)?;
    let repair = RepairPolicy::parse(&args.get("repair", "off"))?;
    let goal = FeedbackConfig::parse(&args.get("goal", "speedup"))?;
    let provider_spec = ProviderSpec::parse(&args.get("provider", "sim"))?;

    match cmd {
        "smoke" => smoke(&artifacts, runtime_shards, repair, &provider_spec),
        "optimize" => {
            let op = args
                .positional
                .get(1)
                .ok_or_else(|| eyre!("optimize needs an op name"))?;
            // Cache and transcripts are opt-in for single runs (default
            // off keeps a one-shot `optimize` free of filesystem side
            // effects).
            let cache = match args.get("cache", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            let transcripts = match args.get("transcripts", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            let events = match args.get("events", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            // Bank deposits and warm-starts are opt-in for single runs,
            // like the cache: a one-shot `optimize` stays side-effect
            // free unless pointed at a journal.
            let bank = match args.get("bank", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            let warm = match args.get("warm-start", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            optimize(
                &artifacts,
                op,
                &args.get("method", "evoengineer-full"),
                &args.get("model", "gpt"),
                args.get_num("seed", 0u64)?,
                args.get_num("budget", evoengineer::TRIAL_BUDGET)?,
                repair,
                goal,
                &provider_spec,
                transcripts.as_deref(),
                events.as_deref(),
                args.get_num("prefetch", 0usize)?,
                cache.as_deref(),
                bank.as_deref(),
                warm.as_deref(),
                runtime_shards,
            )
        }
        "campaign" => {
            // `campaign watch` is a pure observer: it never claims
            // cells or writes journals, so it skips the config build.
            if args.positional.get(1).map(String::as_str) == Some("watch") {
                let target = args.positional.get(2).ok_or_else(|| {
                    eyre!("campaign watch needs an event-journal path or coordinator URL")
                })?;
                let opts = evoengineer::campaign::watch::WatchOpts {
                    interval: std::time::Duration::from_secs_f64(
                        args.get_num("interval", 2.0f64)?.max(0.1),
                    ),
                    once: args.has("once"),
                };
                return evoengineer::campaign::watch::watch(target, &opts);
            }
            // `campaign work` is a pure worker: everything
            // sweep-defining is mirrored from the coordinator, so it
            // skips the config build entirely.
            if args.positional.get(1).map(String::as_str) == Some("work") {
                let url = args
                    .positional
                    .get(2)
                    .ok_or_else(|| eyre!("campaign work needs the coordinator URL"))?;
                // Worker-local journals are opt-in: their default
                // locations would collide with a same-directory
                // coordinator's merged stores.
                let cache = match args.get("cache", "off").as_str() {
                    "off" | "" => None,
                    p => Some(PathBuf::from(p)),
                };
                let opts = wire::WorkOpts {
                    // The worker never builds from its own --provider:
                    // the coordinator's resolved spec (served by
                    // /config) is authoritative. A locally-passed spec
                    // is kept only as a startup assertion.
                    provider: args.flags.get("provider").cloned(),
                    transcripts: match args.get("transcripts", "off").as_str() {
                        "off" | "" => None,
                        p => Some(PathBuf::from(p)),
                    },
                    cache: cache.clone(),
                    bank: match args.get("bank", "off").as_str() {
                        "off" | "" => None,
                        p => Some(PathBuf::from(p)),
                    },
                    concurrency: args.get_num("concurrency", 1usize)?,
                    quiet: args.has("quiet"),
                    stop_after_trials: args.get_num("stop-after-trials", 0usize)?,
                };
                return campaign_work(&artifacts, url, opts, cache.as_deref(), runtime_shards);
            }
            let sub = match args.positional.get(1).map(String::as_str) {
                None | Some("serve") => args.positional.get(1).cloned(),
                Some(other) => {
                    return Err(eyre!(
                        "unknown campaign subcommand `{other}` (serve|work|watch)"
                    ))
                }
            };
            let out = PathBuf::from(args.get("out", "results/records.jsonl"));
            let checkpoint = PathBuf::from(args.get(
                "checkpoint",
                &format!("{}.checkpoint.jsonl", out.display()),
            ));
            // Campaigns record transcripts by default: the journal is
            // what makes the sweep re-runnable with zero live
            // generation (`--provider replay:<path>`).
            let transcripts = match args
                .get("transcripts", &artifacts.join("transcripts.jsonl").display().to_string())
                .as_str()
            {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            let events = match args.get("events", "off").as_str() {
                "off" | "" => None,
                p => Some(PathBuf::from(p)),
            };
            let cfg = CampaignConfig {
                methods: split_csv(&args.get("methods", "")),
                models: split_csv(&args.get("models", "")),
                seeds: (0..args.get_num("seeds", 3u64)?).collect(),
                op_filter: args.get("ops", ""),
                max_ops: args.get_num("max-ops", 0usize)?,
                budget: args.get_num("budget", evoengineer::TRIAL_BUDGET)?,
                repair,
                goal,
                provider: provider_spec,
                transcripts,
                concurrency: args.get_num("concurrency", 0usize)?,
                quiet: args.has("quiet"),
                checkpoint: Some(checkpoint),
                resume: args.has("resume"),
                stop_after: 0,
                stop_after_trials: 0,
                events,
                prefetch: args.get_num("prefetch", 0usize)?,
                // The bank defaults on for campaigns (like the eval
                // cache): deposits are write-only and never change
                // records. Warm-starting stays opt-in.
                bank: bank_path(&args.get("bank", ""), &artifacts),
                warm_start: match args.get("warm-start", "off").as_str() {
                    "off" | "" => None,
                    p => Some(PathBuf::from(p)),
                },
            };
            let cache = cache_path(&args.get("cache", ""), &artifacts);
            if sub.as_deref() == Some("serve") {
                let bind = args.get("bind", "127.0.0.1:7717");
                campaign_serve(&artifacts, cfg, cache.as_deref(), &out, &bind)
            } else {
                campaign(&artifacts, cfg, cache.as_deref(), &out, runtime_shards)
            }
        }
        "cache" => {
            let action = args
                .positional
                .get(1)
                .ok_or_else(|| eyre!("cache needs an action: stats|gc"))?;
            let path = cache_path(&args.get("cache", ""), &artifacts)
                .ok_or_else(|| eyre!("--cache off makes no sense here"))?;
            match action.as_str() {
                "stats" => {
                    let stats = EvalStore::stats(&path)?;
                    print!("{}", evoengineer::store::stats_report(&path, &stats));
                    Ok(())
                }
                "gc" => {
                    let (before, after) = EvalStore::gc(&path)?;
                    println!(
                        "compacted {}: {} -> {} bytes ({} reclaimed)",
                        path.display(),
                        before,
                        after,
                        before.saturating_sub(after)
                    );
                    Ok(())
                }
                other => Err(eyre!("unknown cache action `{other}` (stats|gc)")),
            }
        }
        "bank" => {
            let action = args
                .positional
                .get(1)
                .ok_or_else(|| eyre!("bank needs an action: stats|export|import|gc|top"))?;
            let path = bank_path(&args.get("bank", ""), &artifacts)
                .ok_or_else(|| eyre!("--bank off makes no sense here"))?;
            bank_cmd(&path, action, &args)
        }
        "report" => {
            let which = args
                .positional
                .get(1)
                .ok_or_else(|| eyre!("report needs a table/figure name"))?;
            let bank = bank_path(&args.get("bank", ""), &artifacts)
                .unwrap_or_else(|| artifacts.join("bank.jsonl"));
            run_report(
                &artifacts,
                which,
                &PathBuf::from(args.get("records", "results/records.jsonl")),
                &PathBuf::from(args.get("events", "results/events.jsonl")),
                &bank,
                &args.get("model", ""),
            )
        }
        other => Err(eyre!("unknown command `{other}`\n{USAGE}")),
    }
}

/// Resolve a `--bank` value: "" = default under the artifacts dir,
/// "off" = disabled, anything else = explicit path.
fn bank_path(flag: &str, artifacts: &std::path::Path) -> Option<PathBuf> {
    match flag {
        "off" => None,
        "" => Some(artifacts.join("bank.jsonl")),
        p => Some(PathBuf::from(p)),
    }
}

/// The `bank <stats|export|import|gc|top>` maintenance actions
/// (DESIGN.md §18). All offline: none of them need the runtime.
fn bank_cmd(path: &std::path::Path, action: &str, args: &Args) -> Result<()> {
    use evoengineer::bank;
    match action {
        "stats" => {
            let stats = bank::stats(path)?;
            print!("{}", bank::stats_report(&stats));
            Ok(())
        }
        "export" => {
            // Canonical re-serialization: torn tails repaired,
            // duplicate keys collapsed, one JSON line per entry —
            // exactly the bytes a coordinator ships over GET /bank.
            let bank = bank::KernelBank::load(path)?;
            for line in bank.export_lines() {
                println!("{line}");
            }
            Ok(())
        }
        "import" => {
            let file = args
                .positional
                .get(2)
                .ok_or_else(|| eyre!("bank import needs a source journal path"))?;
            let src = std::fs::read_to_string(file)
                .map_err(|e| eyre!("reading {file}: {e}"))?;
            let bank = bank::KernelBank::open(path)?;
            let (mut added, mut skipped) = (0u64, 0u64);
            for line in src.lines().filter(|l| !l.trim().is_empty()) {
                match bank.ingest_line(line) {
                    Ok(true) => added += 1,
                    Ok(false) => skipped += 1,
                    Err(e) => eprintln!("warning: skipping corrupt line: {e:#}"),
                }
            }
            bank.flush()?;
            println!(
                "imported {added} new elite(s) into {} ({skipped} already present)",
                path.display()
            );
            Ok(())
        }
        "gc" => {
            let (before, after) = bank::gc(path)?;
            println!(
                "compacted {}: {} -> {} bytes ({} reclaimed)",
                path.display(),
                before,
                after,
                before.saturating_sub(after)
            );
            Ok(())
        }
        "top" => {
            let op = args
                .positional
                .get(2)
                .ok_or_else(|| eyre!("bank top needs an op name"))?;
            let k = args.get_num("k", bank::RETRIEVE_K)?;
            let bank = bank::KernelBank::load(path)?;
            let mut entries = bank.entries_for_op(op);
            entries.truncate(k);
            if entries.is_empty() {
                println!("no elites for op `{op}` in {}", path.display());
            } else {
                print!("{}", bank::render_refs(&entries));
            }
            Ok(())
        }
        other => Err(eyre!("unknown bank action `{other}` (stats|export|import|gc|top)")),
    }
}

/// Resolve a `--cache` value: "" = default under the artifacts dir,
/// "off" = disabled, anything else = explicit path.
fn cache_path(flag: &str, artifacts: &std::path::Path) -> Option<PathBuf> {
    match flag {
        "off" => None,
        "" => Some(artifacts.join("eval_cache.jsonl")),
        p => Some(PathBuf::from(p)),
    }
}

fn make_evaluator(
    artifacts: &PathBuf,
    cache: Option<&std::path::Path>,
    runtime_shards: usize,
) -> Result<Evaluator> {
    let registry = std::sync::Arc::new(TaskRegistry::load(artifacts)?);
    let runtime = Runtime::with_shards(runtime_shards)?;
    let mut evaluator = Evaluator::new(registry, runtime);
    if let Some(path) = cache {
        evaluator = evaluator.with_store(EvalStore::open(path)?);
    }
    Ok(evaluator)
}

fn smoke(
    artifacts: &PathBuf,
    runtime_shards: usize,
    repair: RepairPolicy,
    provider_spec: &ProviderSpec,
) -> Result<()> {
    let evaluator = make_evaluator(artifacts, None, runtime_shards)?;
    let reg = &evaluator.registry;
    println!("manifest: {} ops ({} runtime shards)", reg.ops.len(), evaluator.runtime_shards());
    let task = reg.get("matmul_64").expect("matmul_64 in dataset");
    for variant in ["ref", "opt", "bug_scale"] {
        let v = evaluator.functional(task, variant)?;
        println!(
            "matmul_64/{variant}: functional pass={} max_abs_diff={:.3e}",
            v.pass, v.max_abs_diff
        );
    }
    let stats = evaluator.runtime_stats()?;
    println!(
        "runtime: {} executions, {} compiles, {} cache hits",
        stats.executions, stats.compiles, stats.cache_hits
    );
    if repair != RepairPolicy::Off {
        let llm_provider = provider::build(&ProviderConfig::new(provider_spec.clone()))?;
        guard_demo(&evaluator, repair, llm_provider.as_ref())?;
    }
    println!("smoke OK");
    Ok(())
}

/// `smoke --repair MODE`: run the stage-0 guard over one candidate per
/// invalid class and show the structured diagnostics (and, under a
/// repair policy, whether the LLM repair loop mends each one — issued
/// as typed `Repair` requests through the configured provider).
fn guard_demo(
    evaluator: &Evaluator,
    repair: RepairPolicy,
    llm_provider: &dyn Provider,
) -> Result<()> {
    use evoengineer::dsl::{self, KernelSpec};

    let task = evaluator.registry.get("matmul_64").expect("matmul_64 in dataset").clone();
    let base = KernelSpec::baseline(&task.name);

    let mut cases: Vec<(&str, String)> = Vec::new();
    cases.push(("syntax", dsl::print(&base).replacen("schedule", "schedul", 1)));
    cases.push((
        "shadowed binding",
        "kernel matmul_64 { semantics: opt; schedule { tile_m: 8; tile_m: 64; } }".into(),
    ));
    let mut spec = base.clone();
    spec.semantics = "turbo_v9".into();
    cases.push(("undefined ref", dsl::print(&spec)));
    let mut spec = base.clone();
    spec.schedule.tile_k = 0;
    cases.push(("non-terminating", dsl::print(&spec)));
    let mut spec = base.clone();
    spec.schedule.tile_m = 256; // resource-legal, too big for the op
    cases.push(("shape mismatch", dsl::print(&spec)));
    let mut spec = base.clone();
    spec.schedule.threads_per_block = 100;
    cases.push(("resource limit", dsl::print(&spec)));

    println!("\nstage-0 guard ({}, provider {}):", repair.label(), llm_provider.label());
    let rng = evoengineer::util::Rng::new(0).derive("guard-demo");
    let model = profile::by_name("gpt").expect("gpt profile").name;
    // A multi-member ensemble rejects unrouted calls; the demo routes
    // through a fresh (stateless-across-cases) bandit like the engine.
    let routing = llm_provider.routing().map(|spec| evoengineer::llm::Bandit::new(&spec));
    // All verdicts up front through the parallel batch API — same
    // reports in the same order as per-case `guard_check` calls.
    let items: Vec<(&str, &evoengineer::tasks::OpTask)> =
        cases.iter().map(|(_, src)| (src.as_str(), &task)).collect();
    let reports = evoengineer::guard::check_batch(&items, 0);
    for ((label, src), report) in cases.iter().zip(reports) {
        println!("  {label}: {} diagnostic(s)", report.diagnostics.len());
        for d in &report.diagnostics {
            println!("    {d}");
        }
        if let RepairPolicy::Repair { max_attempts } = repair {
            let mut text = src.clone();
            let mut rep = report;
            let mut attempt = 0;
            while !rep.pass() && attempt < max_attempts {
                let seed = rng.derive_seed(&format!("{label}/{attempt}"));
                let mut req = GenerationRequest::repair(model, &text, &rep, seed);
                if let Some(b) = &routing {
                    let member = b.select("repair", &task.family, seed);
                    req = req.with_routing("repair", &task.family, &member);
                }
                text = llm_provider.call(&req)?.text;
                rep = evaluator.guard_check(&text, &task);
                attempt += 1;
            }
            println!(
                "    repair after {attempt} attempt(s): {}",
                if rep.pass() { "PASS" } else { "still rejected" }
            );
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn optimize(
    artifacts: &PathBuf,
    op: &str,
    method: &str,
    model: &str,
    seed: u64,
    budget: usize,
    repair: RepairPolicy,
    goal: FeedbackConfig,
    provider_spec: &ProviderSpec,
    transcripts: Option<&std::path::Path>,
    events: Option<&std::path::Path>,
    prefetch: usize,
    cache: Option<&std::path::Path>,
    bank: Option<&std::path::Path>,
    warm: Option<&std::path::Path>,
    runtime_shards: usize,
) -> Result<()> {
    let evaluator = make_evaluator(artifacts, cache, runtime_shards)?;
    let task = evaluator
        .registry
        .get(op)
        .ok_or_else(|| eyre!("unknown op `{op}`"))?
        .clone();
    let method = methods::by_name(method)?;
    let model = profile::by_name(model).ok_or_else(|| eyre!("unknown model `{model}`"))?;
    let llm_provider = provider::build(
        &ProviderConfig::new(provider_spec.clone())
            .transcripts(transcripts.map(|p| p.to_path_buf())),
    )?;
    let bank = match bank {
        Some(path) => Some(evoengineer::bank::KernelBank::open(path)?),
        None => None,
    };
    let warm = match warm {
        Some(path) => Some(evoengineer::bank::KernelBank::load(path)?),
        None => None,
    };
    let archive = Archive::new();
    if let Some(warm) = &warm {
        evoengineer::campaign::seed_archive_from_bank(&archive, warm);
    }
    let ctx = RunCtx {
        evaluator: &evaluator,
        task: &task,
        model,
        seed,
        archive: &archive,
        budget,
        repair,
        feedback: goal,
        provider: llm_provider.as_ref(),
        bank: bank.clone(),
        warm: warm.clone(),
    };
    // Single runs are "verbose": the progress sink narrates every
    // trial live on stderr; --events additionally journals them.
    let mut sinks: Vec<Arc<dyn EventSink>> = vec![Arc::new(ProgressSink::single_run())];
    if let Some(path) = events {
        sinks.push(Arc::new(JournalSink::new(EventJournal::create(path)?)));
    }
    let opts = EngineOpts { sinks, prefetch, ..EngineOpts::default() };
    let rec = engine::drive(method.as_ref(), &ctx, &opts)?;
    println!(
        "{} / {} on {} (seed {seed}): best speedup {:.2}x vs baseline, {:.2}x vs PyTorch",
        rec.method, rec.model, rec.op, rec.best_speedup, rec.best_pytorch_speedup
    );
    println!(
        "trials: {} (compiled {:.0}%, correct {:.0}%), tokens: {} prompt + {} completion \
         (provider {})",
        rec.trials,
        100.0 * rec.compiled_trials as f64 / rec.trials.max(1) as f64,
        100.0 * rec.correct_trials as f64 / rec.trials.max(1) as f64,
        rec.prompt_tokens,
        rec.completion_tokens,
        rec.provider
    );
    match (provider_spec, transcripts) {
        // provider::build ignores --transcripts under replay: the
        // journal already is the record, nothing new is written.
        (ProviderSpec::Replay(journal), _) => {
            println!("replayed every generation from {} (zero live calls)", journal.display())
        }
        (_, Some(path)) => println!("transcripts: recorded to {}", path.display()),
        _ => {}
    }
    if let Some(path) = events {
        println!("events: journaled to {} (render with `repro report events`)", path.display());
    }
    if rec.repair_policy != "off" {
        println!(
            "stage-0 guard ({}): {} rejected, {} repaired ({} repair calls in the budget)",
            rec.repair_policy, rec.guard_rejected_trials, rec.repaired_trials, rec.repair_attempts
        );
    }
    if !goal.is_default() {
        println!(
            "objective: {} (performance profiles fed back into follow-up prompts)",
            goal.label()
        );
    }
    print!("trajectory:");
    for (i, s) in rec.trajectory.iter().enumerate() {
        if i % 5 == 0 {
            print!(" [{i}]{s:.2}");
        }
    }
    println!();
    if let Some(src) = rec.best_src {
        println!("\nbest kernel:\n{src}");
    }
    if let Some(store) = evaluator.store() {
        store.flush_session_stats()?;
        println!(
            "\neval cache: {} hits, {} misses ({} entries in {})",
            store.hits(),
            store.misses(),
            store.len(),
            store.path().display()
        );
    }
    if let Some(bank) = &bank {
        bank.flush()?;
        println!(
            "bank: {} new elite(s) deposited ({} entries in {})",
            bank.deposits(),
            bank.len(),
            bank.path().map(|p| p.display().to_string()).unwrap_or_default()
        );
    }
    if let Some(warm) = &warm {
        let (hits, misses) = warm.retrieval_counts();
        println!(
            "warm-start: {} elites loaded, retrieval served {hits} request(s) ({misses} without \
             matching elites)",
            warm.len()
        );
    }
    Ok(())
}

/// The saved-records line plus the journal pointers every finished
/// sweep prints, shared by `campaign` and `campaign serve`.
fn campaign_notes(cfg: &CampaignConfig, out: &PathBuf, records: &[KernelRunRecord]) {
    println!("saved {} records to {}", records.len(), out.display());
    match (&cfg.provider, &cfg.transcripts) {
        (ProviderSpec::Replay(path), _) => {
            println!("replayed every generation from {} (zero live calls)", path.display())
        }
        (_, Some(path)) => println!(
            "transcripts: recorded to {} (re-run bit-identically with \
             --provider replay:{})",
            path.display(),
            path.display()
        ),
        _ => {}
    }
    if let Some(path) = &cfg.events {
        println!(
            "events: per-trial journal at {} (render with `repro report events --events {}`)",
            path.display(),
            path.display()
        );
    }
}

/// The headline tables every finished sweep renders.
fn campaign_reports(records: &[KernelRunRecord]) {
    println!("\n{}", report::table4(records));
    // The validity breakdown matters whenever stage-0 verdicts exist,
    // not only when a repair policy ran: guard-only sweeps (`--repair
    // off` with rejected candidates) used to silently skip it.
    if records
        .iter()
        .any(|r| r.repair_policy != "off" || r.guard_rejected_trials > 0 || r.repair_attempts > 0)
    {
        println!("\n{}", report::validity(records));
    }
    if records.iter().any(|r| r.goal != "speedup") {
        println!("\n{}", report::goals(records));
    }
    println!("\n{}", report::tokens(records));
}

fn campaign(
    artifacts: &PathBuf,
    cfg: CampaignConfig,
    cache: Option<&std::path::Path>,
    out: &PathBuf,
    runtime_shards: usize,
) -> Result<()> {
    let evaluator = make_evaluator(artifacts, cache, runtime_shards)?;
    let store = evaluator.store().cloned();
    let records = evoengineer::campaign::run(&cfg, evaluator)?;
    results::save(out, &records)?;
    campaign_notes(&cfg, out, &records);
    if let Some(store) = store {
        println!(
            "eval cache: {} hits, {} misses this run ({} entries in {})",
            store.hits(),
            store.misses(),
            store.len(),
            store.path().display()
        );
    }
    campaign_reports(&records);
    Ok(())
}

/// `campaign serve`: coordinate the sweep for `campaign work`
/// processes. No evaluator/runtime here — workers own the engine
/// stacks; the coordinator owns the grid and the merged journals.
fn campaign_serve(
    artifacts: &PathBuf,
    cfg: CampaignConfig,
    cache: Option<&std::path::Path>,
    out: &PathBuf,
    bind: &str,
) -> Result<()> {
    let registry = TaskRegistry::load(artifacts)?;
    let (records, stats) = coordinator::serve(&cfg, &registry, bind, cache)?;
    results::save(out, &records)?;
    campaign_notes(&cfg, out, &records);
    println!("\n{}", report::plane(&stats));
    campaign_reports(&records);
    Ok(())
}

/// `campaign work <url>`: run one worker process against a coordinator
/// until the sweep drains.
fn campaign_work(
    artifacts: &PathBuf,
    url: &str,
    opts: wire::WorkOpts,
    cache: Option<&std::path::Path>,
    runtime_shards: usize,
) -> Result<()> {
    let evaluator = make_evaluator(artifacts, cache, runtime_shards)?;
    let summary = wire::work(url, evaluator, &opts)?;
    println!(
        "worker drained: {} cell(s) completed{}",
        summary.cells_completed,
        if summary.interrupted { " (interrupted by --stop-after-trials)" } else { "" }
    );
    Ok(())
}

fn run_report(
    artifacts: &PathBuf,
    which: &str,
    records_path: &PathBuf,
    events_path: &PathBuf,
    bank_path: &std::path::Path,
    model: &str,
) -> Result<()> {
    let text = match which {
        "table5" => {
            let reg = TaskRegistry::load(artifacts)?;
            report::table5(&reg)
        }
        "methods" => report::methods_table(),
        "bank" => {
            // Records are optional here: without them the report is
            // the journal aggregates alone; with them it adds the
            // trials-to-best table the nightly cold-vs-warm job diffs.
            let stats = evoengineer::bank::stats(bank_path)?;
            let records = if records_path.exists() {
                results::load_lenient(records_path)?
            } else {
                Vec::new()
            };
            report::bank(&stats, &records)
        }
        "events" => {
            if !events_path.exists() {
                return Err(eyre!(
                    "opening {events_path:?} — run a campaign or optimize with `--events` first"
                ));
            }
            report::events(&EventJournal::load(events_path)?)
        }
        _ => {
            // Lenient load: a mid-campaign checkpoint journal (possibly
            // with a torn final line) renders just as well as a
            // completed records file.
            if !records_path.exists() {
                return Err(eyre!(
                    "opening {records_path:?} — run `repro campaign` first"
                ));
            }
            let records = results::load_lenient(records_path)?;
            match which {
                "table4" => report::table4(&records),
                "validity" => report::validity(&records),
                "tokens" => report::tokens(&records),
                "goals" => report::goals(&records),
                "table7" => report::table7(&records),
                "table8" => report::table8(&records),
                "fig1" => report::fig1(&records),
                "fig4" => report::fig4(&records, model),
                "fig5" => report::fig5(&records),
                "fig8" => report::fig8(&records),
                "fig9" => report::fig9(&records),
                "convergence" => report::convergence(&records),
                "all" => {
                    let reg = TaskRegistry::load(artifacts)?;
                    [
                        report::table5(&reg),
                        report::methods_table(),
                        report::table4(&records),
                        report::validity(&records),
                        report::goals(&records),
                        report::tokens(&records),
                        report::fig1(&records),
                        report::fig4(&records, model),
                        report::fig5(&records),
                        report::table7(&records),
                        report::fig8(&records),
                        report::table8(&records),
                        report::fig9(&records),
                    ]
                    .join("\n\n")
                }
                other => return Err(eyre!("unknown report `{other}`")),
            }
        }
    };
    println!("{text}");
    Ok(())
}
