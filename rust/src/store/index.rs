//! Sidecar offset index for the append-only JSONL journals
//! (DESIGN.md §14).
//!
//! Opening a journal used to re-read and re-parse every line — O(file)
//! work on every process start, which dominates warm campaign runs once
//! the eval cache holds tens of thousands of records. The sidecar
//! (`<journal>.idx`) remembers, per record line, its byte offset,
//! length and caller-assigned key, so a warm open becomes one small
//! sidecar read plus positioned reads (`pread`) of only the records a
//! lookup actually touches. The journal stays the single source of
//! truth: the sidecar is a pure cache, validated against the journal's
//! tail bytes on every open and rebuilt from a full scan on any
//! mismatch — deleting it is always safe and never loses data.
//!
//! Format (text, line-oriented, space-separated):
//!
//! ```text
//! evoidx 1
//! r <offset> <len> <key>
//! c <covered_len> <tail_off> <tail_len> <tail_hash16> <idx> <scan> <rebuilds>
//! ```
//!
//! `r` lines *stage* records; a `c` (cover) line *commits* everything
//! staged above it as valid for the first `covered_len` bytes of the
//! journal. Staged records after the last cover are dropped on load
//! (the tail rescan re-finds them), which makes the sidecar itself
//! torn-tail safe: it is append-extended on indexed opens and fully
//! rewritten (tmp + rename) after a rebuild. Validation preads the
//! journal's last complete line (`tail_off..tail_off+tail_len`) and
//! compares its truncated SHA-256 against `tail_hash16` — any append,
//! truncation, compaction or corruption of the covered region's end
//! invalidates the cover and forces a rebuild.
//!
//! Keys must be single tokens without whitespace (SHA-256 hex digests
//! and event-kind labels in practice); a record whose key the caller
//! declines to index (`extract_key` → `None`) is simply absent from
//! the result, exactly as the old scan-and-skip loops treated it.

use std::io::{BufRead, BufReader, Seek, SeekFrom, Write as _};
use std::os::unix::fs::FileExt as _;
use std::path::{Path, PathBuf};

use super::hash::sha256_hex;

/// Sidecar format version (the header line's second token).
pub const INDEX_FORMAT: u32 = 1;

/// Whether journal opens may consult/maintain the sidecar index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexMode {
    /// Use the sidecar when valid, rebuild it when not (the default).
    Auto,
    /// Never touch the sidecar: every open is a full scan. The
    /// torture suite runs both modes and asserts identical behaviour.
    Off,
}

impl IndexMode {
    /// Mode from the `EVO_JOURNAL_INDEX` environment variable:
    /// `off`/`0`/`false` disable the index, anything else (including
    /// unset) selects [`IndexMode::Auto`].
    pub fn from_env() -> Self {
        match std::env::var("EVO_JOURNAL_INDEX") {
            Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
                "off" | "0" | "false" => IndexMode::Off,
                _ => IndexMode::Auto,
            },
            Err(_) => IndexMode::Auto,
        }
    }
}

/// One indexed journal record: where its line lives and the key the
/// caller filed it under.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecordRef {
    /// Byte offset of the line's first byte.
    pub offset: u64,
    /// Line length in bytes, including the trailing `\n`.
    pub len: u32,
    pub key: String,
}

/// Lifetime counters carried in the cover line — what `cache stats`
/// reports as index health.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IndexHealth {
    /// Opens served from a valid sidecar (cheap path).
    pub indexed_opens: u64,
    /// Opens that fell back to a full journal scan.
    pub scanned_opens: u64,
    /// Scanned opens where a sidecar existed but failed validation.
    pub rebuilds: u64,
}

/// Result of [`load`]: the journal's record map plus how it was built.
#[derive(Debug)]
pub struct LoadOutcome {
    /// Every indexable record, in journal order (duplicate keys are
    /// the caller's first-wins policy to apply).
    pub records: Vec<RecordRef>,
    /// True when a valid sidecar covered the open (only appended tail
    /// lines were scanned).
    pub indexed: bool,
    /// Journal bytes actually read line-by-line this open.
    pub scanned_bytes: u64,
    pub health: IndexHealth,
}

impl LoadOutcome {
    fn empty() -> Self {
        LoadOutcome {
            records: Vec::new(),
            indexed: false,
            scanned_bytes: 0,
            health: IndexHealth::default(),
        }
    }
}

/// The sidecar path for a journal: `<journal>.idx`.
pub fn sidecar_path(journal: &Path) -> PathBuf {
    let mut os = journal.as_os_str().to_os_string();
    os.push(".idx");
    PathBuf::from(os)
}

/// Remove a journal's sidecar (compaction and `create()`-style
/// truncation must not leave a stale index behind; the next open
/// rebuilds it from the journal).
pub fn delete_sidecar(journal: &Path) {
    let _ = std::fs::remove_file(sidecar_path(journal));
}

/// Index health recorded in a journal's sidecar, if one exists and
/// parses (purely informational — never validated against the journal).
pub fn health(journal: &Path) -> Option<IndexHealth> {
    let text = std::fs::read_to_string(sidecar_path(journal)).ok()?;
    parse_sidecar(&text).map(|p| p.cover.health)
}

/// Build the record map for `journal`, consulting and maintaining the
/// sidecar under [`IndexMode::Auto`]. `extract_key` is called once per
/// *scanned* line with `(byte_offset, trimmed_line)` and returns the
/// record's index key, or `None` for lines that should not be indexed
/// (stats trailers, corrupt lines — the closure owns any warning).
/// The caller must repair the journal's torn tail first
/// ([`crate::util::truncate_torn_tail`]); a trailing partial line is
/// skipped and left uncovered regardless. A missing journal yields an
/// empty outcome and touches nothing.
pub fn load(
    journal: &Path,
    mode: IndexMode,
    extract_key: &dyn Fn(u64, &str) -> Option<String>,
) -> std::io::Result<LoadOutcome> {
    let file = match std::fs::File::open(journal) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(LoadOutcome::empty());
        }
        Err(e) => return Err(e),
    };
    let jlen = file.metadata()?.len();

    if mode == IndexMode::Off {
        let scan = scan_from(&file, 0, extract_key)?;
        return Ok(LoadOutcome {
            records: scan.records,
            indexed: false,
            scanned_bytes: scan.scanned_bytes,
            health: IndexHealth::default(),
        });
    }

    let sc_path = sidecar_path(journal);
    let existing = std::fs::read_to_string(&sc_path).ok();
    let had_sidecar = existing.is_some();
    let parsed = existing.as_deref().and_then(parse_sidecar);
    let (mut records, cover, clean) = match parsed {
        Some(p) if cover_valid(&file, jlen, &p.cover) => (p.records, Some(p.cover), p.clean),
        _ => (Vec::new(), None, false),
    };
    let indexed = cover.is_some();
    let start = cover.as_ref().map_or(0, |c| c.covered_len);

    // Scan only what the cover does not vouch for (everything, after
    // a rebuild).
    let scan = scan_from(&file, start, extract_key)?;

    // The new cover's tail is the journal's last complete line —
    // freshly scanned if any, otherwise inherited from the old cover.
    let (tail_off, tail_len) = match scan.last_line {
        Some(t) => t,
        None => cover.as_ref().map_or((0, 0), |c| (c.tail_off, c.tail_len)),
    };
    let mut health = cover.as_ref().map_or_else(IndexHealth::default, |c| c.health);
    if indexed {
        health.indexed_opens += 1;
    } else {
        health.scanned_opens += 1;
        if had_sidecar {
            health.rebuilds += 1;
        }
    }

    let tail_hash16 = tail_hash(&file, tail_off, tail_len)?;
    let cover_line = format!(
        "c {} {} {} {} {} {} {}\n",
        tail_off + tail_len,
        tail_off,
        tail_len,
        tail_hash16,
        health.indexed_opens,
        health.scanned_opens,
        health.rebuilds
    );
    let persist = if indexed && clean {
        // Cheap path: extend the existing sidecar with the freshly
        // scanned records and a new cover committing them.
        let mut out = String::with_capacity(scan.records.len() * 96 + cover_line.len());
        for r in &scan.records {
            push_record_line(&mut out, r);
        }
        out.push_str(&cover_line);
        append_to(&sc_path, out.as_bytes())
    } else {
        // Rebuild (or first build, or torn sidecar): full rewrite via
        // tmp + rename so a kill mid-write never leaves a half-index.
        let mut out = String::with_capacity((records.len() + scan.records.len()) * 96 + 64);
        out.push_str(&format!("evoidx {INDEX_FORMAT}\n"));
        for r in records.iter().chain(&scan.records) {
            push_record_line(&mut out, r);
        }
        out.push_str(&cover_line);
        rewrite(&sc_path, out.as_bytes())
    };
    if let Err(e) = persist {
        // Advisory, like every journal-adjacent write: a failed
        // sidecar update costs the next open a rescan, nothing more.
        eprintln!(
            "warning: journal index {}: sidecar update failed: {e}",
            sc_path.display()
        );
    }

    records.extend(scan.records);
    Ok(LoadOutcome { records, indexed, scanned_bytes: scan.scanned_bytes, health })
}

fn push_record_line(out: &mut String, r: &RecordRef) {
    // Keys with whitespace would corrupt the line format; every real
    // key is a hex digest or event-kind label, so just refuse to
    // persist pathological ones (the record still loads this open; the
    // next open's validation-triggered behaviour stays correct because
    // the cover only vouches for byte extents, not record counts —
    // worst case the record is re-found by a rescan after a rebuild).
    if r.key.is_empty() || r.key.contains(char::is_whitespace) {
        return;
    }
    out.push_str(&format!("r {} {} {}\n", r.offset, r.len, r.key));
}

fn append_to(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(bytes)?;
    f.flush()
}

fn rewrite(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = PathBuf::from(os);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

struct ParsedSidecar {
    records: Vec<RecordRef>,
    cover: Cover,
    /// False when trailing garbage (a torn sidecar tail) was dropped —
    /// the persist step must rewrite rather than append onto it.
    clean: bool,
}

struct Cover {
    covered_len: u64,
    tail_off: u64,
    tail_len: u64,
    tail_hash16: String,
    health: IndexHealth,
}

fn parse_sidecar(text: &str) -> Option<ParsedSidecar> {
    let mut lines = text.split('\n');
    let header = lines.next()?;
    let mut hp = header.split(' ');
    if hp.next() != Some("evoidx") || hp.next()?.parse::<u32>().ok()? != INDEX_FORMAT {
        return None;
    }
    let mut committed: Vec<RecordRef> = Vec::new();
    let mut staged: Vec<RecordRef> = Vec::new();
    let mut cover: Option<Cover> = None;
    // A sidecar that does not end in `\n` cannot be append-extended
    // (the next write would merge with its final line).
    let mut clean = text.ends_with('\n');
    for line in lines {
        if line.is_empty() {
            continue; // the final split fragment after a trailing \n
        }
        match parse_body_line(line) {
            Some(BodyLine::Record(r)) => staged.push(r),
            Some(BodyLine::Cover(c)) => {
                committed.append(&mut staged);
                cover = Some(c);
            }
            None => {
                // Torn/garbled tail: keep what the last cover commits,
                // drop the rest, and remember to rewrite.
                clean = false;
                break;
            }
        }
    }
    // Uncommitted staged records are dropped: the cover is the only
    // durability statement, and the journal rescan re-finds their
    // lines anyway.
    if !staged.is_empty() {
        clean = false;
    }
    cover.map(|cover| ParsedSidecar { records: committed, cover, clean })
}

enum BodyLine {
    Record(RecordRef),
    Cover(Cover),
}

fn parse_body_line(line: &str) -> Option<BodyLine> {
    let mut parts = line.split(' ');
    match parts.next()? {
        "r" => {
            let offset = parts.next()?.parse().ok()?;
            let len = parts.next()?.parse().ok()?;
            let key = parts.next()?.to_string();
            if key.is_empty() || parts.next().is_some() {
                return None;
            }
            Some(BodyLine::Record(RecordRef { offset, len, key }))
        }
        "c" => {
            let covered_len = parts.next()?.parse().ok()?;
            let tail_off = parts.next()?.parse().ok()?;
            let tail_len = parts.next()?.parse().ok()?;
            let tail_hash16 = parts.next()?.to_string();
            let health = IndexHealth {
                indexed_opens: parts.next()?.parse().ok()?,
                scanned_opens: parts.next()?.parse().ok()?,
                rebuilds: parts.next()?.parse().ok()?,
            };
            if parts.next().is_some() {
                return None;
            }
            Some(BodyLine::Cover(Cover { covered_len, tail_off, tail_len, tail_hash16, health }))
        }
        _ => None,
    }
}

/// A cover vouches for the journal's first `covered_len` bytes iff the
/// journal still starts with them: length-compatible, and the last
/// covered line's bytes hash to what the cover recorded.
fn cover_valid(file: &std::fs::File, jlen: u64, c: &Cover) -> bool {
    if c.covered_len > jlen {
        return false;
    }
    if c.covered_len == 0 {
        return c.tail_off == 0 && c.tail_len == 0;
    }
    if c.tail_len == 0
        || c.tail_len > MAX_LINE_BYTES
        || c.tail_off.checked_add(c.tail_len) != Some(c.covered_len)
    {
        return false;
    }
    let mut buf = vec![0u8; c.tail_len as usize];
    if file.read_exact_at(&mut buf, c.tail_off).is_err() {
        return false;
    }
    if buf.last() != Some(&b'\n') {
        return false;
    }
    sha256_hex(&buf)[..16] == c.tail_hash16
}

/// Sanity ceiling on one journal line (a prompt transcript can be
/// large, but nothing legitimate approaches 64 MiB per line).
const MAX_LINE_BYTES: u64 = 64 << 20;

fn tail_hash(file: &std::fs::File, tail_off: u64, tail_len: u64) -> std::io::Result<String> {
    if tail_len == 0 {
        return Ok("-".to_string());
    }
    let mut buf = vec![0u8; tail_len as usize];
    file.read_exact_at(&mut buf, tail_off)?;
    Ok(sha256_hex(&buf)[..16].to_string())
}

struct ScanOutcome {
    records: Vec<RecordRef>,
    /// `(offset, len)` of the last *complete* line seen.
    last_line: Option<(u64, u64)>,
    scanned_bytes: u64,
}

fn scan_from(
    file: &std::fs::File,
    start: u64,
    extract_key: &dyn Fn(u64, &str) -> Option<String>,
) -> std::io::Result<ScanOutcome> {
    let mut records = Vec::new();
    let mut last_line = None;
    let mut reader = BufReader::new(file.try_clone()?);
    reader.seek(SeekFrom::Start(start))?;
    let mut offset = start;
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            break;
        }
        if !line.ends_with('\n') {
            // Torn tail (the caller repairs these before indexing; a
            // racing writer could still produce one): not covered, not
            // indexed.
            break;
        }
        let len = n as u64;
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            if let Some(key) = extract_key(offset, trimmed) {
                records.push(RecordRef { offset, len: len as u32, key });
            }
        }
        last_line = Some((offset, len));
        offset += len;
    }
    Ok(ScanOutcome { records, last_line, scanned_bytes: offset - start })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evo_idx_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Key = first token of the line; `skip` lines are unindexed.
    fn key_of(_off: u64, line: &str) -> Option<String> {
        let first = line.split(' ').next().unwrap_or("");
        if first == "skip" || first.is_empty() {
            None
        } else {
            Some(first.to_string())
        }
    }

    fn keys(out: &LoadOutcome) -> Vec<&str> {
        out.records.iter().map(|r| r.key.as_str()).collect()
    }

    #[test]
    fn builds_then_serves_indexed_opens() {
        let dir = tmpdir("basic");
        let j = dir.join("j.jsonl");
        std::fs::write(&j, "k1 a\nk2 bb\nskip x\nk3 ccc\n").unwrap();

        // First open: full scan, sidecar written.
        let o1 = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(!o1.indexed);
        assert_eq!(keys(&o1), ["k1", "k2", "k3"]);
        assert_eq!(o1.health.scanned_opens, 1);
        assert_eq!(o1.health.rebuilds, 0);
        assert!(sidecar_path(&j).exists());

        // Second open: served by the sidecar, zero journal scanning.
        let o2 = load(&j, IndexMode::Auto, &|_, _| panic!("must not scan")).unwrap();
        assert!(o2.indexed);
        assert_eq!(keys(&o2), ["k1", "k2", "k3"]);
        assert_eq!(o2.scanned_bytes, 0);
        assert_eq!(o2.health.indexed_opens, 1);
        assert_eq!(o2.health.scanned_opens, 1);

        // Offsets must pread back to the original lines.
        let f = std::fs::File::open(&j).unwrap();
        for (r, want) in o2.records.iter().zip(["k1 a\n", "k2 bb\n", "k3 ccc\n"]) {
            let mut buf = vec![0u8; r.len as usize];
            f.read_exact_at(&mut buf, r.offset).unwrap();
            assert_eq!(std::str::from_utf8(&buf).unwrap(), want);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn appended_tail_is_scanned_and_committed() {
        let dir = tmpdir("tail");
        let j = dir.join("j.jsonl");
        std::fs::write(&j, "k1 a\n").unwrap();
        load(&j, IndexMode::Auto, &key_of).unwrap();
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&j).unwrap();
            write!(f, "k2 b\n").unwrap();
        }
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(o.indexed, "the covered prefix must still be served from the sidecar");
        assert_eq!(keys(&o), ["k1", "k2"]);
        assert_eq!(o.scanned_bytes, 5); // only "k2 b\n"
        // And the extension is committed: the next open scans nothing.
        let o = load(&j, IndexMode::Auto, &|_, _| panic!("must not scan")).unwrap();
        assert_eq!(keys(&o), ["k1", "k2"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn journal_mutation_forces_rebuild() {
        let dir = tmpdir("rebuild");
        let j = dir.join("j.jsonl");
        std::fs::write(&j, "k1 a\nk2 b\n").unwrap();
        load(&j, IndexMode::Auto, &key_of).unwrap();
        // Compaction-style rewrite: same length is not enough to fool
        // the tail hash.
        std::fs::write(&j, "k9 a\nk8 b\n").unwrap();
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(!o.indexed);
        assert_eq!(keys(&o), ["k9", "k8"]);
        assert_eq!(o.health.rebuilds, 1);
        // Truncation below covered_len also invalidates.
        std::fs::write(&j, "k9 a\n").unwrap();
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(!o.indexed);
        assert_eq!(keys(&o), ["k9"]);
        assert_eq!(o.health.rebuilds, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_sidecar_tail_drops_uncommitted_records() {
        let dir = tmpdir("tornidx");
        let j = dir.join("j.jsonl");
        std::fs::write(&j, "k1 a\nk2 b\n").unwrap();
        load(&j, IndexMode::Auto, &key_of).unwrap();
        // Simulate a kill mid-extend: staged record + garbage, no cover.
        {
            use std::io::Write as _;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(sidecar_path(&j)).unwrap();
            write!(f, "r 999 5 kghost\nc 12 bad").unwrap();
        }
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(o.indexed, "the committed prefix must survive a torn sidecar tail");
        assert_eq!(keys(&o), ["k1", "k2"], "ghost staged record must be dropped");
        // The rewrite healed the sidecar.
        let o = load(&j, IndexMode::Auto, &|_, _| panic!("must not scan")).unwrap();
        assert_eq!(keys(&o), ["k1", "k2"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn off_mode_is_pure_scan() {
        let dir = tmpdir("off");
        let j = dir.join("j.jsonl");
        std::fs::write(&j, "k1 a\nk2 b\n").unwrap();
        let o = load(&j, IndexMode::Off, &key_of).unwrap();
        assert!(!o.indexed);
        assert_eq!(keys(&o), ["k1", "k2"]);
        assert!(!sidecar_path(&j).exists(), "Off mode must not create a sidecar");
        // Off mode also ignores an existing sidecar entirely.
        load(&j, IndexMode::Auto, &key_of).unwrap();
        std::fs::write(&j, "k7 a\nk6 b\n").unwrap();
        let o = load(&j, IndexMode::Off, &key_of).unwrap();
        assert_eq!(keys(&o), ["k7", "k6"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_and_empty_journals() {
        let dir = tmpdir("empty");
        let j = dir.join("j.jsonl");
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(o.records.is_empty());
        assert!(!sidecar_path(&j).exists(), "missing journal must not spawn a sidecar");
        std::fs::write(&j, "").unwrap();
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(o.records.is_empty());
        let o = load(&j, IndexMode::Auto, &key_of).unwrap();
        assert!(o.indexed, "an empty journal's cover is still a valid cover");
        assert_eq!(health(&j).unwrap(), o.health);
        std::fs::remove_dir_all(dir).ok();
    }
}
