//! Transcript journal for LLM provider calls (DESIGN.md §12).
//!
//! Every live provider call (SimLLM or HTTP) can be recorded to an
//! append-only JSONL journal — by default `<artifacts>/transcripts.jsonl`
//! — keyed by the [`GenerationRequest`] content hash. A recorded
//! campaign can then be re-run with `--provider replay:<path>` and
//! every generation is served from the journal, bit-identically and
//! with **zero live generator calls**: the replay backend has no inner
//! provider to fall back to, so a request outside the journal is a
//! hard error, not a silent regeneration.
//!
//! Journal format (one JSON object per line):
//!
//! * `{"type":"meta","format":1,"provider":"sim"}` — written once,
//!   before the first call line: which backend generated the entries.
//!   Replay impersonates this label so records and reports match the
//!   recording run byte-for-byte.
//! * `{"type":"call","key":"<sha256 of the request>","role":"generate",
//!   "model":"GPT-4.1","seed":"1234...","text":"...","insight":"...",
//!   "prompt_tokens":N,"completion_tokens":N}` — one provider call.
//!   `seed` is a decimal *string* (u64 seeds exceed the f64-exact
//!   integer range our JSON numbers can carry).
//! * `{"type":"route","key":"<sha256 of the request>","member":"alt"}`
//!   — which ensemble member the engine's bandit routed the call to
//!   (DESIGN.md §16). Written only by multi-member ensemble runs, next
//!   to the call it routed, so single-backend journals are unchanged
//!   byte-for-byte. Replay does not *need* these lines (the route is
//!   part of the request hash, and the replay engine re-derives it),
//!   but they make the journal a complete audit record of the bandit's
//!   decisions.
//!
//! Durability matches the eval cache (DESIGN.md §14): call appends are
//! staged in a [`GroupWriter`](super::GroupWriter) and committed at
//! trial-boundary flush points (the `meta` line flushes immediately —
//! it is the journal's identity), a torn final line from a killed
//! process is truncated on reopen, duplicate keys keep their first
//! (original) entry, and opens are served by the sidecar offset index
//! ([`super::index`]) with call bodies `pread` + parsed lazily.
//!
//! [`GenerationRequest`]: crate::llm::GenerationRequest

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use super::index::{self, IndexMode};
use super::GroupWriter;
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

/// Sidecar index key for the journal's `meta` line. Call keys are
/// SHA-256 hex digests, so the `@` prefix cannot collide.
const META_KEY: &str = "@meta";

/// Sidecar index-key suffix for `route` lines: a route shares its
/// request hash with the call it routed, so it is indexed under
/// `<hash>#route` (`#` cannot appear in a hex digest).
const ROUTE_SUFFIX: &str = "#route";

/// One journaled provider call: everything the caller got back, plus
/// the request identity needed to audit it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TranscriptEntry {
    /// `"generate"` or `"repair"` (the [`GenerationRole`] label).
    ///
    /// [`GenerationRole`]: crate::llm::GenerationRole
    pub role: String,
    pub model: String,
    pub seed: u64,
    pub text: String,
    pub insight: String,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

/// One in-memory call slot: parsed, or a journal byte extent hydrated
/// on first lookup (see [`super::Slot`] on the eval cache — same
/// scheme).
#[derive(Debug, Clone)]
enum Slot {
    Parsed(TranscriptEntry),
    OnDisk { offset: u64, len: u32 },
}

/// Append-only transcript journal with an in-memory index.
pub struct TranscriptStore {
    path: PathBuf,
    map: RwLock<HashMap<String, Slot>>,
    /// Journaled ensemble routing decisions, request hash → member
    /// alias. Tiny (one short line per routed call), so hydrated
    /// eagerly at open.
    routes: RwLock<HashMap<String, String>>,
    /// Positioned-read handle for lazy [`Slot::OnDisk`] hydration.
    reader: std::fs::File,
    writer: Mutex<GroupWriter>,
    /// Label of the backend that generated the journal's entries
    /// (from the `meta` line; set on first `record_source`).
    source: RwLock<Option<String>>,
}

impl TranscriptStore {
    /// Open (or create) the journal at `path` and index its entries,
    /// honouring `EVO_JOURNAL_INDEX`. Torn final lines are truncated;
    /// other corrupt lines are skipped with a warning.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        Self::open_with(path, IndexMode::from_env())
    }

    /// [`TranscriptStore::open`] with an explicit index mode.
    pub fn open_with(path: impl AsRef<Path>, mode: IndexMode) -> Result<Arc<Self>> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating transcript dir")?;
            }
        }
        let torn =
            crate::util::truncate_torn_tail(&path).context("repairing transcript tail")?;
        if torn > 0 {
            eprintln!(
                "warning: transcript {}: truncated {torn} bytes of torn final line",
                path.display()
            );
        }
        let writer = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .context("opening transcript journal for append")?;
        let display = path.display().to_string();
        let extract = |off: u64, line: &str| match parse_line(line) {
            Ok(Line::Meta { .. }) => Some(META_KEY.to_string()),
            Ok(Line::Call { key, .. }) => Some(key),
            Ok(Line::Route { key, .. }) => Some(format!("{key}{ROUTE_SUFFIX}")),
            Err(e) => {
                eprintln!("warning: transcript {display}: skipping bad line at byte {off}: {e}");
                None
            }
        };
        let loaded = index::load(&path, mode, &extract).context("indexing transcript")?;
        let reader = std::fs::File::open(&path).context("opening transcript for read")?;
        let mut map = HashMap::new();
        let mut routes = HashMap::new();
        let mut source = None;
        for r in loaded.records {
            if r.key == META_KEY {
                // The journal's identity: hydrate eagerly (first wins).
                if source.is_none() {
                    if let Ok(Line::Meta { provider }) = read_record(&reader, r.offset, r.len) {
                        source = Some(provider);
                    }
                }
            } else if let Some(hash) = r.key.strip_suffix(ROUTE_SUFFIX) {
                if !routes.contains_key(hash) {
                    if let Ok(Line::Route { key, member }) = read_record(&reader, r.offset, r.len)
                    {
                        if key == hash {
                            routes.insert(key, member);
                        }
                    }
                }
            } else {
                map.entry(r.key).or_insert(Slot::OnDisk { offset: r.offset, len: r.len });
            }
        }
        Ok(Arc::new(Self {
            path,
            map: RwLock::new(map),
            routes: RwLock::new(routes),
            reader,
            writer: Mutex::new(GroupWriter::new(writer)),
            source: RwLock::new(source),
        }))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Label of the backend that generated this journal, if recorded.
    pub fn source(&self) -> Option<String> {
        self.source.read().unwrap().clone()
    }

    /// Declare the generating backend. Journals are single-source: a
    /// journal recorded by one backend refuses calls from another (the
    /// replay impersonation contract would otherwise be ambiguous).
    pub fn record_source(&self, label: &str) -> Result<()> {
        {
            let g = self.source.read().unwrap();
            match g.as_deref() {
                Some(existing) if existing == label => return Ok(()),
                Some(existing) => {
                    return Err(eyre!(
                        "transcript journal {} was recorded by `{existing}`; refusing to \
                         append `{label}` calls (use a fresh journal per backend)",
                        self.path.display()
                    ))
                }
                None => {}
            }
        }
        let mut g = self.source.write().unwrap();
        if g.is_none() {
            let line = Json::obj(vec![
                ("type", Json::Str("meta".into())),
                ("format", Json::Num(1.0)),
                ("provider", Json::Str(label.to_string())),
            ])
            .to_string();
            // The meta line is the journal's identity — flush it
            // through immediately rather than waiting for a trial
            // boundary.
            let mut w = self.writer.lock().unwrap();
            w.append_line(line.as_bytes())?;
            w.flush()?;
            *g = Some(label.to_string());
        }
        Ok(())
    }

    /// Journaled response for a request hash, hydrating an on-disk
    /// slot on first touch. A slot whose bytes no longer parse to the
    /// expected key is dropped with a warning (see the eval cache's
    /// `fetch` — same contract).
    pub fn lookup(&self, key: &str) -> Option<TranscriptEntry> {
        let extent = {
            let g = self.map.read().unwrap();
            match g.get(key)? {
                Slot::Parsed(entry) => return Some(entry.clone()),
                Slot::OnDisk { offset, len } => (*offset, *len),
            }
        };
        let (offset, len) = extent;
        match read_record(&self.reader, offset, len) {
            Ok(Line::Call { key: line_key, entry }) if line_key == key => {
                self.map
                    .write()
                    .unwrap()
                    .insert(key.to_string(), Slot::Parsed(entry.clone()));
                Some(entry)
            }
            other => {
                let why = match other {
                    Ok(Line::Call { key: k, .. }) => format!("record at byte {offset} keyed `{k}`"),
                    Ok(Line::Meta { .. }) => format!("record at byte {offset} is a meta line"),
                    Ok(Line::Route { .. }) => format!("record at byte {offset} is a route line"),
                    Err(e) => format!("record at byte {offset} unreadable: {e}"),
                };
                eprintln!(
                    "warning: transcript {}: dropping stale index slot for `{key}`: {why}",
                    self.path.display()
                );
                self.map.write().unwrap().remove(key);
                None
            }
        }
    }

    /// Append one call. A key already present (identical request seen
    /// twice — same prompt, seed and role) keeps its first entry and
    /// is not re-journaled. The append is staged in the group-commit
    /// buffer; durability arrives at the next [`TranscriptStore::flush`].
    pub fn append(&self, key: &str, entry: TranscriptEntry) -> Result<()> {
        {
            let mut g = self.map.write().unwrap();
            if g.contains_key(key) {
                return Ok(());
            }
            g.insert(key.to_string(), Slot::Parsed(entry.clone()));
        }
        let line = call_line(key, &entry).to_string();
        self.writer.lock().unwrap().append_line(line.as_bytes())?;
        Ok(())
    }

    /// Append one ensemble routing decision (DESIGN.md §16). Same
    /// dedup-first-wins and group-commit staging as [`append`]: a
    /// request's route is as immutable as its response.
    ///
    /// [`append`]: TranscriptStore::append
    pub fn append_route(&self, key: &str, member: &str) -> Result<()> {
        {
            let mut g = self.routes.write().unwrap();
            if g.contains_key(key) {
                return Ok(());
            }
            g.insert(key.to_string(), member.to_string());
        }
        let line = route_line(key, member).to_string();
        self.writer.lock().unwrap().append_line(line.as_bytes())?;
        Ok(())
    }

    /// Journaled routing decision for a request hash, if any.
    pub fn route(&self, key: &str) -> Option<String> {
        self.routes.read().unwrap().get(key).cloned()
    }

    /// Journaled routing decisions (multi-member ensemble runs only;
    /// 0 for every single-backend journal).
    pub fn route_count(&self) -> usize {
        self.routes.read().unwrap().len()
    }

    /// Merge one journal line uploaded by another process (the
    /// campaign coordinator's transcript-merge path, DESIGN.md §15).
    /// A fresh `call` line is appended through the normal dedup path;
    /// keys already present and `meta` lines are skipped (the
    /// single-source contract is enforced by [`record_source`], which
    /// the owner calls with the provider's label before any merge).
    /// Returns whether the line was ingested.
    ///
    /// [`record_source`]: TranscriptStore::record_source
    pub fn ingest_line(&self, line: &str) -> Result<bool> {
        match parse_line(line).map_err(|e| eyre!("ingesting uploaded transcript line: {e:#}"))? {
            Line::Meta { .. } => Ok(false),
            Line::Call { key, entry } => {
                if self.lookup(&key).is_some() {
                    return Ok(false);
                }
                self.append(&key, entry)?;
                Ok(true)
            }
            Line::Route { key, member } => {
                if self.route(&key).is_some() {
                    return Ok(false);
                }
                self.append_route(&key, &member)?;
                Ok(true)
            }
        }
    }

    /// Group-commit flush point: make every staged call durable.
    pub fn flush(&self) -> Result<()> {
        self.writer.lock().unwrap().flush()?;
        Ok(())
    }

    /// Test hook: simulate a kill between append and flush.
    #[doc(hidden)]
    pub fn drop_unflushed(&self) {
        self.writer.lock().unwrap().drop_unflushed();
    }

    /// Unique journaled calls.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

enum Line {
    Meta { provider: String },
    Call { key: String, entry: TranscriptEntry },
    Route { key: String, member: String },
}

/// `pread` + parse one journal line by its indexed byte extent.
fn read_record(reader: &std::fs::File, offset: u64, len: u32) -> Result<Line> {
    use std::os::unix::fs::FileExt as _;
    let mut buf = vec![0u8; len as usize];
    reader.read_exact_at(&mut buf, offset).map_err(|e| eyre!("{e}"))?;
    let text = std::str::from_utf8(&buf).map_err(|e| eyre!("{e}"))?;
    parse_line(text.trim_end_matches('\n'))
}

fn call_line(key: &str, e: &TranscriptEntry) -> Json {
    Json::obj(vec![
        ("type", Json::Str("call".into())),
        ("key", Json::Str(key.to_string())),
        ("role", Json::Str(e.role.clone())),
        ("model", Json::Str(e.model.clone())),
        // Decimal string: u64 seeds exceed f64-exact integers.
        ("seed", Json::Str(e.seed.to_string())),
        ("text", Json::Str(e.text.clone())),
        ("insight", Json::Str(e.insight.clone())),
        ("prompt_tokens", Json::Num(e.prompt_tokens as f64)),
        ("completion_tokens", Json::Num(e.completion_tokens as f64)),
    ])
}

fn route_line(key: &str, member: &str) -> Json {
    Json::obj(vec![
        ("type", Json::Str("route".into())),
        ("key", Json::Str(key.to_string())),
        ("member", Json::Str(member.to_string())),
    ])
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("missing string field `{key}`"))
}

fn parse_line(line: &str) -> Result<Line> {
    let v = json::parse(line).map_err(|e| eyre!("{e}"))?;
    match v.get("type").and_then(|t| t.as_str()) {
        Some("meta") => Ok(Line::Meta { provider: get_str(&v, "provider")? }),
        Some("call") => {
            let key = get_str(&v, "key")?;
            let seed_str = get_str(&v, "seed")?;
            let seed: u64 = seed_str
                .parse()
                .map_err(|_| eyre!("bad seed `{seed_str}`"))?;
            let entry = TranscriptEntry {
                role: get_str(&v, "role")?,
                model: get_str(&v, "model")?,
                seed,
                text: get_str(&v, "text")?,
                insight: get_str(&v, "insight")?,
                prompt_tokens: v
                    .get("prompt_tokens")
                    .and_then(|x| x.as_u64())
                    .ok_or_else(|| eyre!("missing prompt_tokens"))?,
                completion_tokens: v
                    .get("completion_tokens")
                    .and_then(|x| x.as_u64())
                    .ok_or_else(|| eyre!("missing completion_tokens"))?,
            };
            Ok(Line::Call { key, entry })
        }
        Some("route") => Ok(Line::Route {
            key: get_str(&v, "key")?,
            member: get_str(&v, "member")?,
        }),
        other => Err(eyre!("unknown transcript line type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evo_transcript_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join("transcripts.jsonl")
    }

    fn sample(seed: u64) -> TranscriptEntry {
        TranscriptEntry {
            role: "generate".into(),
            model: "GPT-4.1".into(),
            seed,
            text: "kernel matmul_64 { semantics: opt; }".into(),
            insight: "widened the loads".into(),
            prompt_tokens: 120,
            completion_tokens: 48,
        }
    }

    #[test]
    fn roundtrip_across_reopen_with_meta() {
        let path = tmpfile("rt");
        std::fs::remove_file(&path).ok();
        // u64 seed beyond f64-exact range must survive the journal.
        let big_seed = u64::MAX - 12345;
        {
            let t = TranscriptStore::open(&path).unwrap();
            t.record_source("sim").unwrap();
            t.append("k1", sample(big_seed)).unwrap();
            let mut repair = sample(7);
            repair.role = "repair".into();
            t.append("k2", repair).unwrap();
            // Duplicate key: first entry wins, no second line.
            let mut dup = sample(1);
            dup.text = "SHOULD NOT APPEAR".into();
            t.append("k1", dup).unwrap();
        }
        let t = TranscriptStore::open(&path).unwrap();
        assert_eq!(t.source().as_deref(), Some("sim"));
        assert_eq!(t.len(), 2);
        let back = t.lookup("k1").unwrap();
        assert_eq!(back, sample(big_seed));
        assert_eq!(t.lookup("k2").unwrap().role, "repair");
        assert!(t.lookup("k3").is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ingest_line_merges_and_dedups() {
        let src = tmpfile("ingest_src");
        let dst = tmpfile("ingest_dst");
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
        {
            let t = TranscriptStore::open(&src).unwrap();
            t.record_source("sim").unwrap();
            t.append("k1", sample(9)).unwrap();
            t.flush().unwrap();
        }
        let lines: Vec<String> = std::fs::read_to_string(&src)
            .unwrap()
            .lines()
            .map(String::from)
            .collect();
        let t = TranscriptStore::open(&dst).unwrap();
        t.record_source("sim").unwrap();
        let mut merged = 0;
        for line in &lines {
            if t.ingest_line(line).unwrap() {
                merged += 1;
            }
        }
        // The meta line is skipped, the call line merges once.
        assert_eq!(merged, 1);
        for line in &lines {
            assert!(!t.ingest_line(line).unwrap(), "second pass is all dups");
        }
        t.flush().unwrap();
        let back = TranscriptStore::open(&dst).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.lookup("k1").unwrap(), sample(9));
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn route_lines_roundtrip_dedup_and_merge() {
        let path = tmpfile("route");
        std::fs::remove_file(&path).ok();
        {
            let t = TranscriptStore::open(&path).unwrap();
            t.record_source("ensemble:[sim@1,sim#alt@1,x=0.25]").unwrap();
            t.append("k1", sample(5)).unwrap();
            t.append_route("k1", "alt").unwrap();
            // First route wins, like call dedup.
            t.append_route("k1", "sim").unwrap();
            t.flush().unwrap();
        }
        let t = TranscriptStore::open(&path).unwrap();
        assert_eq!(t.route("k1").as_deref(), Some("alt"));
        assert_eq!(t.route_count(), 1);
        assert!(t.route("k2").is_none());
        // Calls and routes share the hash key without colliding.
        assert_eq!(t.lookup("k1").unwrap(), sample(5));
        assert_eq!(t.len(), 1);

        // Wire merge: route lines ingest once, dedup after.
        let dst = tmpfile("route_dst");
        std::fs::remove_file(&dst).ok();
        let d = TranscriptStore::open(&dst).unwrap();
        d.record_source("ensemble:[sim@1,sim#alt@1,x=0.25]").unwrap();
        let lines: Vec<String> = std::fs::read_to_string(&path)
            .unwrap()
            .lines()
            .map(String::from)
            .collect();
        let merged: usize = lines.iter().filter(|l| d.ingest_line(l).unwrap()).count();
        assert_eq!(merged, 2, "one call + one route");
        assert!(lines.iter().all(|l| !d.ingest_line(l).unwrap()));
        assert_eq!(d.route("k1").as_deref(), Some("alt"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn single_source_contract() {
        let path = tmpfile("src");
        std::fs::remove_file(&path).ok();
        let t = TranscriptStore::open(&path).unwrap();
        t.record_source("sim").unwrap();
        t.record_source("sim").unwrap(); // idempotent
        let err = t.record_source("http").unwrap_err();
        assert!(err.to_string().contains("recorded by `sim`"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_truncated() {
        let path = tmpfile("torn");
        std::fs::remove_file(&path).ok();
        {
            let t = TranscriptStore::open(&path).unwrap();
            t.record_source("sim").unwrap();
            t.append("k1", sample(3)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"type\":\"call\",\"key\":\"dead").unwrap();
        }
        let t = TranscriptStore::open(&path).unwrap();
        assert_eq!(t.len(), 1);
        assert!(t.lookup("k1").is_some());
        std::fs::remove_file(&path).ok();
    }
}
