//! Content addressing for the evaluation cache: SHA-256 (FIPS 180-4,
//! self-contained — the build environment is offline, so no crypto
//! crate) plus the canonicalization rule that makes the key stable.
//!
//! A candidate's identity is its *canonical printed form*: the raw LLM
//! emission is parsed and re-emitted through [`crate::dsl::printer`],
//! so two textually different programs that parse to the same
//! [`crate::dsl::KernelSpec`] (whitespace, token spacing) share one
//! key, while any semantic or schedule difference changes it. The op
//! name is mixed into the digest (NUL-separated) because the same text
//! evaluates differently under different tasks (the `WrongOp` gate).

use crate::dsl;

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

/// SHA-256 digest of `data`.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = H0;
    let bit_len = (data.len() as u64).wrapping_mul(8);

    // Padded message: data || 0x80 || zeros || 8-byte big-endian length.
    let mut msg = Vec::with_capacity(data.len() + 72);
    msg.extend_from_slice(data);
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());

    let mut w = [0u32; 64];
    for block in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }

    let mut out = [0u8; 32];
    for (i, word) in h.iter().enumerate() {
        out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// Lowercase-hex SHA-256 of `data`.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut s = String::with_capacity(64);
    for b in sha256(data) {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Content-addressed identity of one (candidate, op) evaluation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey(pub String);

impl EvalKey {
    /// Key from an already-canonical printed form.
    pub fn from_canonical(op: &str, canonical: &str) -> Self {
        let mut buf = Vec::with_capacity(op.len() + 1 + canonical.len());
        buf.extend_from_slice(op.as_bytes());
        buf.push(0);
        buf.extend_from_slice(canonical.as_bytes());
        EvalKey(sha256_hex(&buf))
    }

    /// Key for a *stage-0 guard verdict* on a candidate. Two
    /// differences from [`EvalKey::from_canonical`]:
    ///
    /// * the `guard\0` prefix namespaces guard rejections away from
    ///   full-pipeline records — a guard-gated run must never replay a
    ///   stage-0 rejection as a stage-1..3 outcome, and an unguarded
    ///   run must never pick up a guard rejection for a candidate it
    ///   would have compiled (DESIGN.md §11);
    /// * the digest covers the **raw emission text**, not the
    ///   canonical re-print: stage-0 diagnostics depend on surface
    ///   features canonicalization erases (a shadowed schedule binding
    ///   prints identically to its clean last-wins form), so keying on
    ///   the canonical form would let distinct raw candidates replay
    ///   each other's diagnostics.
    pub fn guarded(op: &str, raw_src: &str) -> Self {
        let mut buf = Vec::with_capacity(6 + op.len() + 1 + raw_src.len());
        buf.extend_from_slice(b"guard\0");
        buf.extend_from_slice(op.as_bytes());
        buf.push(0);
        buf.extend_from_slice(raw_src.as_bytes());
        EvalKey(sha256_hex(&buf))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

/// Key for a raw candidate emission: parse, re-print canonically, hash.
/// `None` when the text does not parse — unparseable candidates are a
/// cheap deterministic `CompileFail` and are not worth a journal entry.
pub fn key_for_source(op: &str, src: &str) -> Option<EvalKey> {
    let spec = dsl::parse(src).ok()?;
    Some(EvalKey::from_canonical(op, &dsl::print(&spec)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::KernelSpec;

    #[test]
    fn fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn multi_block_input() {
        // > 64 bytes forces a second compression block.
        let long = vec![b'a'; 200];
        assert_eq!(sha256_hex(&long).len(), 64);
        assert_ne!(sha256_hex(&long), sha256_hex(&long[..199]));
    }

    #[test]
    fn key_is_canonical_not_textual() {
        let spec = KernelSpec::baseline("matmul_64");
        let src = crate::dsl::print(&spec);
        // Same program, different surface text (whitespace churn).
        let noisy = src.replace("; ", ";   ").replace("{\n", "{\n\n");
        assert_ne!(src, noisy);
        assert_eq!(
            key_for_source("matmul_64", &src),
            key_for_source("matmul_64", &noisy)
        );
        // Different op ⇒ different key for identical text.
        assert_ne!(
            key_for_source("matmul_64", &src),
            key_for_source("softmax_64", &src)
        );
        // Unparseable ⇒ no key.
        assert_eq!(key_for_source("matmul_64", "__global__ void k() {}"), None);
    }

    #[test]
    fn guard_keys_are_namespaced_and_raw_textual() {
        let spec = KernelSpec::baseline("matmul_64");
        let canonical = crate::dsl::print(&spec);
        let full = EvalKey::from_canonical("matmul_64", &canonical);
        let guard = EvalKey::guarded("matmul_64", &canonical);
        // Same candidate, disjoint key spaces.
        assert_ne!(full, guard);
        // Deterministic within each space.
        assert_eq!(guard, EvalKey::guarded("matmul_64", &canonical));
        assert_ne!(guard, EvalKey::guarded("softmax_64", &canonical));
        // Guard keys are *raw-text* identities: a shadowed-binding
        // variant canonicalizes to the same printed form but must not
        // share a guard key with it.
        let shadowed = canonical.replacen("tile_m: 8;", "tile_m: 4; tile_m: 8;", 1);
        assert_ne!(
            EvalKey::guarded("matmul_64", &shadowed),
            EvalKey::guarded("matmul_64", &canonical)
        );
    }
}
