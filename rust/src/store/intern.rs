//! Interning for the canonical-print → SHA-256 keying path
//! (DESIGN.md §14).
//!
//! Deriving a candidate's [`EvalKey`] costs a full parse, a canonical
//! re-print and a SHA-256 over the result. Warm campaigns pay that
//! price for the *same* texts over and over: every method bootstraps
//! from the op baseline, populations revisit popular schedule points,
//! and a resumed leg re-derives the key of every replayed trial. The
//! [`KeyInterner`] memoizes the whole raw-text → key derivation —
//! including the exact `CompileFail` error string an unparseable text
//! produces — keyed by `(op, raw source)`, so re-keying an unchanged
//! population is one hash-map probe instead of a parse+print+SHA.
//!
//! Byte-identity is free here: the derivation is a pure function of
//! `(op, src)`, so a memoized answer is definitionally identical to a
//! recomputed one. The map is bounded (epoch-cleared at capacity)
//! because campaign-scale runs see an unbounded stream of novel
//! candidate texts.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::hash::EvalKey;
use crate::{dsl, ir};

/// The memoized result of keying one raw candidate text for one op.
#[derive(Debug, Clone)]
pub enum Keyed {
    /// The text parses; its content-addressed identity.
    Key(EvalKey),
    /// The text does not parse; the exact stage-1 syntax-rejection
    /// error string (`CompileError::Syntax` rendering) the evaluator
    /// reports, so replays of the rejection stay byte-identical.
    Unparseable(String),
}

/// Bounded, shared memo for the raw-text → [`EvalKey`] derivation.
/// Cheap to share: the [`Evaluator`](crate::evals::Evaluator) clones
/// hold it in an `Arc`, so campaign workers dedupe across threads.
pub struct KeyInterner {
    map: RwLock<HashMap<String, Keyed>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl KeyInterner {
    /// Default capacity: comfortably holds a campaign cell's working
    /// set (budget × population revisits) without unbounded growth.
    pub fn new() -> Self {
        Self::with_capacity(4096)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            map: RwLock::new(HashMap::new()),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The key (or canonical syntax-rejection error) for `src` under
    /// `op`, derived at most once per interner epoch.
    pub fn key_for(&self, op: &str, src: &str) -> Keyed {
        let mut memo = String::with_capacity(op.len() + 1 + src.len());
        memo.push_str(op);
        memo.push('\0');
        memo.push_str(src);
        if let Some(k) = self.map.read().unwrap().get(&memo) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return k.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let keyed = match dsl::parse(src) {
            Ok(spec) => Keyed::Key(EvalKey::from_canonical(op, &dsl::print(&spec))),
            Err(e) => Keyed::Unparseable(ir::CompileError::Syntax(e.to_string()).to_string()),
        };
        let mut map = self.map.write().unwrap();
        if map.len() >= self.capacity {
            // Epoch clear: dumb and O(1) amortized. An LRU would save
            // re-derivations across epochs but put a linked-list walk
            // on the hit path — the path this type exists to shorten.
            map.clear();
        }
        map.entry(memo).or_insert_with(|| keyed.clone());
        keyed
    }

    /// Memo probes served without a derivation (this process).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Derivations performed (this process).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Interned entries currently held.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for KeyInterner {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::KernelSpec;

    #[test]
    fn interned_key_matches_direct_derivation() {
        let interner = KeyInterner::new();
        let spec = KernelSpec::baseline("matmul_64");
        let src = dsl::print(&spec);
        let direct = EvalKey::from_canonical("matmul_64", &dsl::print(&dsl::parse(&src).unwrap()));
        for _ in 0..3 {
            match interner.key_for("matmul_64", &src) {
                Keyed::Key(k) => assert_eq!(k, direct),
                Keyed::Unparseable(e) => panic!("unexpected parse failure: {e}"),
            }
        }
        assert_eq!(interner.misses(), 1, "one derivation serves every probe");
        assert_eq!(interner.hits(), 2);

        // The op is part of the memo key.
        match interner.key_for("softmax_64", &src) {
            Keyed::Key(k) => assert_ne!(k, direct),
            Keyed::Unparseable(e) => panic!("unexpected parse failure: {e}"),
        }
        assert_eq!(interner.misses(), 2);
    }

    #[test]
    fn unparseable_error_string_is_memoized_exactly() {
        let interner = KeyInterner::new();
        let garbage = "__global__ void k() {}";
        let expect = match dsl::parse(garbage) {
            Err(e) => ir::CompileError::Syntax(e.to_string()).to_string(),
            Ok(_) => panic!("garbage parsed"),
        };
        for _ in 0..2 {
            match interner.key_for("matmul_64", garbage) {
                Keyed::Unparseable(e) => assert_eq!(e, expect),
                Keyed::Key(k) => panic!("garbage produced a key: {k:?}"),
            }
        }
        assert_eq!(interner.misses(), 1);
    }

    #[test]
    fn epoch_clear_bounds_the_map() {
        let interner = KeyInterner::with_capacity(4);
        for i in 0..20 {
            let _ = interner.key_for("matmul_64", &format!("junk {i}"));
        }
        assert!(interner.len() <= 4, "map must stay bounded, saw {}", interner.len());
        // Correctness is unaffected by clears.
        let spec = KernelSpec::baseline("matmul_64");
        let src = dsl::print(&spec);
        match interner.key_for("matmul_64", &src) {
            Keyed::Key(k) => {
                assert_eq!(k, EvalKey::from_canonical("matmul_64", &dsl::print(&spec)))
            }
            Keyed::Unparseable(e) => panic!("{e}"),
        }
    }
}
