//! Trial-event journal (DESIGN.md §13).
//!
//! The trial engine ([`crate::methods::engine`]) emits one structured
//! [`TrialEvent`] per observable step of every optimization run: run
//! started, trial started, stage-0 guard verdict, repair attempts, the
//! evaluation outcome (with per-trial token usage and the raw-emission
//! hash), new-best improvements, budget exhaustion, run finished. The
//! `JournalSink` appends them here as one JSON object per line —
//! by default `store/events.jsonl` next to the campaign output — so a
//! sweep's complete per-trial history survives the process and can be:
//!
//! * tailed live (`tail -f`) or replayed by `repro report events`;
//! * scanned on `campaign --resume` to find half-finished cells
//!   ([`completed_trials`]) and to *verify* that the resumed leg's
//!   replayed trials re-derive byte-identical emissions (the engine
//!   warns on any `src_hash` divergence — journal drift would mean the
//!   bit-identical-resume contract was violated);
//! * uploaded as a CI artifact next to the report and cache stats.
//!
//! Durability matches the eval cache and transcript journal
//! (DESIGN.md §14): appends are staged in a
//! [`GroupWriter`](super::GroupWriter) and committed at trial-boundary
//! flush points, a torn final line from a killed process is truncated
//! on reopen, and corrupt interior lines are skipped with a warning.
//! Resume scans ([`completed_trials_at`]) are served by the sidecar
//! offset index, reading only the event kinds resume cares about.
//! Format drift is guarded by a bundled fixture journal replayed in
//! the test suite (`tests/trial_engine.rs`).

use std::collections::HashMap;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::index::{self, IndexMode};
use super::GroupWriter;
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

/// Journal format version (the `v` field of every line).
pub const EVENT_FORMAT: u64 = 1;

/// A cell identity: the (method, model, op, seed) grid point the event
/// belongs to.
pub type CellKey = (String, String, String, u64);

/// One structured engine event, tagged with its cell.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialEvent {
    pub method: String,
    pub model: String,
    pub op: String,
    pub seed: u64,
    pub kind: TrialEventKind,
}

impl TrialEvent {
    pub fn cell(&self) -> CellKey {
        (self.method.clone(), self.model.clone(), self.op.clone(), self.seed)
    }
}

/// The event taxonomy (DESIGN.md §13). Every variant is cheap, flat
/// data — no candidate sources, only hashes — so journaling cost stays
/// negligible next to a provider call or a PJRT execution.
#[derive(Debug, Clone, PartialEq)]
pub enum TrialEventKind {
    /// A (method, model, op, seed) run began under `budget` trials.
    RunStarted { budget: usize, provider: String },
    /// Trial group `trial` began (the generate call is about to run).
    TrialStarted { trial: usize },
    /// Stage-0 guard verdict on the initial emission of `trial`.
    GuardVerdict { trial: usize, pass: bool, diagnostics: usize },
    /// One LLM repair attempt within `trial` (consumed a budget unit);
    /// `mended` is the guard verdict on the repaired text.
    RepairAttempt { trial: usize, attempt: usize, mended: bool },
    /// Terminal evaluation outcome of trial group `trial`. `speedup`
    /// is the noise-free speedup when valid, 0 otherwise; the token
    /// counts cover the whole group (generate + repairs); `src_hash`
    /// is a truncated SHA-256 of the raw evaluated emission (the
    /// resume-verification identity).
    EvalOutcome {
        trial: usize,
        outcome: String,
        speedup: f64,
        prompt_tokens: u64,
        completion_tokens: u64,
        src_hash: String,
    },
    /// The trial produced a new best valid candidate.
    NewBest { trial: usize, speedup: f64 },
    /// The trial budget hit zero.
    BudgetExhausted { trials: usize },
    /// The run completed and its record was produced.
    RunFinished { trials: usize, best_speedup: f64, any_valid: bool },
}

impl TrialEventKind {
    /// Stable journal label of the variant.
    pub fn label(&self) -> &'static str {
        match self {
            TrialEventKind::RunStarted { .. } => "run_started",
            TrialEventKind::TrialStarted { .. } => "trial_started",
            TrialEventKind::GuardVerdict { .. } => "guard_verdict",
            TrialEventKind::RepairAttempt { .. } => "repair_attempt",
            TrialEventKind::EvalOutcome { .. } => "eval_outcome",
            TrialEventKind::NewBest { .. } => "new_best",
            TrialEventKind::BudgetExhausted { .. } => "budget_exhausted",
            TrialEventKind::RunFinished { .. } => "run_finished",
        }
    }
}

/// Serialize one event to its journal line (flat JSON object).
pub fn event_to_json(ev: &TrialEvent) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("type", Json::Str("event".into())),
        ("v", Json::Num(EVENT_FORMAT as f64)),
        ("method", Json::Str(ev.method.clone())),
        ("model", Json::Str(ev.model.clone())),
        ("op", Json::Str(ev.op.clone())),
        ("seed", Json::Num(ev.seed as f64)),
        ("kind", Json::Str(ev.kind.label().into())),
    ];
    match &ev.kind {
        TrialEventKind::RunStarted { budget, provider } => {
            pairs.push(("budget", Json::Num(*budget as f64)));
            pairs.push(("provider", Json::Str(provider.clone())));
        }
        TrialEventKind::TrialStarted { trial } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
        }
        TrialEventKind::GuardVerdict { trial, pass, diagnostics } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push(("pass", Json::Bool(*pass)));
            pairs.push(("diagnostics", Json::Num(*diagnostics as f64)));
        }
        TrialEventKind::RepairAttempt { trial, attempt, mended } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push(("attempt", Json::Num(*attempt as f64)));
            pairs.push(("mended", Json::Bool(*mended)));
        }
        TrialEventKind::EvalOutcome {
            trial,
            outcome,
            speedup,
            prompt_tokens,
            completion_tokens,
            src_hash,
        } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push(("outcome", Json::Str(outcome.clone())));
            pairs.push(("speedup", Json::Num(*speedup)));
            pairs.push(("prompt_tokens", Json::Num(*prompt_tokens as f64)));
            pairs.push(("completion_tokens", Json::Num(*completion_tokens as f64)));
            pairs.push(("src_hash", Json::Str(src_hash.clone())));
        }
        TrialEventKind::NewBest { trial, speedup } => {
            pairs.push(("trial", Json::Num(*trial as f64)));
            pairs.push(("speedup", Json::Num(*speedup)));
        }
        TrialEventKind::BudgetExhausted { trials } => {
            pairs.push(("trials", Json::Num(*trials as f64)));
        }
        TrialEventKind::RunFinished { trials, best_speedup, any_valid } => {
            pairs.push(("trials", Json::Num(*trials as f64)));
            pairs.push(("best_speedup", Json::Num(*best_speedup)));
            pairs.push(("any_valid", Json::Bool(*any_valid)));
        }
    }
    Json::obj(pairs)
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("event missing string field `{key}`"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| eyre!("event missing numeric field `{key}`"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| eyre!("event missing numeric field `{key}`"))
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| eyre!("event missing numeric field `{key}`"))
}

fn get_bool(v: &Json, key: &str) -> Result<bool> {
    v.get(key)
        .and_then(|x| x.as_bool())
        .ok_or_else(|| eyre!("event missing bool field `{key}`"))
}

/// Parse one journal line back into a [`TrialEvent`].
pub fn event_from_json(v: &Json) -> Result<TrialEvent> {
    if v.get("type").and_then(|t| t.as_str()) != Some("event") {
        return Err(eyre!("not an event line"));
    }
    let kind = match get_str(v, "kind")?.as_str() {
        "run_started" => TrialEventKind::RunStarted {
            budget: get_usize(v, "budget")?,
            provider: get_str(v, "provider")?,
        },
        "trial_started" => TrialEventKind::TrialStarted { trial: get_usize(v, "trial")? },
        "guard_verdict" => TrialEventKind::GuardVerdict {
            trial: get_usize(v, "trial")?,
            pass: get_bool(v, "pass")?,
            diagnostics: get_usize(v, "diagnostics")?,
        },
        "repair_attempt" => TrialEventKind::RepairAttempt {
            trial: get_usize(v, "trial")?,
            attempt: get_usize(v, "attempt")?,
            mended: get_bool(v, "mended")?,
        },
        "eval_outcome" => TrialEventKind::EvalOutcome {
            trial: get_usize(v, "trial")?,
            outcome: get_str(v, "outcome")?,
            speedup: get_f64(v, "speedup")?,
            prompt_tokens: get_u64(v, "prompt_tokens")?,
            completion_tokens: get_u64(v, "completion_tokens")?,
            src_hash: get_str(v, "src_hash")?,
        },
        "new_best" => TrialEventKind::NewBest {
            trial: get_usize(v, "trial")?,
            speedup: get_f64(v, "speedup")?,
        },
        "budget_exhausted" => {
            TrialEventKind::BudgetExhausted { trials: get_usize(v, "trials")? }
        }
        "run_finished" => TrialEventKind::RunFinished {
            trials: get_usize(v, "trials")?,
            best_speedup: get_f64(v, "best_speedup")?,
            any_valid: get_bool(v, "any_valid")?,
        },
        other => return Err(eyre!("unknown event kind `{other}`")),
    };
    Ok(TrialEvent {
        method: get_str(v, "method")?,
        model: get_str(v, "model")?,
        op: get_str(v, "op")?,
        seed: get_u64(v, "seed")?,
        kind,
    })
}

/// Append-only JSONL event journal, shared by every campaign worker.
pub struct EventJournal {
    path: PathBuf,
    writer: Mutex<GroupWriter>,
}

impl EventJournal {
    /// Open the journal for append, repairing a torn tail first.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        Self::open_inner(path.as_ref(), false)
    }

    /// Start the journal over (a fresh, non-resumed campaign must not
    /// accumulate events from an older sweep).
    pub fn create(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        Self::open_inner(path.as_ref(), true)
    }

    fn open_inner(path: &Path, truncate: bool) -> Result<Arc<Self>> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating event-journal dir")?;
            }
        }
        if truncate {
            std::fs::File::create(path).context("truncating event journal")?;
            // The sidecar indexed the old sweep's events.
            index::delete_sidecar(path);
        } else {
            let torn =
                crate::util::truncate_torn_tail(path).context("repairing event-journal tail")?;
            if torn > 0 {
                eprintln!(
                    "warning: event journal {}: truncated {torn} bytes of torn final line",
                    path.display()
                );
            }
        }
        let writer = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .context("opening event journal for append")?;
        Ok(Arc::new(Self {
            path: path.to_path_buf(),
            writer: Mutex::new(GroupWriter::new(writer)),
        }))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event. Staged in the group-commit buffer; durability
    /// arrives at the next [`EventJournal::flush`] (the engine's
    /// journal sink flushes at every trial boundary).
    pub fn append(&self, ev: &TrialEvent) -> Result<()> {
        let line = event_to_json(ev).to_string();
        self.writer.lock().unwrap().append_line(line.as_bytes())?;
        Ok(())
    }

    /// Group-commit flush point: make every staged event durable.
    pub fn flush(&self) -> Result<()> {
        self.writer.lock().unwrap().flush()?;
        Ok(())
    }

    /// Test hook: simulate a kill between append and flush.
    #[doc(hidden)]
    pub fn drop_unflushed(&self) {
        self.writer.lock().unwrap().drop_unflushed();
    }

    /// Load every parseable event from a journal file; corrupt lines
    /// are skipped with a warning (advisory data, never fatal).
    pub fn load(path: impl AsRef<Path>) -> Result<Vec<TrialEvent>> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening event journal {}", path.display()))?;
        let mut out = Vec::new();
        for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let parsed = json::parse(&line)
                .map_err(|e| eyre!("{e}"))
                .and_then(|v| event_from_json(&v));
            match parsed {
                Ok(ev) => out.push(ev),
                Err(e) => eprintln!(
                    "warning: event journal {}: skipping bad line {}: {e}",
                    path.display(),
                    i + 1
                ),
            }
        }
        Ok(out)
    }
}

/// Per-cell replay index for trial-granular resume: every *unfinished*
/// cell the journal has seen, mapped to its completed trial groups as
/// `(trial, src_hash)` pairs in journal order. A cell killed before
/// its first evaluation still gets an (empty) entry — the resumed leg
/// must know its `RunStarted` is already journaled. Cells that reached
/// `RunFinished` are omitted — the cell checkpoint journal already
/// covers them, and their records are merged whole on resume.
pub fn completed_trials(events: &[TrialEvent]) -> HashMap<CellKey, Vec<(usize, String)>> {
    let mut map: HashMap<CellKey, Vec<(usize, String)>> = HashMap::new();
    let mut finished: std::collections::HashSet<CellKey> = std::collections::HashSet::new();
    for ev in events {
        match &ev.kind {
            TrialEventKind::RunStarted { .. } => {
                map.entry(ev.cell()).or_default();
            }
            TrialEventKind::EvalOutcome { trial, src_hash, .. } => {
                map.entry(ev.cell()).or_default().push((*trial, src_hash.clone()));
            }
            TrialEventKind::RunFinished { .. } => {
                finished.insert(ev.cell());
            }
            _ => {}
        }
    }
    map.retain(|cell, _| !finished.contains(cell));
    map
}

/// [`completed_trials`] straight from a journal file, served by the
/// sidecar offset index: events are keyed by kind label, so a resume
/// scan `pread`s only the `run_started` / `eval_outcome` /
/// `run_finished` lines it folds — the (dominant) per-trial chatter
/// (guard verdicts, repair attempts, new-bests) is never read on a
/// warm resume. `IndexMode::Off` falls back to the full
/// [`EventJournal::load`] scan; both paths produce identical maps. A
/// missing journal yields an empty map.
pub fn completed_trials_at(
    path: impl AsRef<Path>,
    mode: IndexMode,
) -> Result<HashMap<CellKey, Vec<(usize, String)>>> {
    let path = path.as_ref();
    if !path.exists() {
        return Ok(HashMap::new());
    }
    if mode == IndexMode::Off {
        return Ok(completed_trials(&EventJournal::load(path)?));
    }
    let display = path.display().to_string();
    let extract = |off: u64, line: &str| match json::parse(line) {
        Ok(v) => v.get("kind").and_then(|k| k.as_str()).map(String::from),
        Err(e) => {
            eprintln!("warning: event journal {display}: skipping bad line at byte {off}: {e}");
            None
        }
    };
    let loaded = index::load(path, mode, &extract).context("indexing event journal")?;
    let reader = std::fs::File::open(path).context("opening event journal")?;
    use std::os::unix::fs::FileExt as _;
    let mut map: HashMap<CellKey, Vec<(usize, String)>> = HashMap::new();
    let mut finished: std::collections::HashSet<CellKey> = std::collections::HashSet::new();
    for r in &loaded.records {
        if !matches!(r.key.as_str(), "run_started" | "eval_outcome" | "run_finished") {
            continue;
        }
        let mut buf = vec![0u8; r.len as usize];
        let parsed = reader
            .read_exact_at(&mut buf, r.offset)
            .map_err(|e| eyre!("{e}"))
            .and_then(|_| {
                let text = std::str::from_utf8(&buf).map_err(|e| eyre!("{e}"))?;
                json::parse(text.trim_end_matches('\n'))
                    .map_err(|e| eyre!("{e}"))
                    .and_then(|v| event_from_json(&v))
            });
        let ev = match parsed {
            Ok(ev) => ev,
            Err(e) => {
                eprintln!(
                    "warning: event journal {display}: skipping indexed record at byte {}: {e}",
                    r.offset
                );
                continue;
            }
        };
        match &ev.kind {
            TrialEventKind::RunStarted { .. } => {
                map.entry(ev.cell()).or_default();
            }
            TrialEventKind::EvalOutcome { trial, src_hash, .. } => {
                map.entry(ev.cell()).or_default().push((*trial, src_hash.clone()));
            }
            TrialEventKind::RunFinished { .. } => {
                finished.insert(ev.cell());
            }
            _ => {}
        }
    }
    map.retain(|cell, _| !finished.contains(cell));
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evo_events_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join("events.jsonl")
    }

    fn ev(kind: TrialEventKind) -> TrialEvent {
        TrialEvent {
            method: "FunSearch".into(),
            model: "GPT-4.1".into(),
            op: "relu_64".into(),
            seed: 1,
            kind,
        }
    }

    #[test]
    fn every_kind_round_trips() {
        let kinds = vec![
            TrialEventKind::RunStarted { budget: 45, provider: "sim".into() },
            TrialEventKind::TrialStarted { trial: 3 },
            TrialEventKind::GuardVerdict { trial: 3, pass: false, diagnostics: 2 },
            TrialEventKind::RepairAttempt { trial: 3, attempt: 0, mended: true },
            TrialEventKind::EvalOutcome {
                trial: 3,
                outcome: "ok".into(),
                speedup: 1.75,
                prompt_tokens: 120,
                completion_tokens: 40,
                src_hash: "deadbeefdeadbeef".into(),
            },
            TrialEventKind::NewBest { trial: 3, speedup: 1.75 },
            TrialEventKind::BudgetExhausted { trials: 45 },
            TrialEventKind::RunFinished { trials: 45, best_speedup: 1.75, any_valid: true },
        ];
        for kind in kinds {
            let event = ev(kind);
            let line = event_to_json(&event).to_string();
            let back = event_from_json(&json::parse(&line).unwrap()).unwrap();
            assert_eq!(event, back, "{line}");
        }
    }

    #[test]
    fn journal_roundtrip_and_torn_tail() {
        let path = tmpfile("rt");
        std::fs::remove_file(&path).ok();
        {
            let j = EventJournal::create(&path).unwrap();
            j.append(&ev(TrialEventKind::TrialStarted { trial: 0 })).unwrap();
            j.append(&ev(TrialEventKind::BudgetExhausted { trials: 4 })).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"type\":\"event\",\"kind\":\"trial").unwrap();
        }
        // Reopen repairs the torn tail; load sees the two good events.
        let _ = EventJournal::open(&path).unwrap();
        let events = EventJournal::load(&path).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, TrialEventKind::TrialStarted { trial: 0 });
        // create() starts over.
        let _ = EventJournal::create(&path).unwrap();
        assert_eq!(EventJournal::load(&path).unwrap().len(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn completed_trials_omits_finished_cells() {
        let eo = |trial: usize, op: &str| TrialEvent {
            method: "FunSearch".into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            seed: 0,
            kind: TrialEventKind::EvalOutcome {
                trial,
                outcome: "ok".into(),
                speedup: 1.0,
                prompt_tokens: 1,
                completion_tokens: 1,
                src_hash: format!("h{trial}"),
            },
        };
        let fin = |op: &str| TrialEvent {
            method: "FunSearch".into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            seed: 0,
            kind: TrialEventKind::RunFinished { trials: 2, best_speedup: 1.0, any_valid: false },
        };
        let events = vec![eo(0, "a"), eo(1, "a"), fin("a"), eo(0, "b")];
        let map = completed_trials(&events);
        assert_eq!(map.len(), 1, "finished cell `a` must be omitted");
        let key = ("FunSearch".into(), "GPT-4.1".into(), "b".into(), 0u64);
        assert_eq!(map[&key], vec![(0usize, "h0".to_string())]);
    }

    #[test]
    fn indexed_resume_scan_matches_full_scan() {
        let path = tmpfile("resume_idx");
        std::fs::remove_file(&path).ok();
        index::delete_sidecar(&path);
        {
            let j = EventJournal::create(&path).unwrap();
            j.append(&ev(TrialEventKind::RunStarted { budget: 4, provider: "sim".into() }))
                .unwrap();
            j.append(&ev(TrialEventKind::TrialStarted { trial: 0 })).unwrap();
            j.append(&ev(TrialEventKind::EvalOutcome {
                trial: 0,
                outcome: "ok".into(),
                speedup: 1.5,
                prompt_tokens: 10,
                completion_tokens: 5,
                src_hash: "abcd1234".into(),
            }))
            .unwrap();
            j.append(&ev(TrialEventKind::NewBest { trial: 0, speedup: 1.5 })).unwrap();
            j.flush().unwrap();
        }
        let full = completed_trials(&EventJournal::load(&path).unwrap());
        // First Auto call builds the sidecar, second is served by it;
        // Off ignores it. All three agree with the in-memory fold.
        for mode in [IndexMode::Auto, IndexMode::Auto, IndexMode::Off] {
            let at = completed_trials_at(&path, mode).unwrap();
            assert_eq!(at, full);
        }
        // Missing journal: empty map, not an error.
        let missing = tmpfile("resume_missing");
        std::fs::remove_file(&missing).ok();
        assert!(completed_trials_at(&missing, IndexMode::Auto).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
        index::delete_sidecar(&path);
    }

    #[test]
    fn group_commit_kill_loses_only_staged_events() {
        let path = tmpfile("group");
        std::fs::remove_file(&path).ok();
        {
            let j = EventJournal::create(&path).unwrap();
            j.append(&ev(TrialEventKind::TrialStarted { trial: 0 })).unwrap();
            j.flush().unwrap();
            j.append(&ev(TrialEventKind::TrialStarted { trial: 1 })).unwrap();
            assert_eq!(
                EventJournal::load(&path).unwrap().len(),
                1,
                "staged event must not be on disk before the flush point"
            );
            j.drop_unflushed();
        }
        let events = EventJournal::load(&path).unwrap();
        assert_eq!(events.len(), 1, "only the flushed event survives the kill");
        assert_eq!(events[0].kind, TrialEventKind::TrialStarted { trial: 0 });
        std::fs::remove_file(&path).ok();
    }
}
