//! Persistent, content-addressed evaluation cache (DESIGN.md §8).
//!
//! The campaign grid (6 methods × 3 LLMs × 91 ops × 3 seeds × 45
//! trials ≈ 73k candidate evaluations) re-discovers the same kernels
//! constantly: every method bootstraps from the op's baseline schedule,
//! and the SimLLM's mutation moves revisit popular schedule points
//! across methods and seeds. The two-stage pipeline result for a
//! candidate is *deterministic given its canonical form and the op* —
//! compile gating, the PJRT functional verdict, and the noise-free
//! cost-model timing contain no randomness (measurement noise is
//! applied to the stored timing at replay time, from the caller's RNG
//! stream, so a cache hit is bit-identical to a cold evaluation).
//!
//! [`EvalStore`] therefore journals every
//! `(kernel_hash, op) → {verdict, functional diff, timing}` record to
//! an append-only JSONL file (default: `<artifacts>/eval_cache.jsonl`)
//! and serves lookups from an in-memory index. Identical candidates
//! are evaluated exactly once across the whole campaign *and across
//! process restarts*. The journaled `model` field is provenance only —
//! the pipeline's verdicts do not depend on which LLM emitted the
//! text, so keying on it would forfeit cross-model deduplication.
//!
//! What is deliberately **not** cached:
//! * unparseable candidates — rejecting them is already the cheapest
//!   path, and raw defect text has no canonical form;
//! * `RuntimeFail` outcomes — PJRT/infrastructure errors may be
//!   transient and must not poison a persistent store.
//!
//! Durability model (DESIGN.md §14): appends are staged in a
//! [`GroupWriter`] and committed as a group at explicit flush points
//! (trial boundaries in the engine, or when the buffer fills); a
//! process killed between flush points loses at most the records
//! staged since the last trial boundary — exactly the work a resumed
//! campaign re-derives anyway — and corrupts at most the final line,
//! which the loader skips (with a warning). Opens are served by a
//! validated sidecar offset index ([`index`]) instead of a full
//! journal rescan; record bodies are `pread` + parsed lazily on first
//! lookup. `cache gc` compacts duplicate keys and folds the
//! per-session `stats` trailer lines into one.

pub mod events;
pub mod hash;
pub mod index;
pub mod intern;
pub mod transcript;

pub use events::{EventJournal, TrialEvent, TrialEventKind};
pub use hash::{key_for_source, sha256_hex, EvalKey};
pub use index::IndexMode;
pub use intern::{KeyInterner, Keyed};
pub use transcript::{TranscriptEntry, TranscriptStore};

use std::collections::HashMap;
use std::io::{BufRead, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::costmodel::{BoundKind, Timing};
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

/// The deterministic, replayable part of one candidate evaluation.
#[derive(Debug, Clone)]
pub enum StoredOutcome {
    /// Stage-0 rejection by the static validity guard (DESIGN.md §11):
    /// the exact structured diagnostics, journaled under a
    /// guard-namespaced key ([`EvalKey::guarded`]) so a replay is
    /// bit-identical and never shadows a full-pipeline record.
    GuardReject { diagnostics: Vec<crate::guard::GuardDiagnostic> },
    /// Stage-1 rejection (syntax / validation / resolution) — the
    /// exact error string the compile gate produced.
    CompileFail { error: String },
    /// Stage-2 rejection: compiled but wrong numerics on PJRT.
    FunctionalFail { max_abs_diff: f64 },
    /// Cleared both gates; the noise-free cost-model timing. Measured
    /// (noisy) numbers are re-derived at replay time.
    Ok { timing: Timing },
}

/// One journal entry: outcome plus provenance.
#[derive(Debug, Clone)]
pub struct StoredEval {
    pub op: String,
    /// Which LLM first produced this candidate (provenance only; not
    /// part of the lookup key — see module docs).
    pub model: String,
    pub outcome: StoredOutcome,
}

/// Group-commit buffer in front of a journal's append handle
/// (DESIGN.md §14). Records are staged in memory and written+flushed
/// as one batch at explicit flush points — the engine's trial
/// boundaries — or when the buffer reaches [`GROUP_COMMIT_MAX_BUF`].
/// [`Drop`] flushes best-effort, so scope-exit keeps the old
/// every-record-durable behaviour for short-lived handles;
/// [`GroupWriter::drop_unflushed`] is the kill-simulation hook the
/// crash-at-flush-boundary tests use to model a process dying with a
/// dirty buffer.
pub(crate) struct GroupWriter {
    file: std::fs::File,
    buf: Vec<u8>,
}

/// Auto-flush threshold: large enough that a burst of records inside
/// one trial is one write syscall, small enough that a kill loses a
/// bounded, quickly-re-derived amount of work.
pub(crate) const GROUP_COMMIT_MAX_BUF: usize = 64 * 1024;

impl GroupWriter {
    pub(crate) fn new(file: std::fs::File) -> Self {
        Self { file, buf: Vec::new() }
    }

    /// Stage one record line (without its terminator; the writer
    /// appends the `\n`).
    pub(crate) fn append_line(&mut self, line: &[u8]) -> std::io::Result<()> {
        self.buf.extend_from_slice(line);
        self.buf.push(b'\n');
        if self.buf.len() >= GROUP_COMMIT_MAX_BUF {
            self.flush()?;
        }
        Ok(())
    }

    /// Write and flush everything staged since the last flush point.
    pub(crate) fn flush(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        use std::io::Write as _;
        self.file.write_all(&self.buf)?;
        self.buf.clear();
        self.file.flush()
    }

    /// Discard staged-but-unflushed bytes — the kill simulation: a
    /// SIGKILL between append and flush loses exactly these.
    pub(crate) fn drop_unflushed(&mut self) {
        self.buf.clear();
    }
}

impl Drop for GroupWriter {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// One in-memory record slot: parsed, or a `(offset, len)` reference
/// into the journal that is `pread` + parsed on first lookup. Indexed
/// opens start with every slot on disk, so an open's cost no longer
/// scales with record *bodies* — only with the record count.
#[derive(Debug, Clone)]
enum Slot {
    Parsed(StoredEval),
    OnDisk { offset: u64, len: u32 },
}

/// Append-only JSONL store with an in-memory index. Cheap to share:
/// wrap in `Arc` and clone the handle.
pub struct EvalStore {
    path: PathBuf,
    map: RwLock<HashMap<String, Slot>>,
    /// Positioned-read handle for lazy [`Slot::OnDisk`] hydration
    /// (`pread` is `&self`-safe; no seek state to serialize).
    reader: std::fs::File,
    writer: Mutex<GroupWriter>,
    indexed_open: bool,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Aggregate numbers for `cache stats` / `cache gc`.
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    pub entries: usize,
    pub ok: usize,
    pub compile_fail: usize,
    pub functional_fail: usize,
    pub guard_rejected: usize,
    pub ops: usize,
    /// Cumulative hits/misses folded from journaled `stats` lines.
    pub hits: u64,
    pub misses: u64,
    pub file_bytes: u64,
    pub journal_lines: usize,
    /// Sidecar index health (`None` when no sidecar exists — the
    /// journal was never opened with indexing on).
    pub index: Option<index::IndexHealth>,
}

impl EvalStore {
    /// Open (or create) the journal at `path` and index its entries,
    /// honouring the `EVO_JOURNAL_INDEX` environment switch. The torn
    /// tail of a killed process is truncated before the append handle
    /// opens (a fresh record must never concatenate onto partial
    /// bytes); any other corrupt line is skipped with a warning — the
    /// cache is advisory, never fatal.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        Self::open_with(path, IndexMode::from_env())
    }

    /// [`EvalStore::open`] with an explicit index mode — `Off` forces
    /// a full journal rescan with zero sidecar IO (the torture suite
    /// exercises both paths and asserts they agree).
    pub fn open_with(path: impl AsRef<Path>, mode: IndexMode) -> Result<Arc<Self>> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating eval-cache dir")?;
            }
        }
        let torn = crate::util::truncate_torn_tail(&path).context("repairing eval-cache tail")?;
        if torn > 0 {
            eprintln!(
                "warning: eval cache {}: truncated {torn} bytes of torn final line",
                path.display()
            );
        }
        // The append handle opens first so the journal exists (even
        // empty) before the reader and the index look at it.
        let writer = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .context("opening eval cache for append")?;
        let display = path.display().to_string();
        let extract = |off: u64, line: &str| match parse_line(line) {
            Ok(Line::Eval { key, .. }) => Some(key),
            Ok(Line::Stats { .. }) => None,
            Err(e) => {
                eprintln!("warning: eval cache {display}: skipping bad line at byte {off}: {e}");
                None
            }
        };
        let loaded = index::load(&path, mode, &extract).context("indexing eval cache")?;
        let mut map = HashMap::new();
        for r in loaded.records {
            map.entry(r.key).or_insert(Slot::OnDisk { offset: r.offset, len: r.len });
        }
        let reader = std::fs::File::open(&path).context("opening eval cache for read")?;
        Ok(Arc::new(Self {
            path,
            map: RwLock::new(map),
            reader,
            writer: Mutex::new(GroupWriter::new(writer)),
            indexed_open: loaded.indexed,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Whether this open was served by a valid sidecar index (vs a
    /// full journal rescan).
    pub fn opened_indexed(&self) -> bool {
        self.indexed_open
    }

    /// The record behind `key`, hydrating an on-disk slot on first
    /// touch. A slot whose bytes no longer parse to the expected key
    /// (out-of-band journal mutation) is dropped with a warning so an
    /// indexed open converges to the same misses a rescan would see.
    fn fetch(&self, key: &str) -> Option<StoredEval> {
        let extent = {
            let g = self.map.read().unwrap();
            match g.get(key)? {
                Slot::Parsed(entry) => return Some(entry.clone()),
                Slot::OnDisk { offset, len } => (*offset, *len),
            }
        };
        use std::os::unix::fs::FileExt as _;
        let (offset, len) = extent;
        let mut buf = vec![0u8; len as usize];
        let parsed = self
            .reader
            .read_exact_at(&mut buf, offset)
            .map_err(|e| eyre!("{e}"))
            .and_then(|_| {
                let text = std::str::from_utf8(&buf).map_err(|e| eyre!("{e}"))?;
                parse_line(text.trim_end_matches('\n'))
            });
        match parsed {
            Ok(Line::Eval { key: line_key, entry }) if line_key == key => {
                self.map
                    .write()
                    .unwrap()
                    .insert(key.to_string(), Slot::Parsed(entry.clone()));
                Some(entry)
            }
            other => {
                let why = match other {
                    Ok(Line::Eval { key: k, .. }) => format!("record at byte {offset} keyed `{k}`"),
                    Ok(Line::Stats { .. }) => format!("record at byte {offset} is a stats line"),
                    Err(e) => format!("record at byte {offset} unreadable: {e}"),
                };
                eprintln!(
                    "warning: eval cache {}: dropping stale index slot for `{key}`: {why}",
                    self.path.display()
                );
                self.map.write().unwrap().remove(key);
                None
            }
        }
    }

    /// Cached result for `key`, counting a hit or miss.
    pub fn lookup(&self, key: &EvalKey) -> Option<StoredEval> {
        let found = self.fetch(key.as_str());
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert + journal a fresh record. A key that is already present
    /// (e.g. two workers racing on the same candidate) is left as-is
    /// and not re-journaled. The append is staged in the group-commit
    /// buffer; durability arrives at the next [`EvalStore::flush`]
    /// (the engine calls it at every trial boundary).
    pub fn record(&self, key: &EvalKey, entry: StoredEval) -> Result<()> {
        {
            let mut g = self.map.write().unwrap();
            if g.contains_key(key.as_str()) {
                return Ok(());
            }
            g.insert(key.as_str().to_string(), Slot::Parsed(entry.clone()));
        }
        let line = eval_line(key, &entry).to_string();
        self.writer.lock().unwrap().append_line(line.as_bytes())?;
        Ok(())
    }

    /// Merge one journal line uploaded by another process (the
    /// campaign coordinator's record-upload path, DESIGN.md §15).
    /// A fresh `eval` line is inserted and re-journaled; keys already
    /// present and `stats` lines are skipped. Returns whether the line
    /// was ingested. Staged like [`EvalStore::record`]; durability
    /// arrives at the next flush.
    pub fn ingest_line(&self, line: &str) -> Result<bool> {
        match parse_line(line).context("ingesting uploaded eval line")? {
            Line::Stats { .. } => Ok(false),
            Line::Eval { key, entry } => {
                {
                    let mut g = self.map.write().unwrap();
                    if g.contains_key(&key) {
                        return Ok(false);
                    }
                    g.insert(key.clone(), Slot::Parsed(entry.clone()));
                }
                let line = eval_line(&EvalKey(key), &entry).to_string();
                self.writer.lock().unwrap().append_line(line.as_bytes())?;
                Ok(true)
            }
        }
    }

    /// Group-commit flush point: make every staged record durable.
    pub fn flush(&self) -> Result<()> {
        self.writer.lock().unwrap().flush()?;
        Ok(())
    }

    /// Test hook: simulate a kill between append and flush by
    /// discarding staged-but-unflushed bytes.
    #[doc(hidden)]
    pub fn drop_unflushed(&self) {
        self.writer.lock().unwrap().drop_unflushed();
    }

    /// Unique cached evaluations.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hits served by this process.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses seen by this process.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Journal this session's hit/miss counters so `cache stats` can
    /// report cumulative savings across process lifetimes. Call once
    /// at the end of a campaign/run. Always flushes the group-commit
    /// buffer, even when no stats line is due — this is the session's
    /// final flush point.
    pub fn flush_session_stats(&self) -> Result<()> {
        let (h, m) = (self.hits(), self.misses());
        let mut w = self.writer.lock().unwrap();
        if h != 0 || m != 0 {
            let line = Json::obj(vec![
                ("type", Json::Str("stats".into())),
                ("hits", Json::Num(h as f64)),
                ("misses", Json::Num(m as f64)),
            ])
            .to_string();
            w.append_line(line.as_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Read-only aggregate view of a journal on disk.
    pub fn stats(path: impl AsRef<Path>) -> Result<StoreStats> {
        let path = path.as_ref();
        let mut s = StoreStats::default();
        if !path.exists() {
            return Ok(s);
        }
        s.file_bytes = std::fs::metadata(path)?.len();
        let f = std::fs::File::open(path).context("opening eval cache")?;
        let mut seen = std::collections::HashSet::new();
        let mut ops = std::collections::HashSet::new();
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            s.journal_lines += 1;
            match parse_line(&line) {
                Ok(Line::Eval { key, entry }) => {
                    if !seen.insert(key) {
                        continue;
                    }
                    s.entries += 1;
                    ops.insert(entry.op.clone());
                    match entry.outcome {
                        StoredOutcome::Ok { .. } => s.ok += 1,
                        StoredOutcome::CompileFail { .. } => s.compile_fail += 1,
                        StoredOutcome::FunctionalFail { .. } => s.functional_fail += 1,
                        StoredOutcome::GuardReject { .. } => s.guard_rejected += 1,
                    }
                }
                Ok(Line::Stats { hits, misses }) => {
                    s.hits += hits;
                    s.misses += misses;
                }
                Err(_) => {}
            }
        }
        s.ops = ops.len();
        s.index = index::health(path);
        Ok(s)
    }

    /// Compact the journal in place: one line per unique key (first
    /// occurrence wins — the journal is append-only, so the first line
    /// is the original evaluation), all `stats` lines folded into one,
    /// corrupt lines dropped. Returns (bytes_before, bytes_after).
    pub fn gc(path: impl AsRef<Path>) -> Result<(u64, u64)> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(eyre!("no eval cache at {}", path.display()));
        }
        let before = std::fs::metadata(path)?.len();
        let f = std::fs::File::open(path).context("opening eval cache")?;
        let mut seen = std::collections::HashSet::new();
        let mut kept: Vec<String> = Vec::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(&line) {
                Ok(Line::Eval { key, .. }) => {
                    if seen.insert(key) {
                        kept.push(line);
                    }
                }
                Ok(Line::Stats { hits: h, misses: m }) => {
                    hits += h;
                    misses += m;
                }
                Err(_) => {}
            }
        }
        if hits > 0 || misses > 0 {
            kept.push(
                Json::obj(vec![
                    ("type", Json::Str("stats".into())),
                    ("hits", Json::Num(hits as f64)),
                    ("misses", Json::Num(misses as f64)),
                ])
                .to_string(),
            );
        }
        let tmp = path.with_extension("jsonl.gc.tmp");
        {
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&tmp).context("creating gc temp file")?,
            );
            for line in &kept {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")?;
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path).context("replacing eval cache")?;
        // The sidecar indexed the pre-compaction journal; drop it so
        // the next open rebuilds from the compacted bytes.
        index::delete_sidecar(path);
        let after = std::fs::metadata(path)?.len();
        Ok((before, after))
    }
}

// ---------------------------------------------------------------------
// JSONL (de)serialization — util::json, no serde (offline environment).

enum Line {
    Eval { key: String, entry: StoredEval },
    Stats { hits: u64, misses: u64 },
}

/// f64 → Json, preserving non-finite values (a shape-mismatch
/// functional diff is `inf`, which bare JSON numbers cannot carry).
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("nan".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn get_num(v: &Json, key: &str) -> Result<f64> {
    match v.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(Json::Str(s)) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => Err(eyre!("bad numeric field `{key}`: {other}")),
        },
        _ => Err(eyre!("missing numeric field `{key}`")),
    }
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("missing string field `{key}`"))
}

fn bound_str(b: BoundKind) -> &'static str {
    match b {
        BoundKind::Compute => "compute",
        BoundKind::Memory => "memory",
        BoundKind::Launch => "launch",
    }
}

fn bound_from(s: &str) -> Result<BoundKind> {
    match s {
        "compute" => Ok(BoundKind::Compute),
        "memory" => Ok(BoundKind::Memory),
        "launch" => Ok(BoundKind::Launch),
        other => Err(eyre!("unknown bound kind `{other}`")),
    }
}

fn timing_to_json(t: &Timing) -> Json {
    Json::obj(vec![
        ("time", num(t.time)),
        ("t_compute", num(t.t_compute)),
        ("t_mem", num(t.t_mem)),
        ("t_overhead", num(t.t_overhead)),
        ("traffic", num(t.traffic)),
        ("occupancy", num(t.occupancy)),
        ("eff_compute", num(t.eff_compute)),
        ("eff_bw", num(t.eff_bw)),
        ("launches", Json::Num(t.launches as f64)),
        ("bound", Json::Str(bound_str(t.bound).into())),
    ])
}

fn timing_from_json(v: &Json) -> Result<Timing> {
    Ok(Timing {
        time: get_num(v, "time")?,
        t_compute: get_num(v, "t_compute")?,
        t_mem: get_num(v, "t_mem")?,
        t_overhead: get_num(v, "t_overhead")?,
        traffic: get_num(v, "traffic")?,
        occupancy: get_num(v, "occupancy")?,
        eff_compute: get_num(v, "eff_compute")?,
        eff_bw: get_num(v, "eff_bw")?,
        launches: get_num(v, "launches")? as u32,
        bound: bound_from(&get_str(v, "bound")?)?,
    })
}

fn eval_line(key: &EvalKey, entry: &StoredEval) -> Json {
    let mut fields = vec![
        ("type", Json::Str("eval".into())),
        ("key", Json::Str(key.as_str().to_string())),
        ("op", Json::Str(entry.op.clone())),
        ("model", Json::Str(entry.model.clone())),
    ];
    match &entry.outcome {
        StoredOutcome::Ok { timing } => {
            fields.push(("outcome", Json::Str("ok".into())));
            fields.push(("timing", timing_to_json(timing)));
        }
        StoredOutcome::CompileFail { error } => {
            fields.push(("outcome", Json::Str("compile_fail".into())));
            fields.push(("error", Json::Str(error.clone())));
        }
        StoredOutcome::FunctionalFail { max_abs_diff } => {
            fields.push(("outcome", Json::Str("functional_fail".into())));
            fields.push(("max_abs_diff", num(*max_abs_diff)));
        }
        StoredOutcome::GuardReject { diagnostics } => {
            fields.push(("outcome", Json::Str("guard_reject".into())));
            fields.push((
                "diagnostics",
                Json::Arr(diagnostics.iter().map(diagnostic_to_json).collect()),
            ));
        }
    }
    Json::obj(fields)
}

fn diagnostic_to_json(d: &crate::guard::GuardDiagnostic) -> Json {
    let mut fields = vec![
        ("code", Json::Str(d.code.as_str().to_string())),
        ("field", Json::Str(d.field.clone())),
        ("message", Json::Str(d.message.clone())),
    ];
    if let Some((hf, hv)) = &d.hint {
        fields.push(("hint_field", Json::Str(hf.clone())));
        fields.push(("hint_value", Json::Str(hv.clone())));
    }
    Json::obj(fields)
}

fn diagnostic_from_json(v: &Json) -> Result<crate::guard::GuardDiagnostic> {
    let code_str = get_str(v, "code")?;
    let code = crate::guard::GuardCode::from_str(&code_str)
        .ok_or_else(|| eyre!("unknown guard code `{code_str}`"))?;
    let hint = match (v.get("hint_field"), v.get("hint_value")) {
        (Some(f), Some(val)) => Some((
            f.as_str().ok_or_else(|| eyre!("bad hint_field"))?.to_string(),
            val.as_str().ok_or_else(|| eyre!("bad hint_value"))?.to_string(),
        )),
        _ => None,
    };
    Ok(crate::guard::GuardDiagnostic {
        code,
        field: get_str(v, "field")?,
        message: get_str(v, "message")?,
        hint,
    })
}

fn parse_line(line: &str) -> Result<Line> {
    let v = json::parse(line).map_err(|e| eyre!("{e}"))?;
    match v.get("type").and_then(|t| t.as_str()) {
        Some("stats") => Ok(Line::Stats {
            hits: v.get("hits").and_then(|x| x.as_u64()).unwrap_or(0),
            misses: v.get("misses").and_then(|x| x.as_u64()).unwrap_or(0),
        }),
        Some("eval") => {
            let key = get_str(&v, "key")?;
            let op = get_str(&v, "op")?;
            let model = get_str(&v, "model")?;
            let outcome = match get_str(&v, "outcome")?.as_str() {
                "ok" => StoredOutcome::Ok {
                    timing: timing_from_json(
                        v.get("timing").ok_or_else(|| eyre!("missing timing"))?,
                    )?,
                },
                "compile_fail" => StoredOutcome::CompileFail { error: get_str(&v, "error")? },
                "functional_fail" => StoredOutcome::FunctionalFail {
                    max_abs_diff: get_num(&v, "max_abs_diff")?,
                },
                "guard_reject" => StoredOutcome::GuardReject {
                    diagnostics: v
                        .get("diagnostics")
                        .and_then(|d| d.as_arr())
                        .ok_or_else(|| eyre!("missing diagnostics"))?
                        .iter()
                        .map(diagnostic_from_json)
                        .collect::<Result<Vec<_>>>()?,
                },
                other => return Err(eyre!("unknown outcome `{other}`")),
            };
            Ok(Line::Eval { key, entry: StoredEval { op, model, outcome } })
        }
        other => Err(eyre!("unknown journal line type {other:?}")),
    }
}

/// Human-readable `cache stats` rendering.
pub fn stats_report(path: impl AsRef<Path>, s: &StoreStats) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    writeln!(out, "eval cache: {}", path.as_ref().display()).unwrap();
    writeln!(
        out,
        "  entries: {} unique ({} journal lines, {} bytes)",
        s.entries, s.journal_lines, s.file_bytes
    )
    .unwrap();
    writeln!(
        out,
        "  outcomes: {} ok, {} compile_fail, {} functional_fail, {} guard_rejected",
        s.ok, s.compile_fail, s.functional_fail, s.guard_rejected
    )
    .unwrap();
    writeln!(out, "  ops covered: {}", s.ops).unwrap();
    writeln!(
        out,
        "  cumulative: {} hits, {} misses ({} evaluations saved)",
        s.hits, s.misses, s.hits
    )
    .unwrap();
    match &s.index {
        Some(h) => writeln!(
            out,
            "  index: {} indexed opens, {} scanned opens, {} rebuilds",
            h.indexed_opens, h.scanned_opens, h.rebuilds
        )
        .unwrap(),
        None => writeln!(out, "  index: no sidecar").unwrap(),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evo_store_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_timing() -> Timing {
        Timing {
            time: 1.25e-4,
            t_compute: 9e-5,
            t_mem: 1.2e-4,
            t_overhead: 5e-6,
            traffic: 3.2e6,
            occupancy: 0.66,
            eff_compute: 0.4,
            eff_bw: 0.8,
            launches: 2,
            bound: BoundKind::Memory,
        }
    }

    #[test]
    fn ingest_line_merges_and_dedups() {
        let dir = tmpdir("ingest");
        let src = dir.join("src_cache.jsonl");
        let dst = dir.join("dst_cache.jsonl");
        let key = EvalKey::from_canonical("matmul_64", "kernel ingest");
        {
            let store = EvalStore::open(&src).unwrap();
            store
                .record(
                    &key,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store.flush().unwrap();
        }
        let line = std::fs::read_to_string(&src)
            .unwrap()
            .lines()
            .next()
            .unwrap()
            .to_string();
        let dst_store = EvalStore::open(&dst).unwrap();
        assert!(dst_store.ingest_line(&line).unwrap(), "fresh line ingests");
        assert!(!dst_store.ingest_line(&line).unwrap(), "duplicate skipped");
        // Stats lines are ignored, not an error.
        assert!(!dst_store
            .ingest_line(r#"{"type":"stats","hits":3,"misses":1}"#)
            .unwrap());
        dst_store.flush().unwrap();
        // The merged entry is a first-class record: visible after reopen.
        let reopened = EvalStore::open(&dst).unwrap();
        assert_eq!(reopened.len(), 1);
        assert!(reopened.lookup(&key).is_some());
    }

    #[test]
    fn journal_roundtrip_across_reopen() {
        let dir = tmpdir("rt");
        let path = dir.join("cache.jsonl");
        let k1 = EvalKey::from_canonical("matmul_64", "kernel a");
        let k2 = EvalKey::from_canonical("matmul_64", "kernel b");
        let k3 = EvalKey::from_canonical("relu_64", "kernel c");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k1,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store
                .record(
                    &k2,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "Claude-Sonnet-4".into(),
                        outcome: StoredOutcome::CompileFail {
                            error: "validation error: smem overflow".into(),
                        },
                    },
                )
                .unwrap();
            store
                .record(
                    &k3,
                    StoredEval {
                        op: "relu_64".into(),
                        model: "DeepSeek-V3.1".into(),
                        outcome: StoredOutcome::FunctionalFail {
                            max_abs_diff: f64::INFINITY,
                        },
                    },
                )
                .unwrap();
        }
        let store = EvalStore::open(&path).unwrap();
        assert_eq!(store.len(), 3);
        match store.lookup(&k1).unwrap().outcome {
            StoredOutcome::Ok { timing } => {
                assert_eq!(timing.time, 1.25e-4);
                assert_eq!(timing.launches, 2);
                assert_eq!(timing.bound, BoundKind::Memory);
            }
            other => panic!("{other:?}"),
        }
        match store.lookup(&k2).unwrap().outcome {
            StoredOutcome::CompileFail { error } => assert!(error.contains("smem")),
            other => panic!("{other:?}"),
        }
        match store.lookup(&k3).unwrap().outcome {
            StoredOutcome::FunctionalFail { max_abs_diff } => {
                assert!(max_abs_diff.is_infinite())
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(store.hits(), 3);
        assert_eq!(store.misses(), 0);
        assert!(store.lookup(&EvalKey::from_canonical("x", "y")).is_none());
        assert_eq!(store.misses(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn guard_reject_roundtrip_across_reopen() {
        use crate::guard::{GuardCode, GuardDiagnostic};
        let dir = tmpdir("guard");
        let path = dir.join("cache.jsonl");
        let key = EvalKey::guarded("matmul_64", "kernel a");
        let diagnostics = vec![
            GuardDiagnostic {
                code: GuardCode::ShapeMismatch,
                field: "tile_m".into(),
                message: "tile_m=256 exceeds every operand extent".into(),
                hint: Some(("tile_m".into(), "64".into())),
            },
            GuardDiagnostic {
                code: GuardCode::NonTerminating,
                field: "tile_k".into(),
                message: "tile_k=0 is a zero-step loop construct".into(),
                hint: None,
            },
        ];
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &key,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::GuardReject {
                            diagnostics: diagnostics.clone(),
                        },
                    },
                )
                .unwrap();
        }
        // Bit-identical replay after reopen: codes, fields, messages,
        // hints (and hint absence) all survive the journal round-trip.
        let store = EvalStore::open(&path).unwrap();
        match store.lookup(&key).unwrap().outcome {
            StoredOutcome::GuardReject { diagnostics: back } => {
                assert_eq!(back, diagnostics)
            }
            other => panic!("{other:?}"),
        }
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.guard_rejected, 1);
        assert_eq!(s.entries, 1);
        assert!(stats_report(&path, &s).contains("1 guard_rejected"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_tail_is_skipped() {
        let dir = tmpdir("torn");
        let path = dir.join("cache.jsonl");
        let k = EvalKey::from_canonical("matmul_64", "kernel a");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::CompileFail { error: "x".into() },
                    },
                )
                .unwrap();
        }
        // Simulate a kill mid-append: torn, unparseable final line.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"type\":\"eval\",\"key\":\"dead").unwrap();
        }
        // Reopen truncates the torn tail; a fresh record appended after
        // the repair must not merge with the partial bytes.
        let k2 = EvalKey::from_canonical("relu_64", "kernel b");
        {
            let store = EvalStore::open(&path).unwrap();
            assert_eq!(store.len(), 1);
            assert!(store.lookup(&k).is_some());
            store
                .record(
                    &k2,
                    StoredEval {
                        op: "relu_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::CompileFail { error: "y".into() },
                    },
                )
                .unwrap();
        }
        let store = EvalStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.lookup(&k).is_some());
        assert!(store.lookup(&k2).is_some());
        // Every surviving line is well-formed (no merged garbage).
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.journal_lines, 2);
        assert_eq!(s.entries, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gc_compacts_and_folds_stats() {
        let dir = tmpdir("gc");
        let path = dir.join("cache.jsonl");
        let k = EvalKey::from_canonical("matmul_64", "kernel a");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store.lookup(&k);
            store.flush_session_stats().unwrap();
        }
        // A second session appends a duplicate line for the same key
        // (as two racing processes would) plus its own stats.
        {
            use std::io::Write as _;
            let entry = StoredEval {
                op: "matmul_64".into(),
                model: "-".into(),
                outcome: StoredOutcome::Ok { timing: sample_timing() },
            };
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{}", eval_line(&k, &entry).to_string()).unwrap();
            writeln!(
                f,
                "{}",
                Json::obj(vec![
                    ("type", Json::Str("stats".into())),
                    ("hits", Json::Num(4.0)),
                    ("misses", Json::Num(2.0)),
                ])
                .to_string()
            )
            .unwrap();
            writeln!(f, "not json at all").unwrap();
        }
        let before_stats = EvalStore::stats(&path).unwrap();
        assert_eq!(before_stats.entries, 1);
        assert_eq!(before_stats.hits, 5); // 1 + 4
        assert_eq!(before_stats.misses, 3); // 1 + 2

        let (before, after) = EvalStore::gc(&path).unwrap();
        assert!(after < before, "{after} !< {before}");
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.entries, 1);
        assert_eq!(s.journal_lines, 2); // 1 eval + 1 folded stats
        assert_eq!(s.hits, 5);
        assert_eq!(s.misses, 3);
        // Journal still loads and serves the entry.
        let store = EvalStore::open(&path).unwrap();
        assert!(store.lookup(&k).is_some());
        std::fs::remove_dir_all(dir).ok();
    }

    fn fail_entry(op: &str, error: &str) -> StoredEval {
        StoredEval {
            op: op.into(),
            model: "-".into(),
            outcome: StoredOutcome::CompileFail { error: error.into() },
        }
    }

    #[test]
    fn group_commit_buffers_until_flush_point() {
        let dir = tmpdir("group");
        let path = dir.join("cache.jsonl");
        let store = EvalStore::open(&path).unwrap();
        let k1 = EvalKey::from_canonical("matmul_64", "a");
        let k2 = EvalKey::from_canonical("matmul_64", "b");
        store.record(&k1, fail_entry("matmul_64", "x")).unwrap();
        store.record(&k2, fail_entry("matmul_64", "y")).unwrap();
        // Staged, not yet durable — but served from memory.
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        assert!(store.lookup(&k1).is_some());
        store.flush().unwrap();
        let after_flush = std::fs::metadata(&path).unwrap().len();
        assert!(after_flush > 0);
        // Byte-identical to what per-record flushing would have written.
        let want = format!(
            "{}\n{}\n",
            eval_line(&k1, &fail_entry("matmul_64", "x")),
            eval_line(&k2, &fail_entry("matmul_64", "y"))
        );
        assert_eq!(std::fs::read_to_string(&path).unwrap(), want);
        // Idempotent flush point.
        store.flush().unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), after_flush);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn kill_between_append_and_flush_loses_only_staged_records() {
        let dir = tmpdir("kill");
        let path = dir.join("cache.jsonl");
        let k_durable = EvalKey::from_canonical("matmul_64", "a");
        let k_staged = EvalKey::from_canonical("matmul_64", "b");
        {
            let store = EvalStore::open(&path).unwrap();
            store.record(&k_durable, fail_entry("matmul_64", "x")).unwrap();
            store.flush().unwrap();
            store.record(&k_staged, fail_entry("matmul_64", "y")).unwrap();
            // Simulated SIGKILL with a dirty buffer.
            store.drop_unflushed();
        }
        let store = EvalStore::open(&path).unwrap();
        assert!(store.lookup(&k_durable).is_some(), "flushed record must survive");
        assert!(store.lookup(&k_staged).is_none(), "staged record dies with the process");
        // Re-deriving and re-recording the lost record works cleanly.
        store.record(&k_staged, fail_entry("matmul_64", "y")).unwrap();
        store.flush().unwrap();
        let store = EvalStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn drop_without_explicit_flush_still_persists() {
        // Scope-exit durability: GroupWriter's Drop flushes, so code
        // that never reaches a trial boundary (one-shot CLI paths)
        // keeps the old behaviour.
        let dir = tmpdir("dropflush");
        let path = dir.join("cache.jsonl");
        let k = EvalKey::from_canonical("matmul_64", "a");
        {
            let store = EvalStore::open(&path).unwrap();
            store.record(&k, fail_entry("matmul_64", "x")).unwrap();
        }
        let store = EvalStore::open(&path).unwrap();
        assert!(store.lookup(&k).is_some());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn indexed_reopen_serves_identical_records() {
        let dir = tmpdir("idx");
        let path = dir.join("cache.jsonl");
        let k1 = EvalKey::from_canonical("matmul_64", "a");
        let k2 = EvalKey::guarded("matmul_64", "raw b");
        {
            let store = EvalStore::open_with(&path, IndexMode::Auto).unwrap();
            assert!(!store.opened_indexed(), "first open is a scan");
            store
                .record(
                    &k1,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store.record(&k2, fail_entry("matmul_64", "guard")).unwrap();
        }
        // Second open after a (Drop-)flushed append rescans only the
        // tail; third is fully indexed. All three serve the same data.
        for round in 0..2 {
            let store = EvalStore::open_with(&path, IndexMode::Auto).unwrap();
            if round == 1 {
                assert!(store.opened_indexed(), "warm open must be index-served");
            }
            assert_eq!(store.len(), 2);
            match store.lookup(&k1).unwrap().outcome {
                StoredOutcome::Ok { timing } => assert_eq!(timing.time, 1.25e-4),
                other => panic!("{other:?}"),
            }
            assert!(store.lookup(&k2).is_some());
        }
        // Off-mode open of the same journal agrees.
        let off = EvalStore::open_with(&path, IndexMode::Off).unwrap();
        assert!(!off.opened_indexed());
        assert_eq!(off.len(), 2);
        assert!(off.lookup(&k1).is_some() && off.lookup(&k2).is_some());
        // Health is visible through stats + report.
        let s = EvalStore::stats(&path).unwrap();
        let h = s.index.expect("sidecar exists after Auto opens");
        assert!(h.indexed_opens >= 1);
        assert!(stats_report(&path, &s).contains("indexed opens"));
        std::fs::remove_dir_all(dir).ok();
    }
}
