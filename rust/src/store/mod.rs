//! Persistent, content-addressed evaluation cache (DESIGN.md §8).
//!
//! The campaign grid (6 methods × 3 LLMs × 91 ops × 3 seeds × 45
//! trials ≈ 73k candidate evaluations) re-discovers the same kernels
//! constantly: every method bootstraps from the op's baseline schedule,
//! and the SimLLM's mutation moves revisit popular schedule points
//! across methods and seeds. The two-stage pipeline result for a
//! candidate is *deterministic given its canonical form and the op* —
//! compile gating, the PJRT functional verdict, and the noise-free
//! cost-model timing contain no randomness (measurement noise is
//! applied to the stored timing at replay time, from the caller's RNG
//! stream, so a cache hit is bit-identical to a cold evaluation).
//!
//! [`EvalStore`] therefore journals every
//! `(kernel_hash, op) → {verdict, functional diff, timing}` record to
//! an append-only JSONL file (default: `<artifacts>/eval_cache.jsonl`)
//! and serves lookups from an in-memory index. Identical candidates
//! are evaluated exactly once across the whole campaign *and across
//! process restarts*. The journaled `model` field is provenance only —
//! the pipeline's verdicts do not depend on which LLM emitted the
//! text, so keying on it would forfeit cross-model deduplication.
//!
//! What is deliberately **not** cached:
//! * unparseable candidates — rejecting them is already the cheapest
//!   path, and raw defect text has no canonical form;
//! * `RuntimeFail` outcomes — PJRT/infrastructure errors may be
//!   transient and must not poison a persistent store.
//!
//! Durability model: one line per record, flushed on write; a process
//! killed mid-write corrupts at most the final line, which the loader
//! skips (with a warning). `cache gc` compacts duplicate keys and
//! folds the per-session `stats` trailer lines into one.

pub mod events;
pub mod hash;
pub mod transcript;

pub use events::{EventJournal, TrialEvent, TrialEventKind};
pub use hash::{key_for_source, sha256_hex, EvalKey};
pub use transcript::{TranscriptEntry, TranscriptStore};

use std::collections::HashMap;
use std::io::{BufRead, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::costmodel::{BoundKind, Timing};
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

/// The deterministic, replayable part of one candidate evaluation.
#[derive(Debug, Clone)]
pub enum StoredOutcome {
    /// Stage-0 rejection by the static validity guard (DESIGN.md §11):
    /// the exact structured diagnostics, journaled under a
    /// guard-namespaced key ([`EvalKey::guarded`]) so a replay is
    /// bit-identical and never shadows a full-pipeline record.
    GuardReject { diagnostics: Vec<crate::guard::GuardDiagnostic> },
    /// Stage-1 rejection (syntax / validation / resolution) — the
    /// exact error string the compile gate produced.
    CompileFail { error: String },
    /// Stage-2 rejection: compiled but wrong numerics on PJRT.
    FunctionalFail { max_abs_diff: f64 },
    /// Cleared both gates; the noise-free cost-model timing. Measured
    /// (noisy) numbers are re-derived at replay time.
    Ok { timing: Timing },
}

/// One journal entry: outcome plus provenance.
#[derive(Debug, Clone)]
pub struct StoredEval {
    pub op: String,
    /// Which LLM first produced this candidate (provenance only; not
    /// part of the lookup key — see module docs).
    pub model: String,
    pub outcome: StoredOutcome,
}

/// Append-only JSONL store with an in-memory index. Cheap to share:
/// wrap in `Arc` and clone the handle.
pub struct EvalStore {
    path: PathBuf,
    map: RwLock<HashMap<String, StoredEval>>,
    writer: Mutex<std::fs::File>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Aggregate numbers for `cache stats` / `cache gc`.
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    pub entries: usize,
    pub ok: usize,
    pub compile_fail: usize,
    pub functional_fail: usize,
    pub guard_rejected: usize,
    pub ops: usize,
    /// Cumulative hits/misses folded from journaled `stats` lines.
    pub hits: u64,
    pub misses: u64,
    pub file_bytes: u64,
    pub journal_lines: usize,
}

impl EvalStore {
    /// Open (or create) the journal at `path` and index its entries.
    /// The torn tail of a killed process is truncated before the
    /// append handle opens (a fresh record must never concatenate onto
    /// partial bytes); any other corrupt line is skipped with a
    /// warning — the cache is advisory, never fatal.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating eval-cache dir")?;
            }
        }
        let torn = crate::util::truncate_torn_tail(&path).context("repairing eval-cache tail")?;
        if torn > 0 {
            eprintln!(
                "warning: eval cache {}: truncated {torn} bytes of torn final line",
                path.display()
            );
        }
        let mut map = HashMap::new();
        if path.exists() {
            let f = std::fs::File::open(&path).context("opening eval cache")?;
            for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match parse_line(&line) {
                    Ok(Line::Eval { key, entry }) => {
                        map.entry(key).or_insert(entry);
                    }
                    Ok(Line::Stats { .. }) => {}
                    Err(e) => {
                        eprintln!(
                            "warning: eval cache {}: skipping bad line {}: {e}",
                            path.display(),
                            i + 1
                        );
                    }
                }
            }
        }
        let writer = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .context("opening eval cache for append")?;
        Ok(Arc::new(Self {
            path,
            map: RwLock::new(map),
            writer: Mutex::new(writer),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Cached result for `key`, counting a hit or miss.
    pub fn lookup(&self, key: &EvalKey) -> Option<StoredEval> {
        let found = self.map.read().unwrap().get(key.as_str()).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert + journal a fresh record. A key that is already present
    /// (e.g. two workers racing on the same candidate) is left as-is
    /// and not re-journaled.
    pub fn record(&self, key: &EvalKey, entry: StoredEval) -> Result<()> {
        {
            let mut g = self.map.write().unwrap();
            if g.contains_key(key.as_str()) {
                return Ok(());
            }
            g.insert(key.as_str().to_string(), entry.clone());
        }
        let line = eval_line(key, &entry).to_string();
        let mut w = self.writer.lock().unwrap();
        w.write_all(line.as_bytes())?;
        w.write_all(b"\n")?;
        w.flush()?;
        Ok(())
    }

    /// Unique cached evaluations.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hits served by this process.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses seen by this process.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Journal this session's hit/miss counters so `cache stats` can
    /// report cumulative savings across process lifetimes. Call once
    /// at the end of a campaign/run; a no-op when nothing was looked
    /// up.
    pub fn flush_session_stats(&self) -> Result<()> {
        let (h, m) = (self.hits(), self.misses());
        if h == 0 && m == 0 {
            return Ok(());
        }
        let line = Json::obj(vec![
            ("type", Json::Str("stats".into())),
            ("hits", Json::Num(h as f64)),
            ("misses", Json::Num(m as f64)),
        ])
        .to_string();
        let mut w = self.writer.lock().unwrap();
        w.write_all(line.as_bytes())?;
        w.write_all(b"\n")?;
        w.flush()?;
        Ok(())
    }

    /// Read-only aggregate view of a journal on disk.
    pub fn stats(path: impl AsRef<Path>) -> Result<StoreStats> {
        let path = path.as_ref();
        let mut s = StoreStats::default();
        if !path.exists() {
            return Ok(s);
        }
        s.file_bytes = std::fs::metadata(path)?.len();
        let f = std::fs::File::open(path).context("opening eval cache")?;
        let mut seen = std::collections::HashSet::new();
        let mut ops = std::collections::HashSet::new();
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            s.journal_lines += 1;
            match parse_line(&line) {
                Ok(Line::Eval { key, entry }) => {
                    if !seen.insert(key) {
                        continue;
                    }
                    s.entries += 1;
                    ops.insert(entry.op.clone());
                    match entry.outcome {
                        StoredOutcome::Ok { .. } => s.ok += 1,
                        StoredOutcome::CompileFail { .. } => s.compile_fail += 1,
                        StoredOutcome::FunctionalFail { .. } => s.functional_fail += 1,
                        StoredOutcome::GuardReject { .. } => s.guard_rejected += 1,
                    }
                }
                Ok(Line::Stats { hits, misses }) => {
                    s.hits += hits;
                    s.misses += misses;
                }
                Err(_) => {}
            }
        }
        s.ops = ops.len();
        Ok(s)
    }

    /// Compact the journal in place: one line per unique key (first
    /// occurrence wins — the journal is append-only, so the first line
    /// is the original evaluation), all `stats` lines folded into one,
    /// corrupt lines dropped. Returns (bytes_before, bytes_after).
    pub fn gc(path: impl AsRef<Path>) -> Result<(u64, u64)> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(eyre!("no eval cache at {}", path.display()));
        }
        let before = std::fs::metadata(path)?.len();
        let f = std::fs::File::open(path).context("opening eval cache")?;
        let mut seen = std::collections::HashSet::new();
        let mut kept: Vec<String> = Vec::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(&line) {
                Ok(Line::Eval { key, .. }) => {
                    if seen.insert(key) {
                        kept.push(line);
                    }
                }
                Ok(Line::Stats { hits: h, misses: m }) => {
                    hits += h;
                    misses += m;
                }
                Err(_) => {}
            }
        }
        if hits > 0 || misses > 0 {
            kept.push(
                Json::obj(vec![
                    ("type", Json::Str("stats".into())),
                    ("hits", Json::Num(hits as f64)),
                    ("misses", Json::Num(misses as f64)),
                ])
                .to_string(),
            );
        }
        let tmp = path.with_extension("jsonl.gc.tmp");
        {
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&tmp).context("creating gc temp file")?,
            );
            for line in &kept {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")?;
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path).context("replacing eval cache")?;
        let after = std::fs::metadata(path)?.len();
        Ok((before, after))
    }
}

// ---------------------------------------------------------------------
// JSONL (de)serialization — util::json, no serde (offline environment).

enum Line {
    Eval { key: String, entry: StoredEval },
    Stats { hits: u64, misses: u64 },
}

/// f64 → Json, preserving non-finite values (a shape-mismatch
/// functional diff is `inf`, which bare JSON numbers cannot carry).
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("nan".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn get_num(v: &Json, key: &str) -> Result<f64> {
    match v.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(Json::Str(s)) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => Err(eyre!("bad numeric field `{key}`: {other}")),
        },
        _ => Err(eyre!("missing numeric field `{key}`")),
    }
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("missing string field `{key}`"))
}

fn bound_str(b: BoundKind) -> &'static str {
    match b {
        BoundKind::Compute => "compute",
        BoundKind::Memory => "memory",
        BoundKind::Launch => "launch",
    }
}

fn bound_from(s: &str) -> Result<BoundKind> {
    match s {
        "compute" => Ok(BoundKind::Compute),
        "memory" => Ok(BoundKind::Memory),
        "launch" => Ok(BoundKind::Launch),
        other => Err(eyre!("unknown bound kind `{other}`")),
    }
}

fn timing_to_json(t: &Timing) -> Json {
    Json::obj(vec![
        ("time", num(t.time)),
        ("t_compute", num(t.t_compute)),
        ("t_mem", num(t.t_mem)),
        ("t_overhead", num(t.t_overhead)),
        ("traffic", num(t.traffic)),
        ("occupancy", num(t.occupancy)),
        ("eff_compute", num(t.eff_compute)),
        ("eff_bw", num(t.eff_bw)),
        ("launches", Json::Num(t.launches as f64)),
        ("bound", Json::Str(bound_str(t.bound).into())),
    ])
}

fn timing_from_json(v: &Json) -> Result<Timing> {
    Ok(Timing {
        time: get_num(v, "time")?,
        t_compute: get_num(v, "t_compute")?,
        t_mem: get_num(v, "t_mem")?,
        t_overhead: get_num(v, "t_overhead")?,
        traffic: get_num(v, "traffic")?,
        occupancy: get_num(v, "occupancy")?,
        eff_compute: get_num(v, "eff_compute")?,
        eff_bw: get_num(v, "eff_bw")?,
        launches: get_num(v, "launches")? as u32,
        bound: bound_from(&get_str(v, "bound")?)?,
    })
}

fn eval_line(key: &EvalKey, entry: &StoredEval) -> Json {
    let mut fields = vec![
        ("type", Json::Str("eval".into())),
        ("key", Json::Str(key.as_str().to_string())),
        ("op", Json::Str(entry.op.clone())),
        ("model", Json::Str(entry.model.clone())),
    ];
    match &entry.outcome {
        StoredOutcome::Ok { timing } => {
            fields.push(("outcome", Json::Str("ok".into())));
            fields.push(("timing", timing_to_json(timing)));
        }
        StoredOutcome::CompileFail { error } => {
            fields.push(("outcome", Json::Str("compile_fail".into())));
            fields.push(("error", Json::Str(error.clone())));
        }
        StoredOutcome::FunctionalFail { max_abs_diff } => {
            fields.push(("outcome", Json::Str("functional_fail".into())));
            fields.push(("max_abs_diff", num(*max_abs_diff)));
        }
        StoredOutcome::GuardReject { diagnostics } => {
            fields.push(("outcome", Json::Str("guard_reject".into())));
            fields.push((
                "diagnostics",
                Json::Arr(diagnostics.iter().map(diagnostic_to_json).collect()),
            ));
        }
    }
    Json::obj(fields)
}

fn diagnostic_to_json(d: &crate::guard::GuardDiagnostic) -> Json {
    let mut fields = vec![
        ("code", Json::Str(d.code.as_str().to_string())),
        ("field", Json::Str(d.field.clone())),
        ("message", Json::Str(d.message.clone())),
    ];
    if let Some((hf, hv)) = &d.hint {
        fields.push(("hint_field", Json::Str(hf.clone())));
        fields.push(("hint_value", Json::Str(hv.clone())));
    }
    Json::obj(fields)
}

fn diagnostic_from_json(v: &Json) -> Result<crate::guard::GuardDiagnostic> {
    let code_str = get_str(v, "code")?;
    let code = crate::guard::GuardCode::from_str(&code_str)
        .ok_or_else(|| eyre!("unknown guard code `{code_str}`"))?;
    let hint = match (v.get("hint_field"), v.get("hint_value")) {
        (Some(f), Some(val)) => Some((
            f.as_str().ok_or_else(|| eyre!("bad hint_field"))?.to_string(),
            val.as_str().ok_or_else(|| eyre!("bad hint_value"))?.to_string(),
        )),
        _ => None,
    };
    Ok(crate::guard::GuardDiagnostic {
        code,
        field: get_str(v, "field")?,
        message: get_str(v, "message")?,
        hint,
    })
}

fn parse_line(line: &str) -> Result<Line> {
    let v = json::parse(line).map_err(|e| eyre!("{e}"))?;
    match v.get("type").and_then(|t| t.as_str()) {
        Some("stats") => Ok(Line::Stats {
            hits: v.get("hits").and_then(|x| x.as_u64()).unwrap_or(0),
            misses: v.get("misses").and_then(|x| x.as_u64()).unwrap_or(0),
        }),
        Some("eval") => {
            let key = get_str(&v, "key")?;
            let op = get_str(&v, "op")?;
            let model = get_str(&v, "model")?;
            let outcome = match get_str(&v, "outcome")?.as_str() {
                "ok" => StoredOutcome::Ok {
                    timing: timing_from_json(
                        v.get("timing").ok_or_else(|| eyre!("missing timing"))?,
                    )?,
                },
                "compile_fail" => StoredOutcome::CompileFail { error: get_str(&v, "error")? },
                "functional_fail" => StoredOutcome::FunctionalFail {
                    max_abs_diff: get_num(&v, "max_abs_diff")?,
                },
                "guard_reject" => StoredOutcome::GuardReject {
                    diagnostics: v
                        .get("diagnostics")
                        .and_then(|d| d.as_arr())
                        .ok_or_else(|| eyre!("missing diagnostics"))?
                        .iter()
                        .map(diagnostic_from_json)
                        .collect::<Result<Vec<_>>>()?,
                },
                other => return Err(eyre!("unknown outcome `{other}`")),
            };
            Ok(Line::Eval { key, entry: StoredEval { op, model, outcome } })
        }
        other => Err(eyre!("unknown journal line type {other:?}")),
    }
}

/// Human-readable `cache stats` rendering.
pub fn stats_report(path: impl AsRef<Path>, s: &StoreStats) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    writeln!(out, "eval cache: {}", path.as_ref().display()).unwrap();
    writeln!(
        out,
        "  entries: {} unique ({} journal lines, {} bytes)",
        s.entries, s.journal_lines, s.file_bytes
    )
    .unwrap();
    writeln!(
        out,
        "  outcomes: {} ok, {} compile_fail, {} functional_fail, {} guard_rejected",
        s.ok, s.compile_fail, s.functional_fail, s.guard_rejected
    )
    .unwrap();
    writeln!(out, "  ops covered: {}", s.ops).unwrap();
    writeln!(
        out,
        "  cumulative: {} hits, {} misses ({} evaluations saved)",
        s.hits, s.misses, s.hits
    )
    .unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evo_store_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_timing() -> Timing {
        Timing {
            time: 1.25e-4,
            t_compute: 9e-5,
            t_mem: 1.2e-4,
            t_overhead: 5e-6,
            traffic: 3.2e6,
            occupancy: 0.66,
            eff_compute: 0.4,
            eff_bw: 0.8,
            launches: 2,
            bound: BoundKind::Memory,
        }
    }

    #[test]
    fn journal_roundtrip_across_reopen() {
        let dir = tmpdir("rt");
        let path = dir.join("cache.jsonl");
        let k1 = EvalKey::from_canonical("matmul_64", "kernel a");
        let k2 = EvalKey::from_canonical("matmul_64", "kernel b");
        let k3 = EvalKey::from_canonical("relu_64", "kernel c");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k1,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store
                .record(
                    &k2,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "Claude-Sonnet-4".into(),
                        outcome: StoredOutcome::CompileFail {
                            error: "validation error: smem overflow".into(),
                        },
                    },
                )
                .unwrap();
            store
                .record(
                    &k3,
                    StoredEval {
                        op: "relu_64".into(),
                        model: "DeepSeek-V3.1".into(),
                        outcome: StoredOutcome::FunctionalFail {
                            max_abs_diff: f64::INFINITY,
                        },
                    },
                )
                .unwrap();
        }
        let store = EvalStore::open(&path).unwrap();
        assert_eq!(store.len(), 3);
        match store.lookup(&k1).unwrap().outcome {
            StoredOutcome::Ok { timing } => {
                assert_eq!(timing.time, 1.25e-4);
                assert_eq!(timing.launches, 2);
                assert_eq!(timing.bound, BoundKind::Memory);
            }
            other => panic!("{other:?}"),
        }
        match store.lookup(&k2).unwrap().outcome {
            StoredOutcome::CompileFail { error } => assert!(error.contains("smem")),
            other => panic!("{other:?}"),
        }
        match store.lookup(&k3).unwrap().outcome {
            StoredOutcome::FunctionalFail { max_abs_diff } => {
                assert!(max_abs_diff.is_infinite())
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(store.hits(), 3);
        assert_eq!(store.misses(), 0);
        assert!(store.lookup(&EvalKey::from_canonical("x", "y")).is_none());
        assert_eq!(store.misses(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn guard_reject_roundtrip_across_reopen() {
        use crate::guard::{GuardCode, GuardDiagnostic};
        let dir = tmpdir("guard");
        let path = dir.join("cache.jsonl");
        let key = EvalKey::guarded("matmul_64", "kernel a");
        let diagnostics = vec![
            GuardDiagnostic {
                code: GuardCode::ShapeMismatch,
                field: "tile_m".into(),
                message: "tile_m=256 exceeds every operand extent".into(),
                hint: Some(("tile_m".into(), "64".into())),
            },
            GuardDiagnostic {
                code: GuardCode::NonTerminating,
                field: "tile_k".into(),
                message: "tile_k=0 is a zero-step loop construct".into(),
                hint: None,
            },
        ];
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &key,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "GPT-4.1".into(),
                        outcome: StoredOutcome::GuardReject {
                            diagnostics: diagnostics.clone(),
                        },
                    },
                )
                .unwrap();
        }
        // Bit-identical replay after reopen: codes, fields, messages,
        // hints (and hint absence) all survive the journal round-trip.
        let store = EvalStore::open(&path).unwrap();
        match store.lookup(&key).unwrap().outcome {
            StoredOutcome::GuardReject { diagnostics: back } => {
                assert_eq!(back, diagnostics)
            }
            other => panic!("{other:?}"),
        }
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.guard_rejected, 1);
        assert_eq!(s.entries, 1);
        assert!(stats_report(&path, &s).contains("1 guard_rejected"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_tail_is_skipped() {
        let dir = tmpdir("torn");
        let path = dir.join("cache.jsonl");
        let k = EvalKey::from_canonical("matmul_64", "kernel a");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::CompileFail { error: "x".into() },
                    },
                )
                .unwrap();
        }
        // Simulate a kill mid-append: torn, unparseable final line.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"type\":\"eval\",\"key\":\"dead").unwrap();
        }
        // Reopen truncates the torn tail; a fresh record appended after
        // the repair must not merge with the partial bytes.
        let k2 = EvalKey::from_canonical("relu_64", "kernel b");
        {
            let store = EvalStore::open(&path).unwrap();
            assert_eq!(store.len(), 1);
            assert!(store.lookup(&k).is_some());
            store
                .record(
                    &k2,
                    StoredEval {
                        op: "relu_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::CompileFail { error: "y".into() },
                    },
                )
                .unwrap();
        }
        let store = EvalStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.lookup(&k).is_some());
        assert!(store.lookup(&k2).is_some());
        // Every surviving line is well-formed (no merged garbage).
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.journal_lines, 2);
        assert_eq!(s.entries, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gc_compacts_and_folds_stats() {
        let dir = tmpdir("gc");
        let path = dir.join("cache.jsonl");
        let k = EvalKey::from_canonical("matmul_64", "kernel a");
        {
            let store = EvalStore::open(&path).unwrap();
            store
                .record(
                    &k,
                    StoredEval {
                        op: "matmul_64".into(),
                        model: "-".into(),
                        outcome: StoredOutcome::Ok { timing: sample_timing() },
                    },
                )
                .unwrap();
            store.lookup(&k);
            store.flush_session_stats().unwrap();
        }
        // A second session appends a duplicate line for the same key
        // (as two racing processes would) plus its own stats.
        {
            use std::io::Write as _;
            let entry = StoredEval {
                op: "matmul_64".into(),
                model: "-".into(),
                outcome: StoredOutcome::Ok { timing: sample_timing() },
            };
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{}", eval_line(&k, &entry).to_string()).unwrap();
            writeln!(
                f,
                "{}",
                Json::obj(vec![
                    ("type", Json::Str("stats".into())),
                    ("hits", Json::Num(4.0)),
                    ("misses", Json::Num(2.0)),
                ])
                .to_string()
            )
            .unwrap();
            writeln!(f, "not json at all").unwrap();
        }
        let before_stats = EvalStore::stats(&path).unwrap();
        assert_eq!(before_stats.entries, 1);
        assert_eq!(before_stats.hits, 5); // 1 + 4
        assert_eq!(before_stats.misses, 3); // 1 + 2

        let (before, after) = EvalStore::gc(&path).unwrap();
        assert!(after < before, "{after} !< {before}");
        let s = EvalStore::stats(&path).unwrap();
        assert_eq!(s.entries, 1);
        assert_eq!(s.journal_lines, 2); // 1 eval + 1 folded stats
        assert_eq!(s.hits, 5);
        assert_eq!(s.misses, 3);
        // Journal still loads and serves the entry.
        let store = EvalStore::open(&path).unwrap();
        assert!(store.lookup(&k).is_some());
        std::fs::remove_dir_all(dir).ok();
    }
}
