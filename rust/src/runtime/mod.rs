//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (`xla` crate 0.1.6 — pattern from
//! /opt/xla-example/load_hlo).
//!
//! The xla wrapper types hold raw C pointers and are `!Send`, so the
//! client + compiled-executable cache live on one dedicated owner
//! thread; callers talk to it over an mpsc channel. `Runtime` itself is
//! cheap to clone and `Send + Sync`, which is what the campaign's
//! std::thread worker pool needs. Executables are compiled once per
//! artifact path and cached for the lifetime of the runtime (the paper
//! compiles each candidate once and times it many times).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::{eyre, Result};

/// A concrete tensor value crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorValue {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorValue {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }
}

enum Req {
    Execute {
        path: PathBuf,
        inputs: Vec<TensorValue>,
        resp: mpsc::SyncSender<Result<Vec<f32>, String>>,
    },
    Stats {
        resp: mpsc::SyncSender<RuntimeStats>,
    },
}

/// Counters exposed for the perf pass and EXPERIMENTS.md.
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compiles: u64,
    pub cache_hits: u64,
}

/// Handle to the PJRT owner thread. Clone freely.
#[derive(Clone)]
pub struct Runtime {
    tx: Arc<Mutex<mpsc::Sender<Req>>>,
}

impl Runtime {
    /// Spawn the owner thread with a fresh CPU PJRT client.
    pub fn new() -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<(), String>>(1);
        std::thread::Builder::new()
            .name("pjrt-owner".into())
            .spawn(move || owner_thread(rx, ready_tx))
            .map_err(|e| eyre!("spawning pjrt owner: {e}"))?;
        ready_rx
            .recv()
            .map_err(|e| eyre!("pjrt owner died during init: {e}"))?
            .map_err(|e| eyre!("PjRtClient::cpu failed: {e}"))?;
        Ok(Self { tx: Arc::new(Mutex::new(tx)) })
    }

    /// Execute the artifact at `path` with the given inputs; returns the
    /// flattened f32 output (artifacts are lowered as 1-tuples).
    pub fn execute(&self, path: PathBuf, inputs: Vec<TensorValue>) -> Result<Vec<f32>> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        {
            let tx = self.tx.lock().expect("runtime sender poisoned");
            tx.send(Req::Execute { path, inputs, resp: resp_tx })
                .map_err(|_| eyre!("pjrt owner thread is gone"))?;
        }
        resp_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner dropped the response"))?
            .map_err(|e| eyre!("pjrt execution failed: {e}"))
    }

    /// Snapshot execution counters.
    pub fn stats(&self) -> Result<RuntimeStats> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        {
            let tx = self.tx.lock().expect("runtime sender poisoned");
            tx.send(Req::Stats { resp: resp_tx })
                .map_err(|_| eyre!("pjrt owner thread is gone"))?;
        }
        resp_rx.recv().map_err(|_| eyre!("pjrt owner dropped the response"))
    }
}

fn owner_thread(rx: mpsc::Receiver<Req>, ready: mpsc::SyncSender<Result<(), String>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let mut cache: HashMap<PathBuf, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = RuntimeStats::default();

    while let Ok(req) = rx.recv() {
        match req {
            Req::Stats { resp } => {
                let _ = resp.send(stats.clone());
            }
            Req::Execute { path, inputs, resp } => {
                let result = run_one(&client, &mut cache, &mut stats, &path, &inputs);
                stats.executions += 1;
                let _ = resp.send(result);
            }
        }
    }
}

fn run_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    stats: &mut RuntimeStats,
    path: &PathBuf,
    inputs: &[TensorValue],
) -> Result<Vec<f32>, String> {
    if !cache.contains_key(path) {
        let proto =
            xla::HloModuleProto::from_text_file(path).map_err(|e| format!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| format!("compile {path:?}: {e}"))?;
        cache.insert(path.clone(), exe);
        stats.compiles += 1;
    } else {
        stats.cache_hits += 1;
    }
    let exe = cache.get(path).expect("just inserted");

    let mut literals = Vec::with_capacity(inputs.len());
    for t in inputs {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| format!("reshape {:?}: {e}", t.shape))?;
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| format!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("to_literal: {e}"))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| format!("to_tuple1: {e}"))?;
    out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))
}
