//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! CPU PJRT clients (`xla` crate 0.1.6 — pattern from
//! /opt/xla-example/load_hlo; design: DESIGN.md §10).
//!
//! The xla wrapper types hold raw C pointers and are `!Send`, so
//! clients and compiled-executable caches live on dedicated owner
//! threads. Where the first version funneled every caller through a
//! single owner thread (serializing stage-2 functional testing for the
//! whole campaign), the runtime is now a **sharded executor pool**:
//!
//! * N owner threads (`Runtime::with_shards`; `0` = one per CPU), each
//!   with its own `PjRtClient` and executable cache;
//! * requests are routed by a stable FNV-1a hash of the artifact path
//!   ([`Runtime::shard_of`]), so each executable compiles on exactly
//!   one shard and distinct artifacts execute in parallel;
//! * [`Runtime::execute_pairs`] submits a whole batch of functional
//!   test cases as one request per artifact (one channel round-trip
//!   per shard) instead of one `execute()` round-trip per case.
//!
//! `Runtime` itself is cheap to clone and `Send + Sync`, which is what
//! both the campaign's `std::thread` worker pool and the evaluator's
//! concurrent callers need. Executables are compiled once per artifact
//! path, on the shard the path routes to, and cached for the lifetime
//! of the runtime (the paper compiles each candidate once and times it
//! many times).
//!
//! Shard 0's PJRT client is created eagerly during construction so a
//! broken PJRT install fails fast in [`Runtime::new`]; the remaining
//! shards create their clients lazily on first request, keeping
//! construction cost proportional to actual use (tests that touch one
//! artifact pay for one client, a full campaign warms them all).
//!
//! [`RuntimeStats`] counters are kept **per shard**; [`Runtime::stats`]
//! sums them and [`Runtime::shard_stats`] exposes the per-shard
//! breakdown. Because routing is stable, the aggregated `compiles`
//! still counts each distinct artifact path at most once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::{eyre, Result};

/// A concrete tensor value crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorValue {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorValue {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }
}

/// One functional test case: the full input set for a single execution.
pub type Case = Vec<TensorValue>;

enum Req {
    /// Force client creation (construction-time fail-fast probe).
    Init {
        resp: mpsc::SyncSender<Result<(), String>>,
    },
    Execute {
        path: PathBuf,
        inputs: Vec<TensorValue>,
        resp: mpsc::SyncSender<Result<Vec<f32>, String>>,
    },
    /// Execute one artifact over many cases in a single round-trip.
    /// The cases are shared (`Arc`) so the ref and candidate batches of
    /// a functional verdict reuse the same generated input buffers.
    ExecuteBatch {
        path: PathBuf,
        cases: Arc<Vec<Case>>,
        resp: mpsc::SyncSender<Result<Vec<Vec<f32>>, String>>,
    },
    Stats {
        resp: mpsc::SyncSender<RuntimeStats>,
    },
}

/// Counters exposed for the perf pass and EXPERIMENTS.md.
///
/// Counters are accumulated **per shard** and summed by
/// [`Runtime::stats`]: `executions` counts submitted cases (a batch of
/// five cases is five executions), while `compiles`/`cache_hits` count
/// executable-cache outcomes per *request* (a batch resolves its
/// executable once, so it contributes one compile or one hit).
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compiles: u64,
    pub cache_hits: u64,
}

impl RuntimeStats {
    fn absorb(&mut self, other: &RuntimeStats) {
        self.executions += other.executions;
        self.compiles += other.compiles;
        self.cache_hits += other.cache_hits;
    }
}

/// One owner thread's mailbox. The `Mutex` makes the `mpsc::Sender`
/// shareable across the campaign's worker threads.
struct Shard {
    tx: Mutex<mpsc::Sender<Req>>,
}

/// Hard ceiling on the shard count: beyond this, extra shards only
/// cost threads and (once touched) whole PJRT clients.
pub const MAX_SHARDS: usize = 256;

/// Handle to the sharded PJRT executor pool. Clone freely.
#[derive(Clone)]
pub struct Runtime {
    shards: Arc<Vec<Shard>>,
}

/// Stable artifact-path → shard routing (FNV-1a over the path bytes).
/// Deterministic across processes and runtime instances: the same path
/// always lands on the same shard for a given shard count.
fn route(path: &Path, shards: usize) -> usize {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in path.to_string_lossy().as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    (h % shards as u64) as usize
}

impl Runtime {
    /// Spawn the executor pool with one shard per CPU.
    pub fn new() -> Result<Self> {
        Self::with_shards(0)
    }

    /// Spawn the executor pool with `shards` owner threads (`0` = one
    /// per CPU, via `available_parallelism`; capped at [`MAX_SHARDS`] —
    /// every shard that actually executes work owns a full PJRT client
    /// with its own intra-op thread pool, so absurd counts would only
    /// burn memory). Fails fast if PJRT itself is unavailable (shard
    /// 0's client is created eagerly).
    pub fn with_shards(shards: usize) -> Result<Self> {
        let n = if shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            shards
        }
        .min(MAX_SHARDS);
        let mut pool = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Req>();
            std::thread::Builder::new()
                .name(format!("pjrt-owner-{i}"))
                .spawn(move || owner_thread(rx))
                .map_err(|e| eyre!("spawning pjrt owner {i}: {e}"))?;
            pool.push(Shard { tx: Mutex::new(tx) });
        }
        let rt = Self { shards: Arc::new(pool) };
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        rt.send(0, Req::Init { resp: resp_tx })?;
        resp_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner died during init"))?
            .map_err(|e| eyre!("{e}"))?;
        Ok(rt)
    }

    /// Number of executor shards in the pool.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard `path` routes to (stable: same path → same shard).
    pub fn shard_of(&self, path: &Path) -> usize {
        route(path, self.shards.len())
    }

    fn send(&self, shard: usize, req: Req) -> Result<()> {
        let tx = self.shards[shard].tx.lock().expect("runtime sender poisoned");
        tx.send(req).map_err(|_| eyre!("pjrt owner thread {shard} is gone"))
    }

    /// Execute the artifact at `path` with the given inputs; returns the
    /// flattened f32 output (artifacts are lowered as 1-tuples).
    pub fn execute(&self, path: PathBuf, inputs: Vec<TensorValue>) -> Result<Vec<f32>> {
        let shard = self.shard_of(&path);
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.send(shard, Req::Execute { path, inputs, resp: resp_tx })?;
        resp_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner dropped the response"))?
            .map_err(|e| eyre!("pjrt execution failed: {e}"))
    }

    /// Execute one artifact over a batch of cases in a single
    /// round-trip to its shard; returns one flattened output per case.
    pub fn execute_batch(&self, path: PathBuf, cases: Arc<Vec<Case>>) -> Result<Vec<Vec<f32>>> {
        let shard = self.shard_of(&path);
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.send(shard, Req::ExecuteBatch { path, cases, resp: resp_tx })?;
        resp_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner dropped the response"))?
            .map_err(|e| eyre!("pjrt execution failed: {e}"))
    }

    /// Execute a reference/candidate artifact pair over the same shared
    /// batch of cases: both batch requests are submitted before either
    /// response is awaited, so the two artifacts run concurrently when
    /// they route to different shards, and each shard sees exactly one
    /// round-trip. Returns `(ref_outputs, candidate_outputs)`, one
    /// flattened output per case each.
    pub fn execute_pairs(
        &self,
        ref_path: PathBuf,
        cand_path: PathBuf,
        cases: Arc<Vec<Case>>,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let ref_shard = self.shard_of(&ref_path);
        let cand_shard = self.shard_of(&cand_path);
        let (ref_tx, ref_rx) = mpsc::sync_channel(1);
        let (cand_tx, cand_rx) = mpsc::sync_channel(1);
        self.send(
            ref_shard,
            Req::ExecuteBatch { path: ref_path, cases: cases.clone(), resp: ref_tx },
        )?;
        self.send(cand_shard, Req::ExecuteBatch { path: cand_path, cases, resp: cand_tx })?;
        let want = ref_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner dropped the response"))?
            .map_err(|e| eyre!("pjrt execution failed: {e}"))?;
        let got = cand_rx
            .recv()
            .map_err(|_| eyre!("pjrt owner dropped the response"))?
            .map_err(|e| eyre!("pjrt execution failed: {e}"))?;
        Ok((want, got))
    }

    /// Snapshot execution counters, summed across all shards.
    pub fn stats(&self) -> Result<RuntimeStats> {
        let mut total = RuntimeStats::default();
        for s in self.shard_stats()? {
            total.absorb(&s);
        }
        Ok(total)
    }

    /// Per-shard counter snapshots, in shard order.
    pub fn shard_stats(&self) -> Result<Vec<RuntimeStats>> {
        let mut out = Vec::with_capacity(self.shards.len());
        for shard in 0..self.shards.len() {
            let (resp_tx, resp_rx) = mpsc::sync_channel(1);
            self.send(shard, Req::Stats { resp: resp_tx })?;
            out.push(
                resp_rx.recv().map_err(|_| eyre!("pjrt owner dropped the response"))?,
            );
        }
        Ok(out)
    }
}

fn owner_thread(rx: mpsc::Receiver<Req>) {
    let mut client: Option<xla::PjRtClient> = None;
    let mut cache: HashMap<PathBuf, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = RuntimeStats::default();

    while let Ok(req) = rx.recv() {
        match req {
            Req::Init { resp } => {
                let _ = resp.send(ensure_client(&mut client).map(|_| ()));
            }
            Req::Stats { resp } => {
                let _ = resp.send(stats.clone());
            }
            Req::Execute { path, inputs, resp } => {
                let result = match ensure_client(&mut client) {
                    Ok(c) => run_one(c, &mut cache, &mut stats, &path, &inputs),
                    Err(e) => Err(e),
                };
                stats.executions += 1;
                let _ = resp.send(result);
            }
            Req::ExecuteBatch { path, cases, resp } => {
                let result = match ensure_client(&mut client) {
                    Ok(c) => run_batch(c, &mut cache, &mut stats, &path, &cases),
                    Err(e) => Err(e),
                };
                stats.executions += cases.len() as u64;
                let _ = resp.send(result);
            }
        }
    }
}

/// Lazily create this shard's PJRT client (shard 0 is forced eagerly
/// by the construction-time `Init` probe).
fn ensure_client(slot: &mut Option<xla::PjRtClient>) -> Result<&xla::PjRtClient, String> {
    if slot.is_none() {
        let c = xla::PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu failed: {e}"))?;
        *slot = Some(c);
    }
    Ok(slot.as_ref().expect("just initialized"))
}

/// Compile-or-fetch the executable for `path` on this shard's cache.
fn compiled<'a>(
    client: &xla::PjRtClient,
    cache: &'a mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    stats: &mut RuntimeStats,
    path: &PathBuf,
) -> Result<&'a xla::PjRtLoadedExecutable, String> {
    if !cache.contains_key(path) {
        let proto =
            xla::HloModuleProto::from_text_file(path).map_err(|e| format!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| format!("compile {path:?}: {e}"))?;
        cache.insert(path.clone(), exe);
        stats.compiles += 1;
    } else {
        stats.cache_hits += 1;
    }
    Ok(cache.get(path).expect("just inserted"))
}

fn run_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    stats: &mut RuntimeStats,
    path: &PathBuf,
    inputs: &[TensorValue],
) -> Result<Vec<f32>, String> {
    let exe = compiled(client, cache, stats, path)?;
    exec_case(exe, inputs)
}

fn run_batch(
    client: &xla::PjRtClient,
    cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    stats: &mut RuntimeStats,
    path: &PathBuf,
    cases: &[Case],
) -> Result<Vec<Vec<f32>>, String> {
    let exe = compiled(client, cache, stats, path)?;
    cases.iter().map(|inputs| exec_case(exe, inputs)).collect()
}

fn exec_case(exe: &xla::PjRtLoadedExecutable, inputs: &[TensorValue]) -> Result<Vec<f32>, String> {
    let mut literals = Vec::with_capacity(inputs.len());
    for t in inputs {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| format!("reshape {:?}: {e}", t.shape))?;
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| format!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("to_literal: {e}"))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| format!("to_tuple1: {e}"))?;
    out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable_and_in_range() {
        let p = Path::new("artifacts/matmul_64/ref.hlo.txt");
        for shards in 1..=8 {
            let first = route(p, shards);
            assert!(first < shards);
            // Same path, same shard count -> same shard, every time.
            assert_eq!(route(p, shards), first);
        }
        // A single shard takes everything.
        assert_eq!(route(Path::new("/any/where.hlo.txt"), 1), 0);
    }

    #[test]
    fn routing_spreads_distinct_paths() {
        let shards = 4;
        let hit: std::collections::HashSet<usize> = (0..64)
            .map(|i| route(Path::new(&format!("artifacts/op_{i}/ref.hlo.txt")), shards))
            .collect();
        // 64 distinct artifact paths must not all collapse onto one
        // shard (FNV-1a spreads short ASCII keys well).
        assert!(hit.len() >= 2, "{hit:?}");
    }
}
