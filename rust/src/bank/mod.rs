//! Persistent cross-campaign kernel knowledge bank (DESIGN.md §18).
//!
//! Every campaign in this reproduction used to start cold: the
//! archive, insights, and performance profiles died with the run. The
//! bank makes elite kernels *durable artifacts* that outlive any
//! single campaign — an append-only JSONL journal (`bank.jsonl`) of
//! content-addressed **bank entries**: the elite candidate's canonical
//! printed form plus its SHA-256 key, op/family/category, the goal it
//! was optimized under, its noise-free measured speedup and
//! goal-adjusted fitness, a distilled profile line, provider/route
//! provenance, and the insight the LLM attached to it.
//!
//! Journal mechanics reuse the eval-cache machinery (DESIGN.md §8/§14):
//! appends are staged in a [`GroupWriter`] and group-committed at the
//! engine's trial boundaries; opens are served by the [`index`] sidecar
//! (honouring `EVO_JOURNAL_INDEX`) with record bodies `pread` + parsed
//! lazily; a torn tail left by a killed process is truncated before
//! the append handle opens; `bank gc` compacts duplicate keys
//! first-occurrence-wins.
//!
//! Consumption is strictly read-only and deterministic:
//!
//! * **retrieval-seeded prompts** — [`KernelBank::retrieve`] ranks
//!   entries by (same-op > same-family > same-category >
//!   ArgSpec-shape similarity), tie-broken by goal-adjusted fitness
//!   then key, and the engine injects the top-K as a `## PRIOR
//!   ELITES` few-shot section ([`render_refs`]) into generation
//!   requests via the NUL-framed `bank_refs` request field;
//! * **warm-started campaigns** — `--warm-start <bank>` seeds each
//!   cell's population and the shared archive from the bank's elites
//!   for that op before trial 0 ([`KernelBank::entries_for_op`]).
//!
//! Determinism contract: a bank attached for *deposits* (`--bank`)
//! only ever writes — records and events are byte-identical with or
//! without it. A bank attached for *consumption* (`--warm-start`) is
//! an immutable snapshot taken at campaign start, so retrieval text is
//! constant per cell, workers fed the same snapshot over the wire
//! (`GET /bank`) behave byte-identically to a local run, and an empty
//! snapshot is indistinguishable from no snapshot at all.

use std::collections::HashMap;
use std::io::{BufRead as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::store::{index, EvalKey, GroupWriter, IndexMode};
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

/// How many retrieved elites a generation prompt carries.
pub const RETRIEVE_K: usize = 3;

/// How many bank elites seed a warm-started cell's population.
pub const WARM_SEED_K: usize = 3;

/// One journaled elite. `src` is the canonical printed form; `key` is
/// [`EvalKey::from_canonical`] over (op, src), so the bank is
/// content-addressed and deposits dedup across campaigns.
#[derive(Debug, Clone, PartialEq)]
pub struct BankEntry {
    pub key: String,
    pub op: String,
    pub family: String,
    pub category: u8,
    /// Goal label the depositing run optimized under ("speedup",
    /// "memory", "balanced").
    pub goal: String,
    /// Canonical printed form of the elite kernel.
    pub src: String,
    /// Noise-free true speedup vs the op baseline at deposit time.
    pub speedup: f64,
    /// Goal-adjusted fitness at deposit time (equals `speedup` under
    /// the default goal).
    pub rank: f64,
    /// Flattened argument dims of the op — the retriever's shape axis.
    pub shape: Vec<usize>,
    /// Distilled one-line profile summary ("" when profiling had
    /// nothing to say).
    pub profile: String,
    /// Provenance: provider label, LLM name, method, ensemble member
    /// ("" when the provider was not an ensemble).
    pub provider: String,
    pub model: String,
    pub method: String,
    pub route: String,
    /// The insight line the LLM attached to the elite ("" if none).
    pub insight: String,
}

/// Content-addressed key for a canonical elite: identical to the
/// eval-cache keying rule so the two stores agree on identity.
pub fn entry_key(op: &str, canonical: &str) -> String {
    EvalKey::from_canonical(op, canonical).0
}

// ---------------------------------------------------------------------
// JSONL (de)serialization — util::json, no serde (offline environment).

/// f64 → Json preserving non-finite values (mirrors the eval cache).
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("nan".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn get_num(v: &Json, key: &str) -> Result<f64> {
    match v.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(Json::Str(s)) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => Err(eyre!("bad numeric field `{key}`: {other}")),
        },
        _ => Err(eyre!("missing numeric field `{key}`")),
    }
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("missing string field `{key}`"))
}

impl BankEntry {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::Str("elite".into())),
            ("key", Json::Str(self.key.clone())),
            ("op", Json::Str(self.op.clone())),
            ("family", Json::Str(self.family.clone())),
            ("category", Json::Num(self.category as f64)),
            ("goal", Json::Str(self.goal.clone())),
            ("speedup", num(self.speedup)),
            ("rank", num(self.rank)),
            (
                "shape",
                Json::Arr(self.shape.iter().map(|d| Json::Num(*d as f64)).collect()),
            ),
            ("profile", Json::Str(self.profile.clone())),
            ("provider", Json::Str(self.provider.clone())),
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.clone())),
            ("route", Json::Str(self.route.clone())),
            ("insight", Json::Str(self.insight.clone())),
            ("src", Json::Str(self.src.clone())),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        if v.get("type").and_then(|t| t.as_str()) != Some("elite") {
            return Err(eyre!("not a bank elite line"));
        }
        let shape = match v.get("shape") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| eyre!("bad shape dim")))
                .collect::<Result<Vec<_>>>()?,
            _ => return Err(eyre!("missing shape field")),
        };
        Ok(Self {
            key: get_str(v, "key")?,
            op: get_str(v, "op")?,
            family: get_str(v, "family")?,
            category: get_num(v, "category")? as u8,
            goal: get_str(v, "goal")?,
            src: get_str(v, "src")?,
            speedup: get_num(v, "speedup")?,
            rank: get_num(v, "rank")?,
            shape,
            profile: get_str(v, "profile")?,
            provider: get_str(v, "provider")?,
            model: get_str(v, "model")?,
            method: get_str(v, "method")?,
            route: get_str(v, "route")?,
            insight: get_str(v, "insight")?,
        })
    }
}

fn parse_entry(line: &str) -> Result<BankEntry> {
    let v = json::parse(line).map_err(|e| eyre!("{e}"))?;
    BankEntry::from_json(&v)
}

// ---------------------------------------------------------------------
// The bank

/// One in-memory slot: parsed, or an `(offset, len)` journal extent
/// hydrated on first consumption (deposit-only banks never pay body
/// parsing; see the eval cache's identical scheme).
#[derive(Debug, Clone)]
enum Slot {
    Parsed(BankEntry),
    OnDisk { offset: u64, len: u32 },
}

/// The kernel knowledge bank. Three flavours behind one type:
/// read-write over a journal file ([`KernelBank::open`]), read-only
/// over a journal file ([`KernelBank::load`]), and read-only over
/// wire-shipped lines ([`KernelBank::from_lines`] — what `campaign
/// work` builds from `GET /bank`). Cheap to share: wrap in `Arc`.
pub struct KernelBank {
    path: Option<PathBuf>,
    map: RwLock<HashMap<String, Slot>>,
    /// Positioned-read handle for lazy hydration (file-backed only).
    reader: Option<std::fs::File>,
    /// Append handle (read-write only); staged group-commit.
    writer: Option<Mutex<GroupWriter>>,
    indexed_open: bool,
    retrieval_hits: AtomicU64,
    retrieval_misses: AtomicU64,
    deposits: AtomicU64,
}

impl KernelBank {
    /// Open (or create) a read-write bank at `path`, honouring
    /// `EVO_JOURNAL_INDEX`. Torn tails are truncated before the append
    /// handle opens; corrupt interior lines are skipped with a warning
    /// — the bank is advisory, never fatal.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        Self::open_with(path, IndexMode::from_env())
    }

    /// [`KernelBank::open`] with an explicit index mode (the torture
    /// suite exercises both paths and asserts they agree).
    pub fn open_with(path: impl AsRef<Path>, mode: IndexMode) -> Result<Arc<Self>> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating bank dir")?;
            }
        }
        let torn = crate::util::truncate_torn_tail(&path).context("repairing bank tail")?;
        if torn > 0 {
            eprintln!(
                "warning: bank {}: truncated {torn} bytes of torn final line",
                path.display()
            );
        }
        // Append handle first so the journal exists (even empty)
        // before the reader and the index look at it.
        let writer = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .context("opening bank for append")?;
        let display = path.display().to_string();
        let extract = |off: u64, line: &str| match parse_entry(line) {
            Ok(e) => Some(e.key),
            Err(e) => {
                eprintln!("warning: bank {display}: skipping bad line at byte {off}: {e}");
                None
            }
        };
        let loaded = index::load(&path, mode, &extract).context("indexing bank")?;
        let mut map = HashMap::new();
        for r in loaded.records {
            map.entry(r.key).or_insert(Slot::OnDisk { offset: r.offset, len: r.len });
        }
        let reader = std::fs::File::open(&path).context("opening bank for read")?;
        Ok(Arc::new(Self {
            path: Some(path),
            map: RwLock::new(map),
            reader: Some(reader),
            writer: Some(Mutex::new(GroupWriter::new(writer))),
            indexed_open: loaded.indexed,
            retrieval_hits: AtomicU64::new(0),
            retrieval_misses: AtomicU64::new(0),
            deposits: AtomicU64::new(0),
        }))
    }

    /// Load an existing bank read-only (the `--warm-start` snapshot):
    /// a full scan that parses every entry up front, first occurrence
    /// wins, corrupt lines skipped with a warning. No torn-tail
    /// repair — a consumption snapshot must not mutate the file.
    pub fn load(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening warm-start bank {}", path.display()))?;
        let mut map = HashMap::new();
        for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match parse_entry(&line) {
                Ok(e) => {
                    map.entry(e.key.clone()).or_insert(Slot::Parsed(e));
                }
                Err(e) => eprintln!(
                    "warning: bank {}: skipping bad line {}: {e}",
                    path.display(),
                    i + 1
                ),
            }
        }
        Ok(Arc::new(Self {
            path: Some(path.to_path_buf()),
            map: RwLock::new(map),
            reader: None,
            writer: None,
            indexed_open: false,
            retrieval_hits: AtomicU64::new(0),
            retrieval_misses: AtomicU64::new(0),
            deposits: AtomicU64::new(0),
        }))
    }

    /// Build a read-only in-memory bank from journal lines shipped
    /// over the wire (`GET /bank`). Bad lines are skipped with a
    /// warning, matching [`KernelBank::load`] semantics exactly so a
    /// worker's snapshot equals the coordinator's file snapshot.
    pub fn from_lines<S: AsRef<str>>(lines: &[S]) -> Arc<Self> {
        let mut map = HashMap::new();
        for (i, line) in lines.iter().enumerate() {
            let line = line.as_ref();
            if line.trim().is_empty() {
                continue;
            }
            match parse_entry(line) {
                Ok(e) => {
                    map.entry(e.key.clone()).or_insert(Slot::Parsed(e));
                }
                Err(e) => {
                    eprintln!("warning: bank (wire): skipping bad line {}: {e}", i + 1)
                }
            }
        }
        Arc::new(Self {
            path: None,
            map: RwLock::new(map),
            reader: None,
            writer: None,
            indexed_open: false,
            retrieval_hits: AtomicU64::new(0),
            retrieval_misses: AtomicU64::new(0),
            deposits: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Whether this open was served by a valid sidecar index.
    pub fn opened_indexed(&self) -> bool {
        self.indexed_open
    }

    /// Unique entries.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deposit one elite. Content-addressed: a key already present is
    /// left as-is and not re-journaled (this is what keeps
    /// record-then-replay from growing the journal — the replay
    /// re-derives the same elites). Read-only banks ignore deposits.
    /// Staged in the group-commit buffer; durability arrives at the
    /// next [`KernelBank::flush`].
    pub fn deposit(&self, entry: BankEntry) -> Result<bool> {
        let Some(writer) = &self.writer else {
            return Ok(false);
        };
        {
            let mut g = self.map.write().unwrap();
            if g.contains_key(&entry.key) {
                return Ok(false);
            }
            g.insert(entry.key.clone(), Slot::Parsed(entry.clone()));
        }
        let line = entry.to_json().to_string();
        writer.lock().unwrap().append_line(line.as_bytes())?;
        self.deposits.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Merge one journal line from another bank (`bank import`).
    /// Returns whether the line was ingested.
    pub fn ingest_line(&self, line: &str) -> Result<bool> {
        let entry = parse_entry(line).context("ingesting bank line")?;
        self.deposit(entry)
    }

    /// Group-commit flush point: make every staged deposit durable.
    pub fn flush(&self) -> Result<()> {
        if let Some(writer) = &self.writer {
            writer.lock().unwrap().flush()?;
        }
        Ok(())
    }

    /// Test hook: simulate a kill between deposit and flush.
    #[doc(hidden)]
    pub fn drop_unflushed(&self) {
        if let Some(writer) = &self.writer {
            writer.lock().unwrap().drop_unflushed();
        }
    }

    /// Deposits journaled by this process.
    pub fn deposits(&self) -> u64 {
        self.deposits.load(Ordering::Relaxed)
    }

    /// (non-empty, empty) retrieval counts served by this process.
    pub fn retrieval_counts(&self) -> (u64, u64) {
        (
            self.retrieval_hits.load(Ordering::Relaxed),
            self.retrieval_misses.load(Ordering::Relaxed),
        )
    }

    /// The entry behind `key`, hydrating an on-disk slot on first
    /// touch (stale slots are dropped with a warning, mirroring the
    /// eval cache).
    fn hydrate(&self, key: &str) -> Option<BankEntry> {
        let extent = {
            let g = self.map.read().unwrap();
            match g.get(key)? {
                Slot::Parsed(e) => return Some(e.clone()),
                Slot::OnDisk { offset, len } => (*offset, *len),
            }
        };
        let reader = self.reader.as_ref()?;
        use std::os::unix::fs::FileExt as _;
        let (offset, len) = extent;
        let mut buf = vec![0u8; len as usize];
        let parsed = reader
            .read_exact_at(&mut buf, offset)
            .map_err(|e| eyre!("{e}"))
            .and_then(|_| {
                let text = std::str::from_utf8(&buf).map_err(|e| eyre!("{e}"))?;
                parse_entry(text.trim_end_matches('\n'))
            });
        match parsed {
            Ok(e) if e.key == key => {
                self.map
                    .write()
                    .unwrap()
                    .insert(key.to_string(), Slot::Parsed(e.clone()));
                Some(e)
            }
            other => {
                let why = match other {
                    Ok(e) => format!("record at byte {offset} keyed `{}`", e.key),
                    Err(e) => format!("record at byte {offset} unreadable: {e}"),
                };
                eprintln!(
                    "warning: bank: dropping stale index slot for `{key}`: {why}"
                );
                self.map.write().unwrap().remove(key);
                None
            }
        }
    }

    /// Every entry, hydrated, in key order (the deterministic base for
    /// both consumption paths).
    pub fn all_entries(&self) -> Vec<BankEntry> {
        let mut keys: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        keys.sort();
        keys.iter().filter_map(|k| self.hydrate(k)).collect()
    }

    /// Bank elites for exactly `op`, best first (rank desc, key asc) —
    /// the warm-start seeding order.
    pub fn entries_for_op(&self, op: &str) -> Vec<BankEntry> {
        let mut hits: Vec<BankEntry> =
            self.all_entries().into_iter().filter(|e| e.op == op).collect();
        hits.sort_by(|a, b| {
            b.rank.total_cmp(&a.rank).then_with(|| a.key.cmp(&b.key))
        });
        hits
    }

    /// Deterministic retriever: rank every entry by affinity to the
    /// asking cell — same-op (3) > same-family (2) > same-category (1)
    /// — then ArgSpec-shape similarity, tie-broken by goal-adjusted
    /// fitness (rank) then key; return the top `k`. Counts a hit when
    /// anything comes back (surfaced by `report bank` / end-of-run
    /// summaries).
    pub fn retrieve(
        &self,
        op: &str,
        family: &str,
        category: u8,
        shape: &[usize],
        k: usize,
    ) -> Vec<BankEntry> {
        let mut scored: Vec<(u64, u64, BankEntry)> = self
            .all_entries()
            .into_iter()
            .map(|e| {
                let affinity = if e.op == op {
                    3
                } else if e.family == family {
                    2
                } else if e.category == category {
                    1
                } else {
                    0
                };
                let sim = shape_similarity(&e.shape, shape);
                (affinity, sim, e)
            })
            .collect();
        scored.sort_by(|a, b| {
            b.0.cmp(&a.0)
                .then_with(|| b.1.cmp(&a.1))
                .then_with(|| b.2.rank.total_cmp(&a.2.rank))
                .then_with(|| a.2.key.cmp(&b.2.key))
        });
        let out: Vec<BankEntry> = scored.into_iter().take(k).map(|(_, _, e)| e).collect();
        match out.is_empty() {
            false => self.retrieval_hits.fetch_add(1, Ordering::Relaxed),
            true => self.retrieval_misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Journal lines for every unique entry, key order — what the
    /// coordinator ships to workers (`GET /bank`) and what `bank
    /// export` prints. Re-serialized from parsed entries, so the
    /// output is compacted and canonical regardless of journal state.
    pub fn export_lines(&self) -> Vec<String> {
        self.all_entries()
            .iter()
            .map(|e| e.to_json().to_string())
            .collect()
    }
}

/// Positional shape affinity: 2 per matching dim (same position), +1
/// for matching rank. Integer on purpose — float similarity invites
/// platform-dependent ordering.
fn shape_similarity(a: &[usize], b: &[usize]) -> u64 {
    let matching = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count() as u64;
    let same_rank = (a.len() == b.len()) as u64;
    2 * matching + same_rank
}

/// The `## PRIOR ELITES` few-shot section body: one block per
/// retrieved elite, in retrieval order. Deterministic fixed-format
/// text — it feeds the request hash.
pub fn render_refs(entries: &[BankEntry]) -> String {
    let mut s = String::new();
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            s.push('\n');
        }
        s.push_str(&format!(
            "### elite {} | op {} | speedup {:.3}x | goal {}\n",
            i + 1,
            e.op,
            e.speedup,
            e.goal
        ));
        if !e.insight.is_empty() {
            s.push_str(&format!("// insight: {}\n", e.insight));
        }
        if !e.profile.is_empty() {
            s.push_str(&format!("// profile: {}\n", e.profile));
        }
        s.push_str(&e.src);
        if !e.src.ends_with('\n') {
            s.push('\n');
        }
    }
    s
}

// ---------------------------------------------------------------------
// Offline maintenance (`bank stats` / `bank gc` / `bank top`)

/// Aggregate numbers for `bank stats` / `report bank`.
#[derive(Debug, Clone, Default)]
pub struct BankStats {
    pub entries: usize,
    pub journal_lines: usize,
    /// Lines beyond the first occurrence of their key (what `gc`
    /// would drop).
    pub dup_lines: usize,
    pub file_bytes: u64,
    /// (op, entries, best rank, best speedup), op order.
    pub per_op: Vec<(String, usize, f64, f64)>,
    /// (goal label, entries), label order.
    pub per_goal: Vec<(String, usize)>,
    /// Sidecar index health (`None` when no sidecar exists).
    pub index: Option<index::IndexHealth>,
}

/// Read-only aggregate view of a bank journal on disk.
pub fn stats(path: impl AsRef<Path>) -> Result<BankStats> {
    let path = path.as_ref();
    let mut s = BankStats::default();
    if !path.exists() {
        return Ok(s);
    }
    s.file_bytes = std::fs::metadata(path)?.len();
    let f = std::fs::File::open(path).context("opening bank")?;
    let mut seen = std::collections::HashSet::new();
    let mut per_op: HashMap<String, (usize, f64, f64)> = HashMap::new();
    let mut per_goal: HashMap<String, usize> = HashMap::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        s.journal_lines += 1;
        let Ok(e) = parse_entry(&line) else { continue };
        if !seen.insert(e.key.clone()) {
            s.dup_lines += 1;
            continue;
        }
        s.entries += 1;
        let slot = per_op.entry(e.op.clone()).or_insert((0, f64::NEG_INFINITY, 0.0));
        slot.0 += 1;
        if e.rank > slot.1 {
            slot.1 = e.rank;
            slot.2 = e.speedup;
        }
        *per_goal.entry(e.goal.clone()).or_insert(0) += 1;
    }
    s.per_op = per_op
        .into_iter()
        .map(|(op, (n, rank, speedup))| (op, n, rank, speedup))
        .collect();
    s.per_op.sort_by(|a, b| a.0.cmp(&b.0));
    s.per_goal = per_goal.into_iter().collect();
    s.per_goal.sort_by(|a, b| a.0.cmp(&b.0));
    s.index = index::health(path);
    Ok(s)
}

/// Compact the journal in place: one line per unique key (first
/// occurrence wins), corrupt lines dropped. Returns
/// (bytes_before, bytes_after).
pub fn gc(path: impl AsRef<Path>) -> Result<(u64, u64)> {
    let path = path.as_ref();
    if !path.exists() {
        return Err(eyre!("no bank at {}", path.display()));
    }
    let before = std::fs::metadata(path)?.len();
    let f = std::fs::File::open(path).context("opening bank")?;
    let mut seen = std::collections::HashSet::new();
    let mut kept: Vec<String> = Vec::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(e) = parse_entry(&line) {
            if seen.insert(e.key) {
                kept.push(line);
            }
        }
    }
    let tmp = path.with_extension("jsonl.gc.tmp");
    {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(&tmp).context("creating bank gc temp file")?,
        );
        for line in &kept {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path).context("replacing bank journal")?;
    // The sidecar indexed the pre-compaction journal; drop it so the
    // next open rebuilds from the compacted bytes.
    index::delete_sidecar(path);
    let after = std::fs::metadata(path)?.len();
    Ok((before, after))
}

/// Human-readable `bank stats` report.
pub fn stats_report(s: &BankStats) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "bank: {} entries across {} ops ({} journal lines, {} duplicate, {} bytes)\n",
        s.entries,
        s.per_op.len(),
        s.journal_lines,
        s.dup_lines,
        s.file_bytes
    ));
    if let Some(h) = &s.index {
        out.push_str(&format!(
            "index: {} indexed opens, {} scanned, {} rebuilds\n",
            h.indexed_opens, h.scanned_opens, h.rebuilds
        ));
    }
    if !s.per_goal.is_empty() {
        let goals: Vec<String> = s
            .per_goal
            .iter()
            .map(|(g, n)| format!("{g}={n}"))
            .collect();
        out.push_str(&format!("goals: {}\n", goals.join(" ")));
    }
    for (op, n, rank, speedup) in &s.per_op {
        out.push_str(&format!(
            "  {op}: {n} elites, best rank {rank:.4} (speedup {speedup:.3}x)\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("evo_bank_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn entry(op: &str, src: &str, rank: f64) -> BankEntry {
        BankEntry {
            key: entry_key(op, src),
            op: op.into(),
            family: "matmul".into(),
            category: 1,
            goal: "speedup".into(),
            src: src.into(),
            speedup: rank,
            rank,
            shape: vec![64, 64],
            profile: String::new(),
            provider: "sim".into(),
            model: "sim-balanced".into(),
            method: "evo_funsearch".into(),
            route: String::new(),
            insight: "tile harder".into(),
        }
    }

    #[test]
    fn entry_roundtrips_including_nonfinite() {
        let mut e = entry("matmul_64", "kernel a { }", 2.5);
        e.rank = f64::INFINITY;
        e.profile = "memory bound; traffic 2.1x".into();
        e.route = "aggressive".into();
        let line = e.to_json().to_string();
        let back = parse_entry(&line).unwrap();
        assert_eq!(back.op, e.op);
        assert_eq!(back.src, e.src);
        assert_eq!(back.shape, vec![64, 64]);
        assert!(back.rank.is_infinite() && back.rank > 0.0);
        assert_eq!(back.route, "aggressive");
        assert_eq!(back.profile, "memory bound; traffic 2.1x");
        // A second print → parse cycle is a fixed point.
        assert_eq!(parse_entry(&back.to_json().to_string()).unwrap(), back);
    }

    #[test]
    fn deposits_dedup_and_survive_reopen() {
        let dir = tmpdir("dedup");
        let path = dir.join("bank.jsonl");
        {
            let bank = KernelBank::open(&path).unwrap();
            assert!(bank.deposit(entry("matmul_64", "kernel a { }", 2.0)).unwrap());
            assert!(!bank.deposit(entry("matmul_64", "kernel a { }", 2.0)).unwrap());
            assert!(bank.deposit(entry("matmul_64", "kernel b { }", 3.0)).unwrap());
            bank.flush().unwrap();
            assert_eq!(bank.len(), 2);
            assert_eq!(bank.deposits(), 2);
        }
        let bank = KernelBank::open(&path).unwrap();
        assert_eq!(bank.len(), 2);
        // Re-deposit of a journaled key is still a no-op.
        assert!(!bank.deposit(entry("matmul_64", "kernel b { }", 3.0)).unwrap());
        let best = bank.entries_for_op("matmul_64");
        assert_eq!(best.len(), 2);
        assert_eq!(best[0].src, "kernel b { }");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retrieval_ranks_op_family_category_shape_then_rank_and_key() {
        let mut e_other = entry("conv_9", "kernel o { }", 9.0);
        e_other.family = "conv".into();
        e_other.category = 4;
        e_other.shape = vec![3, 3];
        let mut e_family = entry("matmul_128", "kernel f { }", 1.1);
        e_family.shape = vec![128, 128];
        let mut e_cat = entry("gemv_64", "kernel c { }", 5.0);
        e_cat.family = "gemv".into();
        e_cat.shape = vec![64];
        let e_op_lo = entry("matmul_64", "kernel a { }", 1.5);
        let e_op_hi = entry("matmul_64", "kernel b { }", 2.5);
        let lines: Vec<String> = [&e_other, &e_family, &e_cat, &e_op_lo, &e_op_hi]
            .iter()
            .map(|e| e.to_json().to_string())
            .collect();
        let bank = KernelBank::from_lines(&lines);
        let got = bank.retrieve("matmul_64", "matmul", 1, &[64, 64], 4);
        let ops: Vec<&str> = got.iter().map(|e| e.op.as_str()).collect();
        // same-op first (rank desc), then same-family, then same-category;
        // the unrelated high-rank conv entry loses to all of them.
        assert_eq!(ops, vec!["matmul_64", "matmul_64", "matmul_128", "gemv_64"]);
        assert_eq!(got[0].src, "kernel b { }");
        assert_eq!(got[1].src, "kernel a { }");
        let (hits, misses) = bank.retrieval_counts();
        assert_eq!((hits, misses), (1, 0));
        // Empty bank: a miss, and an empty section.
        let empty = KernelBank::from_lines::<String>(&[]);
        assert!(empty.retrieve("matmul_64", "matmul", 1, &[64, 64], 4).is_empty());
        assert_eq!(empty.retrieval_counts(), (0, 1));
    }

    #[test]
    fn retrieval_is_deterministic_across_insertion_order() {
        let a = entry("matmul_64", "kernel a { }", 2.0);
        let b = entry("matmul_64", "kernel b { }", 2.0); // equal rank: key breaks the tie
        let fwd = KernelBank::from_lines(&[a.to_json().to_string(), b.to_json().to_string()]);
        let rev = KernelBank::from_lines(&[b.to_json().to_string(), a.to_json().to_string()]);
        let f: Vec<String> = fwd.retrieve("matmul_64", "matmul", 1, &[64, 64], 2)
            .iter().map(|e| e.key.clone()).collect();
        let r: Vec<String> = rev.retrieve("matmul_64", "matmul", 1, &[64, 64], 2)
            .iter().map(|e| e.key.clone()).collect();
        assert_eq!(f, r);
        assert_eq!(render_refs(&fwd.retrieve("matmul_64", "matmul", 1, &[64, 64], 2)),
                   render_refs(&rev.retrieve("matmul_64", "matmul", 1, &[64, 64], 2)));
    }

    #[test]
    fn render_refs_is_fixed_format() {
        let mut e = entry("matmul_64", "kernel a { }", 2.0);
        e.profile = "memory bound".into();
        let text = render_refs(&[e.clone()]);
        assert!(text.starts_with("### elite 1 | op matmul_64 | speedup 2.000x | goal speedup\n"));
        assert!(text.contains("// insight: tile harder\n"));
        assert!(text.contains("// profile: memory bound\n"));
        assert!(text.ends_with("kernel a { }\n"));
        assert_eq!(render_refs(&[]), "");
        // Two elites are newline-separated blocks in retrieval order.
        let two = render_refs(&[e.clone(), entry("matmul_64", "kernel b { }", 1.0)]);
        assert!(two.contains("\n### elite 2 |"));
    }

    #[test]
    fn stats_gc_and_export_roundtrip() {
        let dir = tmpdir("gc");
        let path = dir.join("bank.jsonl");
        let e1 = entry("matmul_64", "kernel a { }", 2.0);
        let mut e2 = entry("softmax_64", "kernel s { }", 1.2);
        e2.family = "softmax".into();
        e2.goal = "balanced".into();
        // Write e1 twice (duplicate line) plus one corrupt line.
        let mut raw = String::new();
        raw.push_str(&e1.to_json().to_string());
        raw.push('\n');
        raw.push_str(&e1.to_json().to_string());
        raw.push('\n');
        raw.push_str("{\"type\":\"elite\",\"key\":\"truncated");
        raw.push('\n');
        raw.push_str(&e2.to_json().to_string());
        raw.push('\n');
        std::fs::write(&path, &raw).unwrap();
        let s = stats(&path).unwrap();
        assert_eq!(s.entries, 2);
        assert_eq!(s.dup_lines, 1);
        assert_eq!(s.journal_lines, 4);
        assert_eq!(s.per_op.len(), 2);
        assert_eq!(s.per_goal, vec![("balanced".to_string(), 1), ("speedup".to_string(), 1)]);
        let report = stats_report(&s);
        assert!(report.contains("2 entries across 2 ops"));
        assert!(report.contains("balanced=1"));
        let (before, after) = gc(&path).unwrap();
        assert!(after < before);
        let s2 = stats(&path).unwrap();
        assert_eq!(s2.entries, 2);
        assert_eq!(s2.dup_lines, 0);
        // Export from a reopened bank is canonical and importable.
        let bank = KernelBank::open(&path).unwrap();
        let lines = bank.export_lines();
        assert_eq!(lines.len(), 2);
        let other = KernelBank::open(dir.join("other.jsonl")).unwrap();
        for line in &lines {
            assert!(other.ingest_line(line).unwrap());
        }
        for line in &lines {
            assert!(!other.ingest_line(line).unwrap());
        }
        other.flush().unwrap();
        assert_eq!(other.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn readonly_snapshots_ignore_deposits() {
        let dir = tmpdir("ro");
        let path = dir.join("bank.jsonl");
        let bank = KernelBank::open(&path).unwrap();
        bank.deposit(entry("matmul_64", "kernel a { }", 2.0)).unwrap();
        bank.flush().unwrap();
        let before = std::fs::read(&path).unwrap();
        let snap = KernelBank::load(&path).unwrap();
        assert_eq!(snap.len(), 1);
        assert!(!snap.deposit(entry("matmul_64", "kernel b { }", 3.0)).unwrap());
        snap.flush().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), before);
        assert_eq!(snap.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shape_similarity_prefers_positional_matches() {
        assert_eq!(shape_similarity(&[64, 64], &[64, 64]), 5);
        assert_eq!(shape_similarity(&[64, 32], &[64, 64]), 3);
        assert_eq!(shape_similarity(&[64], &[64, 64]), 2);
        assert_eq!(shape_similarity(&[], &[]), 1);
        assert_eq!(shape_similarity(&[3], &[64, 64]), 0);
    }
}
