//! The paper's two-stage evaluation pipeline (§4.3), fronted by an
//! optional stage-0 static guard (DESIGN.md §11):
//!
//! 0. **Stage-0 validity guard** — [`crate::guard`]: pure static
//!    shape/rank/limit checks over the candidate text, run *before*
//!    any compile when a repair policy is active. Rejections carry
//!    structured diagnostics, are journaled in the persistent store
//!    under a guard-namespaced key ([`crate::store::EvalKey::guarded`])
//!    and never reach the compile gate or the PJRT runtime pool.
//! 1. **Compilation Check** — KernelScript front-end + lowering against
//!    the artifact manifest (real lexing/parsing/resource validation).
//! 2. **Functional Testing** — five random test cases executed on the
//!    PJRT runtime: the candidate's semantics artifact vs the `ref`
//!    oracle artifact, compared under the op's tolerances. The five
//!    cases are generated once and submitted as one batched
//!    ref/candidate pair request ([`Runtime::execute_pairs`]) — one
//!    channel round-trip per executor shard instead of ten blocking
//!    `execute()` calls. Verdicts are memoized per (op, variant):
//!    semantics are deterministic, so one live verification covers
//!    every candidate sharing the variant (the numerics still come
//!    from real HLO execution).
//! 3. **Performance measurement** — the analytical RTX-4090 price of
//!    the candidate schedule, observed through the noise model as the
//!    median of 100 runs (paper: "collected ... over 100 runs").
//!
//! Two cache layers sit in front of the pipeline:
//! * in-process memos for functional verdicts (per (op, variant)) and
//!   baseline times (per op) — semantics are deterministic, so one live
//!   PJRT verification covers every candidate sharing the variant;
//! * an optional persistent [`store::EvalStore`](crate::store), keyed
//!   by the candidate's canonical printed form, which deduplicates
//!   whole evaluations across methods, seeds and process restarts.
//!   Replay from the store is bit-identical to a cold evaluation: the
//!   stored record holds only the deterministic pipeline results, and
//!   measurement noise is re-drawn from the caller's RNG stream.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::costmodel::{self, price, price_baseline, price_pytorch, Gpu, Timing};
use crate::ir::{self, ExecutionPlan};
use crate::runtime::{Runtime, TensorValue};
use crate::store::{EvalKey, EvalStore, KeyInterner, Keyed, StoredEval, StoredOutcome};
use crate::tasks::gen::{gen_case, NUM_TEST_CASES};
use crate::tasks::{OpTask, TaskRegistry};
use crate::util::Rng;
use crate::{dsl, Result};

/// The full stage-2 input batch for one op: all `NUM_TEST_CASES`
/// seeded test cases, `Arc`-shared so the ref and candidate executions
/// (and any benchmark mirroring them) reuse the same buffers.
pub fn functional_case_batch(task: &OpTask) -> Arc<Vec<Vec<TensorValue>>> {
    Arc::new(
        (0..NUM_TEST_CASES)
            .map(|case| {
                gen_case(task, case)
                    .into_iter()
                    .zip(&task.args)
                    .map(|(data, spec)| TensorValue::new(spec.shape.clone(), data))
                    .collect()
            })
            .collect(),
    )
}

/// Result of stage-2 functional testing for one (op, variant).
#[derive(Debug, Clone, Copy)]
pub struct FuncVerdict {
    pub pass: bool,
    pub max_abs_diff: f64,
}

/// Performance numbers for a candidate that cleared both gates.
#[derive(Debug, Clone)]
pub struct EvalSuccess {
    /// Measured time (median-of-100 noise model), seconds.
    pub time: f64,
    /// Measured speedup vs the op's baseline kernel (what the search
    /// selects on — subject to the paper's §A.7 measurement noise).
    pub speedup: f64,
    /// Measured speedup vs the modeled PyTorch implementation.
    pub pytorch_speedup: f64,
    /// Noise-free speedup vs baseline (what the final report cites —
    /// the paper re-times the chosen kernel over 100 runs).
    pub true_speedup: f64,
    /// Noise-free speedup vs PyTorch.
    pub true_pytorch_speedup: f64,
    /// Noise-free profile (occupancy, roofline bound, traffic) — the
    /// feedback the traverse layer can surface in prompts.
    pub timing: Timing,
}

/// Outcome of one candidate evaluation.
#[derive(Debug, Clone)]
pub enum EvalOutcome {
    /// Stage-0 rejection by the static validity guard, before any
    /// compile — the structured diagnostics the repair loop saw.
    GuardReject { diagnostics: Vec<crate::guard::GuardDiagnostic> },
    /// Stage-1 rejection (syntax / validation / resolution).
    CompileFail { error: String },
    /// Stage-2 rejection: compiled but produced wrong numerics.
    FunctionalFail { max_abs_diff: f64 },
    /// PJRT-level failure (treated as functional failure in metrics).
    RuntimeFail { error: String },
    Ok(EvalSuccess),
}

impl EvalOutcome {
    pub fn compiled(&self) -> bool {
        !matches!(
            self,
            EvalOutcome::CompileFail { .. } | EvalOutcome::GuardReject { .. }
        )
    }

    pub fn correct(&self) -> bool {
        matches!(self, EvalOutcome::Ok(_))
    }

    pub fn speedup(&self) -> Option<f64> {
        match self {
            EvalOutcome::Ok(s) => Some(s.speedup),
            _ => None,
        }
    }
}

/// Shared evaluation service (cloneable; used concurrently by the
/// campaign workers).
#[derive(Clone)]
pub struct Evaluator {
    pub registry: Arc<TaskRegistry>,
    runtime: Runtime,
    pub gpu: Gpu,
    func_memo: Arc<RwLock<HashMap<(String, String), FuncVerdict>>>,
    baseline_memo: Arc<RwLock<HashMap<String, f64>>>,
    store: Option<Arc<EvalStore>>,
    /// Memo for the raw-text → canonical-key derivation (DESIGN.md
    /// §14): shared across clones, so campaign workers dedupe the
    /// parse+print+SHA cost of re-keying unchanged populations.
    intern: Arc<KeyInterner>,
}

impl Evaluator {
    pub fn new(registry: Arc<TaskRegistry>, runtime: Runtime) -> Self {
        Self {
            registry,
            runtime,
            gpu: Gpu::rtx4090(),
            func_memo: Arc::new(RwLock::new(HashMap::new())),
            baseline_memo: Arc::new(RwLock::new(HashMap::new())),
            store: None,
            intern: Arc::new(KeyInterner::new()),
        }
    }

    /// Attach a persistent evaluation cache; every `evaluate*` call
    /// consults it before running the pipeline.
    pub fn with_store(mut self, store: Arc<EvalStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// The attached persistent cache, if any.
    pub fn store(&self) -> Option<&Arc<EvalStore>> {
        self.store.as_ref()
    }

    /// The shared canonical-key interner (bench/test introspection).
    pub fn interner(&self) -> &Arc<KeyInterner> {
        &self.intern
    }

    /// Drop the in-process memos (functional verdicts + baseline
    /// times). Test/bench hook: makes the next evaluation pay the full
    /// cold-pipeline cost even in a warm process.
    pub fn clear_memos(&self) {
        self.func_memo.write().unwrap().clear();
        self.baseline_memo.write().unwrap().clear();
    }

    /// Evaluate one candidate program (raw text, as emitted by the
    /// LLM) for `task`. `rng` drives the measurement noise only.
    pub fn evaluate(&self, src: &str, task: &OpTask, rng: &mut Rng) -> EvalOutcome {
        self.evaluate_keyed(src, task, "-", rng)
    }

    /// [`Self::evaluate`] with provenance: `model` names the LLM that
    /// emitted `src` and is journaled with any fresh cache record (it
    /// is *not* part of the lookup key — verdicts are model-free).
    pub fn evaluate_keyed(
        &self,
        src: &str,
        task: &OpTask,
        model: &str,
        rng: &mut Rng,
    ) -> EvalOutcome {
        let Some(store) = &self.store else {
            return self.evaluate_cold(src, task, rng);
        };
        // Canonical identity requires a successful parse; unparseable
        // text is a cheap deterministic rejection, not worth caching.
        // The interner memoizes the whole parse→print→SHA derivation
        // (including the exact rejection string), so re-keying an
        // unchanged population is one map probe.
        let key = match self.intern.key_for(&task.name, src) {
            Keyed::Unparseable(error) => return EvalOutcome::CompileFail { error },
            Keyed::Key(key) => key,
        };
        if let Some(stored) = store.lookup(&key) {
            return self.replay(&stored.outcome, task, rng);
        }
        // Miss: a fresh pipeline run needs the parsed spec. Re-parsing
        // here is fine — the parse is noise next to lowering + PJRT,
        // and the interner already proved the text parses.
        let spec = dsl::parse(src).expect("interner certified this text parses");
        let outcome = match ir::lower(spec, task, &self.registry) {
            Ok(plan) => self.evaluate_plan(&plan, task, rng),
            Err(e) => EvalOutcome::CompileFail { error: e.to_string() },
        };
        if let Some(stored) = Self::storable(&outcome) {
            let entry = StoredEval {
                op: task.name.clone(),
                model: model.to_string(),
                outcome: stored,
            };
            if let Err(e) = store.record(&key, entry) {
                eprintln!("warning: eval cache write failed: {e:#}");
            }
        }
        outcome
    }

    /// Stage 0: the static validity guard, as a pure function — never
    /// touches the compile gate, the runtime pool, or the cache.
    pub fn guard_check(&self, src: &str, task: &OpTask) -> crate::guard::GuardReport {
        crate::guard::check_source(src, task)
    }

    /// Finalize a stage-0 rejection: journal the verdict (under the
    /// guard-namespaced **raw-text** key — stage-0 diagnostics depend
    /// on surface features like shadowed bindings that the canonical
    /// re-print erases, so the verdict is an identity of the raw
    /// emission, and it can never shadow or be shadowed by a
    /// full-pipeline record) and return the outcome. Consumes no RNG,
    /// so replays are trivially bit-identical. Unparseable candidates
    /// are not journaled (same policy as stage-1 syntax rejections:
    /// re-rejecting them is already the cheapest path).
    pub fn reject_stage0(
        &self,
        src: &str,
        task: &OpTask,
        model: &str,
        report: &crate::guard::GuardReport,
    ) -> EvalOutcome {
        debug_assert!(!report.pass(), "reject_stage0 called with a passing report");
        if let Some(store) = &self.store {
            if matches!(self.intern.key_for(&task.name, src), Keyed::Key(_)) {
                let key = EvalKey::guarded(&task.name, src);
                if let Some(stored) = store.lookup(&key) {
                    if let StoredOutcome::GuardReject { diagnostics } = stored.outcome {
                        return EvalOutcome::GuardReject { diagnostics };
                    }
                }
                let entry = StoredEval {
                    op: task.name.clone(),
                    model: model.to_string(),
                    outcome: StoredOutcome::GuardReject {
                        diagnostics: report.diagnostics.clone(),
                    },
                };
                if let Err(e) = store.record(&key, entry) {
                    eprintln!("warning: eval cache write failed: {e:#}");
                }
            }
        }
        EvalOutcome::GuardReject { diagnostics: report.diagnostics.clone() }
    }

    /// Guard-gated evaluation: stage 0 first, stages 1–3 only when the
    /// guard passes (the `diagnose` policy's view of the pipeline).
    pub fn evaluate_guarded(
        &self,
        src: &str,
        task: &OpTask,
        model: &str,
        rng: &mut Rng,
    ) -> EvalOutcome {
        let report = self.guard_check(src, task);
        if report.pass() {
            self.evaluate_keyed(src, task, model, rng)
        } else {
            self.reject_stage0(src, task, model, &report)
        }
    }

    /// The full pipeline with no persistent-cache consultation.
    fn evaluate_cold(&self, src: &str, task: &OpTask, rng: &mut Rng) -> EvalOutcome {
        // Stage 1: compile.
        let plan = match ir::compile(src, task, &self.registry) {
            Ok(p) => p,
            Err(e) => return EvalOutcome::CompileFail { error: e.to_string() },
        };
        self.evaluate_plan(&plan, task, rng)
    }

    /// The deterministic, journal-worthy part of an outcome. Runtime
    /// (PJRT/infrastructure) failures may be transient and are never
    /// persisted.
    fn storable(outcome: &EvalOutcome) -> Option<StoredOutcome> {
        match outcome {
            EvalOutcome::GuardReject { diagnostics } => Some(StoredOutcome::GuardReject {
                diagnostics: diagnostics.clone(),
            }),
            EvalOutcome::CompileFail { error } => {
                Some(StoredOutcome::CompileFail { error: error.clone() })
            }
            EvalOutcome::FunctionalFail { max_abs_diff } => {
                Some(StoredOutcome::FunctionalFail { max_abs_diff: *max_abs_diff })
            }
            EvalOutcome::Ok(s) => Some(StoredOutcome::Ok { timing: s.timing.clone() }),
            EvalOutcome::RuntimeFail { .. } => None,
        }
    }

    /// Rebuild an [`EvalOutcome`] from a stored record. The RNG
    /// consumption mirrors the cold success path exactly (candidate
    /// measurement, then baseline measurement), so a replay is
    /// bit-identical to the evaluation it stands in for.
    fn replay(&self, stored: &StoredOutcome, task: &OpTask, rng: &mut Rng) -> EvalOutcome {
        match stored {
            StoredOutcome::GuardReject { diagnostics } => EvalOutcome::GuardReject {
                diagnostics: diagnostics.clone(),
            },
            StoredOutcome::CompileFail { error } => {
                EvalOutcome::CompileFail { error: error.clone() }
            }
            StoredOutcome::FunctionalFail { max_abs_diff } => {
                EvalOutcome::FunctionalFail { max_abs_diff: *max_abs_diff }
            }
            StoredOutcome::Ok { timing } => {
                let baseline = self.baseline_time(task);
                let measured = costmodel::measure(timing.time, 100, rng);
                let baseline_measured = costmodel::measure(baseline, 100, rng);
                let pt = price_pytorch(task, &self.gpu);
                EvalOutcome::Ok(EvalSuccess {
                    time: measured,
                    speedup: baseline_measured / measured,
                    pytorch_speedup: pt / measured,
                    true_speedup: baseline / timing.time,
                    true_pytorch_speedup: pt / timing.time,
                    timing: timing.clone(),
                })
            }
        }
    }

    /// Evaluate an already-compiled plan (stages 2–3).
    pub fn evaluate_plan(&self, plan: &ExecutionPlan, task: &OpTask, rng: &mut Rng) -> EvalOutcome {
        // Stage 2: functional testing on PJRT (memoized per variant).
        match self.functional(task, &plan.spec.semantics) {
            Ok(v) if v.pass => {}
            Ok(v) => return EvalOutcome::FunctionalFail { max_abs_diff: v.max_abs_diff },
            Err(e) => return EvalOutcome::RuntimeFail { error: e.to_string() },
        }

        // Stage 3: performance.
        let timing = price(&plan.spec.schedule, task, &self.gpu);
        let baseline = self.baseline_time(task);
        let measured = costmodel::measure(timing.time, 100, rng);
        let baseline_measured = costmodel::measure(baseline, 100, rng);
        let pt = price_pytorch(task, &self.gpu);
        EvalOutcome::Ok(EvalSuccess {
            time: measured,
            speedup: baseline_measured / measured,
            pytorch_speedup: pt / measured,
            true_speedup: baseline / timing.time,
            true_pytorch_speedup: pt / timing.time,
            timing,
        })
    }

    /// Noise-free baseline kernel time for an op (memoized).
    pub fn baseline_time(&self, task: &OpTask) -> f64 {
        if let Some(t) = self.baseline_memo.read().unwrap().get(&task.name) {
            return *t;
        }
        let t = price_baseline(task, &self.gpu).time;
        self.baseline_memo.write().unwrap().insert(task.name.clone(), t);
        t
    }

    /// Stage-2 functional verdict for (op, variant), via live PJRT
    /// execution of the AOT artifacts on five seeded test cases.
    pub fn functional(&self, task: &OpTask, variant: &str) -> Result<FuncVerdict> {
        let key = (task.name.clone(), variant.to_string());
        if let Some(v) = self.func_memo.read().unwrap().get(&key) {
            return Ok(*v);
        }
        let verdict = self.functional_uncached(task, variant)?;
        self.func_memo.write().unwrap().insert(key, verdict);
        Ok(verdict)
    }

    fn functional_uncached(&self, task: &OpTask, variant: &str) -> Result<FuncVerdict> {
        let ref_path = self
            .registry
            .artifact_path(task, "ref")
            .ok_or_else(|| crate::eyre!("{}: missing ref artifact", task.name))?;
        let var_path = self
            .registry
            .artifact_path(task, variant)
            .ok_or_else(|| crate::eyre!("{}: missing {variant} artifact", task.name))?;

        // Each test case is generated once and shared (`Arc`) between
        // the ref and candidate batches — no per-case input cloning,
        // and the whole verdict costs one channel round-trip per shard
        // instead of 2 x NUM_TEST_CASES blocking `execute()` calls.
        let (wants, gots) =
            self.runtime.execute_pairs(ref_path, var_path, functional_case_batch(task))?;

        // Cases are compared in order and scanning stops at the first
        // failing case, so `max_abs_diff` is identical to what the old
        // sequential early-exit loop reported.
        let mut max_diff = 0.0f64;
        let mut pass = true;
        for (want, got) in wants.iter().zip(&gots) {
            if want.len() != got.len() {
                return Ok(FuncVerdict { pass: false, max_abs_diff: f64::INFINITY });
            }
            for (w, g) in want.iter().zip(got) {
                let diff = (*w as f64 - *g as f64).abs();
                max_diff = max_diff.max(diff);
                if diff > task.atol + task.rtol * (*w as f64).abs() {
                    pass = false;
                }
            }
            if !pass {
                break; // first failing case settles the verdict
            }
        }
        Ok(FuncVerdict { pass, max_abs_diff: max_diff })
    }

    /// Runtime execution counters (for EXPERIMENTS.md §Perf), summed
    /// across all executor shards.
    pub fn runtime_stats(&self) -> Result<crate::runtime::RuntimeStats> {
        self.runtime.stats()
    }

    /// Number of PJRT executor shards backing this evaluator.
    pub fn runtime_shards(&self) -> usize {
        self.runtime.shard_count()
    }
}
