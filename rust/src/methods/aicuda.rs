//! AI CUDA Engineer replication (paper §A.8, faithfully re-replicated):
//! the four-stage pipeline Convert → Translate → Optimize → Compose
//! with the paper's budget split (4 LLMs × 10 generations + 5 RAG
//! proposals = 45; we spend the same 45 sequentially since the model
//! under test is fixed per run, like the paper's replication).
//!
//! * **Convert**: produce an initial kernel from the task description;
//!   retry limit 10; if nothing compiles the whole op is a failure
//!   (§A.8.1 "If the LLM fails to convert the code after 10 attempts,
//!   the process terminates").
//! * **Translate**: one restyling pass; failures do **not** halt the
//!   pipeline (§A.8.1).
//! * **Optimize**: the heavyweight loop — five correct kernels in the
//!   prompt, ensemble prompting, profiling feedback, verbose style
//!   (this is where the Figure-4 token cost comes from).
//! * **Compose**: 5 RAG-based proposals seeded with the top-5 kernels
//!   of *other* ops from the shared archive (family similarity as the
//!   embedding-search stand-in).

use crate::population::Elite;
use crate::traverse::{GuidanceConfig, PromptStyle};

use super::common::{KernelRunRecord, RunCtx, Session};
use super::Method;

pub struct AiCudaEngineer;

impl AiCudaEngineer {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        AiCudaEngineer
    }
}

const CONVERT: &str = "Convert the high-level operation description into an initial CUDA \
kernel implementation. Correctness first; a plain schedule is acceptable.";
const TRANSLATE: &str = "Translate the kernel into an alternative implementation style while \
preserving semantics.";
const OPTIMIZE: &str = "Optimize the kernel aggressively. Use the profiling data and the \
correct kernels above; consider the ensemble of optimization directions and commit to the \
fastest.";
const COMPOSE: &str = "The kernels above come from related operations in the archive. \
Compose their optimization strategies into this operation's kernel.";

const CONVERT_RETRIES: usize = 10;
const COMPOSE_TRIALS: usize = 5;

impl Method for AiCudaEngineer {
    fn name(&self) -> String {
        "AI CUDA Engineer".into()
    }

    fn run(&self, ctx: &RunCtx) -> crate::Result<KernelRunRecord> {
        let name = self.name();
        let mut session = Session::new(ctx, &name);
        let mut pop = Elite::new(5); // "providing five correct kernels"

        // NOTE: unlike the evolutionary methods, AI CUDA Engineer does
        // not start from the dataset's baseline kernel — Convert must
        // produce it (that is the stage's purpose).
        let convert_cfg = GuidanceConfig {
            n_history: 0,
            n_insights: 0,
            profiling: false,
            style: PromptStyle::Verbose,
        };

        // --- Stage 1: Convert ------------------------------------------
        let mut converted = false;
        for _ in 0..CONVERT_RETRIES {
            match session.trial(&convert_cfg, &mut pop, CONVERT, None, None)? {
                Some(cand) if cand.compiled => {
                    converted = true;
                    break;
                }
                Some(_) => continue,
                None => break,
            }
        }
        if !converted {
            // Terminal conversion failure: the op is classified failed.
            return Ok(session.finish(&name));
        }

        // --- Stage 2: Translate ------------------------------------------
        // One pass; failure does not halt.
        let _ = session.trial(&convert_cfg, &mut pop, TRANSLATE, None, None)?;

        // --- Stage 3: Optimize ---------------------------------------------
        let optimize_cfg = GuidanceConfig::aicuda();
        while session.budget_left() > COMPOSE_TRIALS {
            if session
                .trial(&optimize_cfg, &mut pop, OPTIMIZE, None, None)?
                .is_none()
            {
                break;
            }
        }

        // --- Stage 4: Compose (RAG) ------------------------------------------
        let rag = ctx.archive.similar(&ctx.task.name, &ctx.task.family, 5);
        let rag_cands: Vec<crate::population::Candidate> = rag
            .into_iter()
            .map(|e| crate::population::Candidate {
                src: e.src,
                spec: None,
                compiled: true,
                correct: true,
                speedup: e.speedup,
                pytorch_speedup: 0.0,
                true_speedup: e.speedup,
                true_pytorch_speedup: 0.0,
                insight: None,
                trial: 0,
            })
            .collect();
        for _ in 0..COMPOSE_TRIALS {
            let history = if rag_cands.is_empty() {
                None // empty archive: fall back to own elites
            } else {
                Some(rag_cands.clone())
            };
            if session
                .trial(&optimize_cfg, &mut pop, COMPOSE, None, history)?
                .is_none()
            {
                break;
            }
        }
        Ok(session.finish(&name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::{Archive, ArchiveEntry};
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    fn eval() -> Evaluator {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        Evaluator::new(reg, Runtime::new().unwrap())
    }

    #[test]
    fn pipeline_spends_budget_and_is_token_heavy() {
        let evaluator = eval();
        let task = evaluator.registry.get("matmul_32").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        archive.record(ArchiveEntry {
            op: "matmul_64".into(),
            family: "matmul".into(),
            src: crate::dsl::print(&crate::dsl::KernelSpec::baseline("matmul_64")),
            speedup: 2.0,
        });
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 4,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
        };
        let rec = AiCudaEngineer::new().run(&ctx).unwrap();
        assert!(rec.trials <= 45);
        assert!(rec.trials >= 40, "{}", rec.trials);
        // Verbose prompting must cost notably more than a Free run.
        let free_ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 4,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
        };
        let free = crate::methods::EvoEngineer::new(crate::methods::EvoVariant::Free)
            .run(&free_ctx)
            .unwrap();
        assert!(
            rec.prompt_tokens > 2 * free.prompt_tokens,
            "aicuda={} free={}",
            rec.prompt_tokens,
            free.prompt_tokens
        );
    }
}
