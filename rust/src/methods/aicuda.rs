//! AI CUDA Engineer replication (paper §A.8, faithfully re-replicated):
//! the four-stage pipeline Convert → Translate → Optimize → Compose
//! with the paper's budget split (4 LLMs × 10 generations + 5 RAG
//! proposals = 45; we spend the same 45 sequentially since the model
//! under test is fixed per run, like the paper's replication).
//!
//! * **Convert**: produce an initial kernel from the task description;
//!   retry limit 10; if nothing compiles the whole op is a failure
//!   (§A.8.1 "If the LLM fails to convert the code after 10 attempts,
//!   the process terminates").
//! * **Translate**: one restyling pass; failures do **not** halt the
//!   pipeline (§A.8.1).
//! * **Optimize**: the heavyweight loop — five correct kernels in the
//!   prompt, ensemble prompting, profiling feedback, verbose style
//!   (this is where the Figure-4 token cost comes from).
//! * **Compose**: 5 RAG-based proposals seeded with the top-5 kernels
//!   of *other* ops from the shared archive (family similarity as the
//!   embedding-search stand-in).

use crate::population::{Candidate, Elite, Population};
use crate::traverse::{GuidanceConfig, PromptStyle};

use super::common::{RunCtx, Session};
use super::engine::{GenerateStep, MethodState, Step};
use super::Method;

pub struct AiCudaEngineer;

impl AiCudaEngineer {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        AiCudaEngineer
    }
}

const CONVERT: &str = "Convert the high-level operation description into an initial CUDA \
kernel implementation. Correctness first; a plain schedule is acceptable.";
const TRANSLATE: &str = "Translate the kernel into an alternative implementation style while \
preserving semantics.";
const OPTIMIZE: &str = "Optimize the kernel aggressively. Use the profiling data and the \
correct kernels above; consider the ensemble of optimization directions and commit to the \
fastest.";
const COMPOSE: &str = "The kernels above come from related operations in the archive. \
Compose their optimization strategies into this operation's kernel.";

const CONVERT_RETRIES: usize = 10;
const COMPOSE_TRIALS: usize = 5;

/// Convert/Translate prompting: task description only, verbose style.
///
/// NOTE: unlike the evolutionary methods, AI CUDA Engineer does not
/// start from the dataset's baseline kernel — Convert must produce it
/// (that is the stage's purpose), so the state machine never yields a
/// bootstrap `Evaluate` step.
fn convert_cfg() -> GuidanceConfig {
    GuidanceConfig {
        n_history: 0,
        n_insights: 0,
        profiling: false,
        style: PromptStyle::Verbose,
    }
}

enum Phase {
    /// Stage 1: up to [`CONVERT_RETRIES`] attempts until one compiles;
    /// exhausting them classifies the whole op as failed (§A.8.1).
    Convert { attempts: usize },
    /// Stage 2: one restyling pass; failure does not halt.
    Translate,
    /// Stage 3: the heavyweight loop, until only the Compose reserve
    /// of the budget remains.
    Optimize,
    /// Stage 4: RAG proposals seeded from the shared archive, captured
    /// once at phase entry (same timing as the pre-redesign loop, so
    /// the prompts — and hence transcript coverage — are unchanged).
    Compose { left: usize, rag: Vec<Candidate> },
}

struct AiCudaState {
    phase: Phase,
}

impl MethodState for AiCudaState {
    fn next(&mut self, session: &Session) -> Step {
        if session.budget_left() == 0 {
            return Step::Done;
        }
        loop {
            // Phase transitions are decided from a read-only view and
            // applied with no match borrow outstanding.
            let transition = match &self.phase {
                // The previous Convert attempt's outcome decides the
                // transition (this is why Convert is unpredictable for
                // `peek`).
                Phase::Convert { attempts }
                    if *attempts > 0
                        && session.last().map(|c| c.compiled).unwrap_or(false) =>
                {
                    Some(Phase::Translate)
                }
                Phase::Optimize if session.budget_left() <= COMPOSE_TRIALS => {
                    let ctx = session.ctx;
                    let rag: Vec<Candidate> = ctx
                        .archive
                        .similar(&ctx.task.name, &ctx.task.family, 5)
                        .into_iter()
                        .map(|e| Candidate {
                            src: e.src,
                            spec: None,
                            compiled: true,
                            correct: true,
                            speedup: e.speedup,
                            pytorch_speedup: 0.0,
                            true_speedup: e.speedup,
                            true_pytorch_speedup: 0.0,
                            insight: None,
                            trial: 0,
                        })
                        .collect();
                    Some(Phase::Compose { left: COMPOSE_TRIALS, rag })
                }
                _ => None,
            };
            if let Some(phase) = transition {
                self.phase = phase;
                continue;
            }
            match &mut self.phase {
                Phase::Convert { attempts } => {
                    if *attempts >= CONVERT_RETRIES {
                        // Terminal conversion failure: op classified failed.
                        return Step::Done;
                    }
                    *attempts += 1;
                    return Step::Generate(GenerateStep::new(convert_cfg(), CONVERT));
                }
                Phase::Translate => {
                    self.phase = Phase::Optimize;
                    return Step::Generate(GenerateStep::new(convert_cfg(), TRANSLATE));
                }
                Phase::Optimize => {
                    return Step::Generate(GenerateStep::new(GuidanceConfig::aicuda(), OPTIMIZE));
                }
                Phase::Compose { left, rag } => {
                    if *left == 0 {
                        return Step::Done;
                    }
                    *left -= 1;
                    let history = if rag.is_empty() {
                        None // empty archive: fall back to own elites
                    } else {
                        Some(rag.clone())
                    };
                    return Step::Generate(
                        GenerateStep::new(GuidanceConfig::aicuda(), COMPOSE)
                            .with_history(history),
                    );
                }
            }
        }
    }

    fn peek(&self, session: &Session, n: usize) -> Vec<GenerateStep> {
        match &self.phase {
            // Convert transitions on the pending outcome — unpredictable.
            Phase::Convert { .. } => Vec::new(),
            // After Translate yields, the phase is already Optimize, so
            // this arm covers both the translate→optimize seam and the
            // optimize steady state.
            Phase::Translate | Phase::Optimize => (0..n)
                .filter(|j| session.budget_left() > COMPOSE_TRIALS + 1 + j)
                .map(|_| GenerateStep::new(GuidanceConfig::aicuda(), OPTIMIZE))
                .collect(),
            Phase::Compose { left, rag } => {
                let history = if rag.is_empty() { None } else { Some(rag.clone()) };
                (0..n.min(*left))
                    .map(|_| {
                        GenerateStep::new(GuidanceConfig::aicuda(), COMPOSE)
                            .with_history(history.clone())
                    })
                    .collect()
            }
        }
    }
}

impl Method for AiCudaEngineer {
    fn name(&self) -> String {
        "AI CUDA Engineer".into()
    }

    fn start(&self, _ctx: &RunCtx) -> (Box<dyn Population>, Box<dyn MethodState>) {
        // "providing five correct kernels"
        (Box::new(Elite::new(5)), Box::new(AiCudaState { phase: Phase::Convert { attempts: 0 } }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::{Archive, ArchiveEntry};
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    fn eval() -> Evaluator {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        Evaluator::new(reg, Runtime::new().unwrap())
    }

    #[test]
    fn pipeline_spends_budget_and_is_token_heavy() {
        let evaluator = eval();
        let task = evaluator.registry.get("matmul_32").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        archive.record(ArchiveEntry {
            op: "matmul_64".into(),
            family: "matmul".into(),
            src: crate::dsl::print(&crate::dsl::KernelSpec::baseline("matmul_64")),
            speedup: 2.0,
            rank: 2.0,
        });
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 4,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = AiCudaEngineer::new().run(&ctx).unwrap();
        assert!(rec.trials <= 45);
        assert!(rec.trials >= 40, "{}", rec.trials);
        // Verbose prompting must cost notably more than a Free run.
        let free_ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 4,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let free = crate::methods::EvoEngineer::new(crate::methods::EvoVariant::Free)
            .run(&free_ctx)
            .unwrap();
        assert!(
            rec.prompt_tokens > 2 * free.prompt_tokens,
            "aicuda={} free={}",
            rec.prompt_tokens,
            free.prompt_tokens
        );
    }
}
