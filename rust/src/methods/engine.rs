//! The event-driven trial engine (DESIGN.md §13).
//!
//! Pre-redesign, `Method::run` was a blocking black box: 45 trials of
//! generate → guard/repair → evaluate hidden behind one call, with no
//! live progress, no per-trial telemetry, and nothing to resume below
//! cell granularity. This module inverts that control flow:
//!
//! * Each method is a **resumable state machine** ([`MethodState`]):
//!   `next(&mut self, &Session) -> Step` decides the next [`Step`] —
//!   [`Step::Evaluate`] (seed a known kernel, no budget),
//!   [`Step::Generate`] (one budget-consuming trial), or
//!   [`Step::Done`].
//! * [`drive`] owns the [`Session`] and the generate → guard/repair →
//!   evaluate sequencing, and emits structured
//!   [`TrialEvent`]s through every configured [`EventSink`]. Three
//!   sinks ship: [`ProgressSink`] (stderr progress/ETA),
//!   [`JournalSink`] (the append-only `events.jsonl`,
//!   [`crate::store::events`]), and [`MetricsSink`] (an in-memory
//!   [`EventStats`](crate::metrics::EventStats) accumulator).
//! * Because the engine — not the method — owns the sequencing, it can
//!   **pipeline generation against evaluation**: with
//!   [`EngineOpts::prefetch`] > 0, a pool of worker threads runs
//!   provider calls for *speculatively assembled* future trials while
//!   the current candidate is being guarded/compiled/benchmarked, so
//!   HTTP-provider latency no longer serializes with compile+bench.
//!
//! **Byte-identity contract.** Every RNG stream is label-derived
//! (`trial/{i}`, `llm/{i}`, `repair/{i}/{a}`, `eval/{i}`) from the
//! session seed, and the engine performs the derivations in exactly
//! the order the pre-redesign `Session::trial` did, so records are
//! byte-identical to the monolithic implementation for the same seeds
//! (proven against a verbatim legacy reimplementation in
//! `tests/trial_engine.rs`). Speculative prefetch preserves the
//! contract by *validation*: the true request is always re-assembled
//! from the real population state, and a speculative response is used
//! only when its request hash matches — a mis-speculation costs a
//! wasted provider call, never correctness. Token accounting counts
//! only responses actually consumed.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::costmodel::price;
use crate::evals::EvalOutcome;
use crate::feedback::{Goal, Objective, ProfileReport};
use crate::llm::{bandit, Bandit, GenerationRequest, GenerationResponse};
use crate::population::{Candidate, Population};
use crate::store::events::{EventJournal, TrialEvent, TrialEventKind};
use crate::store::sha256_hex;
use crate::traverse::prompt::{profiling_line, render};
use crate::traverse::{Guidance, GuidanceConfig, InsightRecord};
use crate::util::Rng;
use crate::Result;

use super::common::{top_insights, KernelRunRecord, RepairPolicy, RunCtx, Session};

// ---------------------------------------------------------------------
// The stepwise method API

/// One budget-consuming trial request, as decided by a method's state
/// machine. The engine assembles the actual prompt from the session's
/// live population/insight state at execution time.
#[derive(Debug, Clone)]
pub struct GenerateStep {
    pub cfg: GuidanceConfig,
    /// Operator-specific directive (EoH E1/E2/M1/M2, stage names…).
    pub instruction: String,
    /// Pin the prompt's CURRENT KERNEL (EoH's M1/M2 operate on an
    /// explicit parent) instead of sampling one from the population.
    pub parent_override: Option<Candidate>,
    /// Substitute the I2 history section (the AI CUDA Engineer Compose
    /// stage's RAG kernels).
    pub history_override: Option<Vec<Candidate>>,
}

impl GenerateStep {
    pub fn new(cfg: GuidanceConfig, instruction: &str) -> Self {
        Self {
            cfg,
            instruction: instruction.to_string(),
            parent_override: None,
            history_override: None,
        }
    }

    pub fn with_parent(mut self, parent: Option<Candidate>) -> Self {
        self.parent_override = parent;
        self
    }

    pub fn with_history(mut self, history: Option<Vec<Candidate>>) -> Self {
        self.history_override = history;
        self
    }
}

/// What a method's state machine asks the engine to do next.
// One Step per trial: the size skew vs `Done` is irrelevant next to a
// provider call, and boxing would tax every state machine's ergonomics.
#[allow(clippy::large_enum_variant)]
#[derive(Debug)]
pub enum Step {
    /// Evaluate a known kernel source (no provider call, no budget) and
    /// seed the population with it — the bootstrap of the evolutionary
    /// methods.
    Evaluate(String),
    /// Run one full generate → guard/repair → evaluate trial.
    Generate(GenerateStep),
    /// The method's schedule is complete.
    Done,
}

/// A method's resumable per-run state machine. `next` is called once
/// per step with the read view of the session (budget left, last
/// candidate, population); the engine executes the returned step and
/// feeds the result back through the session before the next call.
pub trait MethodState: Send {
    fn next(&mut self, session: &Session) -> Step;

    /// Best-effort prediction of the instructions/configs of the `n`
    /// `Generate` steps *after* the one most recently yielded, assuming
    /// the pending trial leaves the method's plan unchanged. Used only
    /// by speculative prefetch — an empty or wrong prediction costs
    /// throughput, never correctness.
    fn peek(&self, session: &Session, n: usize) -> Vec<GenerateStep> {
        let _ = (session, n);
        Vec::new()
    }
}

// ---------------------------------------------------------------------
// Events

/// Receives every [`TrialEvent`] the engine emits. Implementations are
/// shared across campaign workers, so they must serialize internally.
pub trait EventSink: Send + Sync {
    fn emit(&self, ev: &TrialEvent);

    /// Group-commit flush point (DESIGN.md §14): the engine calls this
    /// at every trial boundary and at run end; sinks that buffer
    /// appends make them durable here. Default: no-op.
    fn flush(&self) {}
}

/// Appends every event to an [`EventJournal`] (`events.jsonl`).
/// Advisory, like the eval cache: a failed write warns, never kills
/// the run that produced the event.
pub struct JournalSink {
    journal: Arc<EventJournal>,
}

impl JournalSink {
    pub fn new(journal: Arc<EventJournal>) -> Self {
        Self { journal }
    }
}

impl EventSink for JournalSink {
    fn emit(&self, ev: &TrialEvent) {
        if let Err(e) = self.journal.append(ev) {
            eprintln!("warning: event journal append failed: {e:#}");
        }
    }

    fn flush(&self) {
        if let Err(e) = self.journal.flush() {
            eprintln!("warning: event journal flush failed: {e:#}");
        }
    }
}

/// Accumulates events into [`crate::metrics::EventStats`] (the
/// aggregate `report events` renders).
#[derive(Default)]
pub struct MetricsSink {
    stats: Mutex<crate::metrics::EventStats>,
}

impl MetricsSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> crate::metrics::EventStats {
        self.stats.lock().unwrap().clone()
    }
}

impl EventSink for MetricsSink {
    fn emit(&self, ev: &TrialEvent) {
        self.stats.lock().unwrap().fold(ev);
    }
}

/// Live progress/ETA lines on stderr. Two modes: `per_trial` prints a
/// line per evaluated trial group (single `optimize` runs);
/// otherwise a campaign-wide summary line is printed at most every two
/// seconds.
pub struct ProgressSink {
    per_trial: bool,
    total_cells: usize,
    state: Mutex<ProgressState>,
}

struct ProgressState {
    started: Instant,
    last_print: Option<Instant>,
    /// Trial budget per cell (from the last `RunStarted`; uniform
    /// across a campaign).
    budget: usize,
    /// Budget units spent (generate + repair calls).
    units: usize,
    /// Evaluated trial groups.
    groups: usize,
    cells: usize,
    best: f64,
}

impl ProgressSink {
    /// Per-trial mode for a single run.
    pub fn single_run() -> Self {
        Self::new(true, 1)
    }

    /// Interval mode for a campaign of `total_cells` runs.
    pub fn campaign(total_cells: usize) -> Self {
        Self::new(false, total_cells)
    }

    fn new(per_trial: bool, total_cells: usize) -> Self {
        Self {
            per_trial,
            total_cells,
            state: Mutex::new(ProgressState {
                started: Instant::now(),
                last_print: None,
                budget: 0,
                units: 0,
                groups: 0,
                cells: 0,
                best: 1.0,
            }),
        }
    }
}

impl EventSink for ProgressSink {
    fn emit(&self, ev: &TrialEvent) {
        let mut s = self.state.lock().unwrap();
        match &ev.kind {
            TrialEventKind::RunStarted { budget, .. } => s.budget = *budget,
            TrialEventKind::RepairAttempt { .. } => s.units += 1,
            TrialEventKind::NewBest { speedup, .. } => s.best = *speedup,
            TrialEventKind::RunFinished { .. } => s.cells += 1,
            TrialEventKind::EvalOutcome { trial, outcome, speedup, .. } => {
                s.units += 1;
                s.groups += 1;
                // The NewBest event follows EvalOutcome, so fold the
                // outcome's own speedup in first — otherwise the line
                // that *sets* a new best would print the stale one.
                if *speedup > s.best {
                    s.best = *speedup;
                }
                if self.per_trial {
                    let elapsed = s.started.elapsed().as_secs_f64();
                    let left = s.budget.saturating_sub(s.units);
                    let eta = elapsed / s.units.max(1) as f64 * left as f64;
                    eprintln!(
                        "  trial {:>3}: {:<15} best {:>5.2}x  [{} of {} budget units, \
                         ETA {eta:>4.0}s]",
                        trial, outcome, s.best, s.units, s.budget
                    );
                }
            }
            _ => {}
        }
        if !self.per_trial {
            let due = s
                .last_print
                .map(|t| t.elapsed().as_secs_f64() >= 2.0)
                .unwrap_or(s.groups > 0);
            if due && s.groups > 0 {
                let elapsed = s.started.elapsed().as_secs_f64();
                let rate = s.units as f64 / elapsed.max(1e-9);
                let total_units = self.total_cells * s.budget.max(1);
                let eta = (total_units.saturating_sub(s.units)) as f64 / rate.max(1e-9);
                eprintln!(
                    "campaign: {}/{} cells, {} trial units, {rate:.1} units/s, ETA ~{eta:.0}s",
                    s.cells, self.total_cells, s.units
                );
                s.last_print = Some(Instant::now());
            }
        }
    }
}

// ---------------------------------------------------------------------
// Kill switch (trial-granular --stop-after-trials test hook)

/// Claim-based global trial counter shared across campaign workers: a
/// simulated kill fires when `limit` trial groups have been *claimed*
/// process-wide, which makes the interruption point deterministic
/// (unlike a completion-count race).
pub struct TrialGate {
    limit: usize,
    claimed: AtomicUsize,
}

impl TrialGate {
    pub fn new(limit: usize) -> Self {
        Self { limit, claimed: AtomicUsize::new(0) }
    }

    /// Claim the right to start one more trial group.
    pub fn claim(&self) -> bool {
        self.claimed.fetch_add(1, Ordering::SeqCst) < self.limit
    }
}

/// Marker error for a [`TrialGate`]-induced simulated kill: the
/// campaign recognizes it (`downcast_ref`) and treats the sweep as
/// interrupted-but-healthy rather than failed.
#[derive(Debug)]
pub struct Interrupted;

impl std::fmt::Display for Interrupted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("run interrupted by the trial gate (--stop-after-trials)")
    }
}

impl std::error::Error for Interrupted {}

// ---------------------------------------------------------------------
// Engine options

/// How [`drive`] should run a cell.
#[derive(Clone, Default)]
pub struct EngineOpts {
    /// Event receivers (empty = silent, the pre-redesign behaviour).
    pub sinks: Vec<Arc<dyn EventSink>>,
    /// Speculative generation prefetch workers (0 = off). See the
    /// module docs for the byte-identity argument.
    pub prefetch: usize,
    /// Simulated mid-cell kill, shared across a campaign's workers.
    pub trial_gate: Option<Arc<TrialGate>>,
    /// This cell is resuming a prior interrupted run whose events are
    /// already journaled: suppress the duplicate `RunStarted` (and,
    /// per `verify_replay`, the replayed trials' events).
    pub resumed: bool,
    /// `(trial, src_hash)` pairs journaled by a prior interrupted run
    /// of this cell: replayed trials are verified against them and any
    /// divergence is reported (journal drift would break the
    /// bit-identical-resume contract).
    pub verify_replay: Vec<(usize, String)>,
}

// ---------------------------------------------------------------------
// The drive loop

/// Drive a method's state machine to completion for one
/// (method, model, op, seed) cell and produce its record.
pub fn drive(
    method: &dyn super::Method,
    ctx: &RunCtx,
    opts: &EngineOpts,
) -> Result<KernelRunRecord> {
    let (pop, state) = method.start(ctx);
    drive_parts(&method.name(), pop, state, ctx, opts)
}

/// [`drive`] over pre-built parts (what the `Method::run` default
/// implementation calls).
pub fn drive_parts(
    name: &str,
    pop: Box<dyn Population>,
    mut state: Box<dyn MethodState>,
    ctx: &RunCtx,
    opts: &EngineOpts,
) -> Result<KernelRunRecord> {
    let mut session = Session::start(ctx, name, pop);
    // Warm-start seeding (DESIGN.md §18): bank elites for this op
    // enter the population before trial 0. No RNG and no budget is
    // consumed, and a resumed cell re-seeds identically from the same
    // snapshot, so resume byte-identity holds.
    session.warm_seed();
    let emit = |kind: TrialEventKind| {
        if opts.sinks.is_empty() {
            return;
        }
        let ev = TrialEvent {
            method: name.to_string(),
            model: ctx.model.name.to_string(),
            op: ctx.task.name.clone(),
            seed: ctx.seed,
            kind,
        };
        for sink in &opts.sinks {
            sink.emit(&ev);
        }
    };
    // A resumed half-finished cell already has its RunStarted and its
    // completed trials in the event journal; re-emitting them would
    // double-count the cell in `report events`, so the journal reads
    // as one continuous run across the kill.
    if !opts.resumed {
        emit(TrialEventKind::RunStarted {
            budget: ctx.budget,
            provider: ctx.provider.label().to_string(),
        });
    }

    if opts.prefetch == 0 {
        run_loop(&mut session, state.as_mut(), opts, None, &emit)?;
    } else {
        // The shared job receiver must outlive the scope (workers
        // borrow it), so it lives out here; the sender/receiver pair
        // the main loop owns moves into the pool inside the scope.
        let (job_tx, job_rx) = mpsc::channel::<(String, GenerationRequest)>();
        let job_rx = Mutex::new(job_rx);
        let (res_tx, res_rx) = mpsc::channel();
        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..opts.prefetch {
                let res_tx = res_tx.clone();
                let job_rx = &job_rx;
                let provider = ctx.provider;
                scope.spawn(move || loop {
                    // Lock only for the blocking recv, never across the
                    // provider call, so generations run concurrently.
                    let job = { job_rx.lock().unwrap().recv() };
                    match job {
                        Ok((hash, req)) => {
                            let resp = provider.call(&req);
                            if res_tx.send((hash, resp)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break, // pool dropped: drain and exit
                    }
                });
            }
            drop(res_tx);
            let mut pool = PrefetchPool {
                workers: opts.prefetch,
                job_tx,
                res_rx,
                inflight: HashSet::new(),
                done: HashMap::new(),
                submitted: 0,
                served: 0,
            };
            let result = run_loop(&mut session, state.as_mut(), opts, Some(&mut pool), &emit);
            // Honest accounting: a mis-speculated call's response is
            // discarded, but on a live backend its token cost was real
            // — say so rather than silently under-reporting spend.
            let wasted = pool.submitted.saturating_sub(pool.served);
            if wasted > 0 {
                eprintln!(
                    "note: prefetch: {wasted} mis-speculated generation call(s) discarded \
                     for {}/{} seed {} — their provider-side token cost is not in the \
                     run record",
                    ctx.task.name, ctx.model.name, ctx.seed
                );
            }
            result
            // `pool` drops here, closing the job channel; the workers
            // exit and the scope joins them before returning.
        })?;
    }

    if session.budget_left() == 0 {
        emit(TrialEventKind::BudgetExhausted { trials: session.trials_done() });
    }
    let rec = session.finish();
    emit(TrialEventKind::RunFinished {
        trials: rec.trials,
        best_speedup: rec.best_speedup,
        any_valid: rec.any_valid,
    });
    flush_boundary(ctx, opts);
    Ok(rec)
}

/// Group-commit flush point (DESIGN.md §14): called at every trial
/// boundary and at run end, this makes everything the trial staged —
/// journal events, eval-cache records, transcript calls — durable
/// together. A kill strictly between two flush points therefore loses
/// whole trailing trials, never a torn slice of one, which is exactly
/// the granularity the trial-granular resume contract (PR 5)
/// re-derives.
fn flush_boundary(ctx: &RunCtx, opts: &EngineOpts) {
    for sink in &opts.sinks {
        sink.flush();
    }
    if let Some(store) = ctx.evaluator.store() {
        if let Err(e) = store.flush() {
            eprintln!("warning: eval-cache flush failed: {e:#}");
        }
    }
    ctx.provider.flush();
    if let Some(bank) = &ctx.bank {
        if let Err(e) = bank.flush() {
            eprintln!("warning: bank flush failed: {e:#}");
        }
    }
}

fn run_loop(
    session: &mut Session,
    state: &mut dyn MethodState,
    opts: &EngineOpts,
    mut pool: Option<&mut PrefetchPool>,
    emit: &dyn Fn(TrialEventKind),
) -> Result<()> {
    loop {
        match state.next(session) {
            Step::Done => return Ok(()),
            Step::Evaluate(src) => session.seed(src),
            Step::Generate(gen) => {
                if session.budget_left() == 0 {
                    return Ok(());
                }
                if let Some(gate) = &opts.trial_gate {
                    if !gate.claim() {
                        return Err(anyhow::Error::new(Interrupted));
                    }
                }
                // Trials a prior interrupted run already journaled are
                // replayed (warm) but not re-emitted: the journal keeps
                // one event stream per cell across kill+resume.
                let replayed = opts
                    .verify_replay
                    .iter()
                    .find(|(t, _)| *t == session.trials_done());
                if replayed.is_none() {
                    emit(TrialEventKind::TrialStarted { trial: session.trials_done() });
                }
                let report = run_trial(session, &gen, pool.as_deref_mut(), Some(&*state))?
                    .expect("budget checked above");
                if let Some((_, expect)) = replayed {
                    if *expect != report.src_hash {
                        eprintln!(
                            "warning: resume verification: trial {} of {}/{}/{} seed {} \
                             re-derived a different emission than the event journal \
                             recorded — resumed records may not be bit-identical",
                            report.trial,
                            session.method_name,
                            session.ctx.model.name,
                            session.ctx.task.name,
                            session.ctx.seed
                        );
                    }
                    flush_boundary(session.ctx, opts);
                    continue;
                }
                if let Some((pass, diagnostics)) = report.guard {
                    emit(TrialEventKind::GuardVerdict { trial: report.trial, pass, diagnostics });
                }
                for &(attempt, mended) in &report.repairs {
                    emit(TrialEventKind::RepairAttempt { trial: report.trial, attempt, mended });
                }
                emit(TrialEventKind::EvalOutcome {
                    trial: report.trial,
                    outcome: report.outcome.to_string(),
                    speedup: report.speedup,
                    prompt_tokens: report.prompt_tokens,
                    completion_tokens: report.completion_tokens,
                    src_hash: report.src_hash.clone(),
                });
                if report.new_best {
                    emit(TrialEventKind::NewBest { trial: report.trial, speedup: report.speedup });
                }
                flush_boundary(session.ctx, opts);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Trial execution (the sequencing that used to live in Session::trial)

/// Everything observable that happened in one trial group — the
/// engine's event source, returned rather than emitted so the trial
/// executor stays decoupled from the sinks.
pub(super) struct TrialReport {
    pub trial: usize,
    /// Initial stage-0 verdict `(pass, diagnostics)`, if a guard ran.
    pub guard: Option<(bool, usize)>,
    /// `(attempt, mended_after)` per LLM repair call.
    pub repairs: Vec<(usize, bool)>,
    pub outcome: &'static str,
    /// Noise-free speedup when valid, 0 otherwise.
    pub speedup: f64,
    /// Token usage of the whole group (generate + repairs).
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
    pub new_best: bool,
    /// Truncated SHA-256 of the raw evaluated emission.
    pub src_hash: String,
}

/// Run one full trial. Returns `Ok(None)` when the budget is spent;
/// `Err` only when the generation backend fails (an HTTP error after
/// retries, a transcript miss under replay — the sim backend is
/// infallible for known models).
pub(super) fn run_trial(
    session: &mut Session,
    step: &GenerateStep,
    mut pool: Option<&mut PrefetchPool>,
    state_for_peek: Option<&dyn MethodState>,
) -> Result<Option<TrialReport>> {
    if session.budget_left() == 0 {
        return Ok(None);
    }
    let trial_idx = session.trials_done();

    // --- solution guiding layer + prompt engineering layer ---------
    // Assembled from the *real* population state (this is the one
    // mutation point: stateful strategies advance here).
    let assembled = assemble(
        session.ctx,
        &session.rng,
        &session.insights,
        session.bandit.as_ref(),
        session.last_profile.as_ref(),
        session.bank_refs.as_deref(),
        session.pop.as_mut(),
        trial_idx,
        step,
    );
    let gen_routing = assembled
        .req
        .route
        .clone()
        .map(|member| (member, assembled.req.operator.clone().unwrap_or_default()));

    // --- provider call (possibly overlapped) ------------------------
    let resp = match pool.as_deref_mut() {
        Some(pool) => {
            let hash = assembled.req.hash();
            // The true request always goes through the pool so a
            // worker can run it while we speculate ahead.
            pool.submit(assembled.req.clone());
            if let Some(state) = state_for_peek {
                speculate(session, state, pool);
            }
            match pool.take(&hash) {
                Ok(resp) => resp,
                // A pooled failure may be stale — a transient HTTP
                // error cached when the call ran speculatively. One
                // live retry keeps "speculation costs throughput,
                // never correctness" honest; a deterministic failure
                // (replay miss) just fails identically again.
                Err(_) => session.ctx.provider.call(&assembled.req)?,
            }
        }
        None => session.ctx.provider.call(&assembled.req)?,
    };

    finish_trial(session, trial_idx, assembled.parent, resp, gen_routing).map(Some)
}

/// Submit speculative provider calls for the predicted next trials,
/// assembled on a population snapshot (never the real state).
fn speculate(session: &Session, state: &dyn MethodState, pool: &mut PrefetchPool) {
    let depth = pool.workers;
    let steps = state.peek(session, depth);
    if steps.is_empty() {
        return;
    }
    let mut pop = session.pop().snapshot();
    for (j, step) in steps.iter().take(depth).enumerate() {
        // Future indices assume each pending trial consumes exactly one
        // budget unit (a fired repair shifts the indices and the
        // speculation simply misses).
        let idx = session.trials_done() + 1 + j;
        if idx >= session.ctx.budget {
            break;
        }
        // Speculative routing runs against the *current* arm state; a
        // pending trial's bandit update changes the pick and the
        // speculation simply hash-misses (throughput, not correctness).
        // Likewise the performance profile: the pending trial's outcome
        // will replace `last_profile` before the next real assembly, so
        // with profiles enabled speculation always misses — the request
        // hash covers the profile text, keeping replay byte-identical.
        // Bank refs are constant per cell (the warm-start snapshot is
        // immutable), so speculation stays hash-exact under them.
        let a = assemble(
            session.ctx,
            &session.rng,
            &session.insights,
            session.bandit.as_ref(),
            session.last_profile.as_ref(),
            session.bank_refs.as_deref(),
            pop.as_mut(),
            idx,
            step,
        );
        pool.submit(a.req);
    }
}

struct Assembled {
    req: GenerationRequest,
    /// The parent candidate the prompt improved upon (insight-delta
    /// attribution needs it after evaluation).
    parent: Option<Candidate>,
}

/// Assemble the typed generation request for `trial_idx`: guidance
/// (parent pick, history, insights, profiling) → rendered prompt →
/// derived per-call seed. Pure except for `pop` (parent sampling may
/// advance strategy state, e.g. the island cursor) — which is why the
/// speculative path hands in a snapshot.
fn assemble(
    ctx: &RunCtx,
    session_rng: &Rng,
    insights: &[InsightRecord],
    routing_bandit: Option<&Bandit>,
    profile: Option<&ProfileReport>,
    bank_refs: Option<&str>,
    pop: &mut dyn Population,
    trial_idx: usize,
    step: &GenerateStep,
) -> Assembled {
    let mut trial_rng = session_rng.derive(&format!("trial/{trial_idx}"));
    let parent = step
        .parent_override
        .clone()
        .or_else(|| pop.parent(&mut trial_rng));
    let history: Vec<Candidate> = match &step.history_override {
        Some(h) => h.clone(),
        None => pop.history(step.cfg.n_history),
    };
    let insights = top_insights(insights, step.cfg.n_insights);
    let profiling = if step.cfg.profiling {
        parent.as_ref().and_then(|p| {
            p.spec.as_ref().map(|spec| {
                let t = price(&spec.schedule, ctx.task, &ctx.evaluator.gpu);
                profiling_line(&t)
            })
        })
    } else {
        None
    };
    let baseline_us = ctx.evaluator.baseline_time(ctx.task) * 1e6;
    let guidance = Guidance {
        task: ctx.task,
        baseline_us,
        parent: parent.as_ref(),
        history: history.iter().collect(),
        insights,
        profiling,
        instruction: step.instruction.clone(),
    };
    // The request seed is the exact word the pre-provider code's
    // inline `rng.derive("llm/{trial_idx}")` expanded, so the sim
    // backend reproduces the historical stream byte-for-byte.
    let prompt = render(&step.cfg, &guidance);
    let llm_seed = session_rng.derive_seed(&format!("llm/{trial_idx}"));
    let mut req = GenerationRequest::generate(ctx.model.name, &prompt, llm_seed);
    // Ensemble routing (DESIGN.md §16): pick the member arm with the
    // request's own llm seed (no new RNG derivations — the derivation
    // order above is a byte-identity contract) and stamp the decision
    // into the request, making it part of the request hash.
    if let Some(b) = routing_bandit {
        let operator = bandit::operator_tag(&step.instruction);
        let member = b.select(&operator, &ctx.task.family, llm_seed);
        req = req.with_routing(&operator, &ctx.task.family, &member);
    }
    // Profile-guided feedback (DESIGN.md §17): stamp the previous
    // trial's measured profile and the non-default objective emphasis
    // into the request. No new RNG derivations, and both fields are
    // `None` under the default `--goal speedup`, so legacy requests —
    // and their hashes — are byte-identical.
    let rendered = if ctx.feedback.profile {
        profile.map(|p| p.render(ctx.feedback.goal))
    } else {
        None
    };
    let goal = if ctx.feedback.goal != Goal::Speedup {
        Some(ctx.feedback.goal.name().to_string())
    } else {
        None
    };
    if rendered.is_some() || goal.is_some() {
        req = req.with_feedback(rendered, goal);
    }
    // Retrieval-seeded prompts (DESIGN.md §18): the warm-start
    // snapshot's top-K elites ride every generation request as a
    // `## PRIOR ELITES` section. No RNG derivations, and the field is
    // `None` without a snapshot, so legacy request hashes survive.
    if let Some(refs) = bank_refs {
        req = req.with_bank_refs(Some(refs.to_string()));
    }
    Assembled { req, parent }
}

fn outcome_label(outcome: &EvalOutcome) -> &'static str {
    match outcome {
        EvalOutcome::GuardReject { .. } => "guard_reject",
        EvalOutcome::CompileFail { .. } => "compile_fail",
        EvalOutcome::FunctionalFail { .. } => "functional_fail",
        EvalOutcome::RuntimeFail { .. } => "runtime_fail",
        EvalOutcome::Ok(_) => "ok",
    }
}

/// Everything after the generate call: stage-0 guard + LLM repair loop,
/// two-stage evaluation, insight recording, population/bookkeeping
/// updates. The sequencing (and every RNG derivation label) is the
/// pre-redesign `Session::trial` body, verbatim.
fn finish_trial(
    session: &mut Session,
    trial_idx: usize,
    parent: Option<Candidate>,
    resp: GenerationResponse,
    // `(member, operator)` the generate call was routed to, when
    // ensemble routing is active — its arm is rewarded from this
    // trial's outcome.
    gen_routing: Option<(String, String)>,
) -> Result<TrialReport> {
    let ctx = session.ctx;
    let mut group_prompt = resp.usage.prompt_tokens;
    let mut group_completion = resp.usage.completion_tokens;
    session.prompt_tokens += resp.usage.prompt_tokens;
    session.completion_tokens += resp.usage.completion_tokens;
    session.trials_done += 1;

    // --- stage 0: static validity guard + LLM repair loop ---------
    // (DESIGN.md §11.) Under `Repair`, each attempt is one more LLM
    // call and consumes one budget unit, per the paper's 45-trial
    // accounting; the loop stops early when the budget runs out.
    let mut text = resp.text;
    let mut was_repaired = false;
    let mut guard_seen: Option<(bool, usize)> = None;
    let mut repairs: Vec<(usize, bool)> = Vec::new();
    let guard_report = match ctx.repair {
        RepairPolicy::Off => None,
        RepairPolicy::Diagnose => {
            let report = ctx.evaluator.guard_check(&text, ctx.task);
            guard_seen = Some((report.pass(), report.diagnostics.len()));
            Some(report)
        }
        RepairPolicy::Repair { max_attempts } => {
            let mut report = ctx.evaluator.guard_check(&text, ctx.task);
            guard_seen = Some((report.pass(), report.diagnostics.len()));
            let initially_failed = !report.pass();
            let mut attempt = 0;
            while !report.pass() && attempt < max_attempts && session.budget_left() > 0 {
                let repair_seed =
                    session.rng.derive_seed(&format!("repair/{trial_idx}/{attempt}"));
                let mut req =
                    GenerationRequest::repair(ctx.model.name, &text, &report, repair_seed);
                if let Some(b) = &session.bandit {
                    let member = b.select("repair", &ctx.task.family, repair_seed);
                    req = req.with_routing("repair", &ctx.task.family, &member);
                }
                let fix = ctx.provider.call(&req)?;
                group_prompt += fix.usage.prompt_tokens;
                group_completion += fix.usage.completion_tokens;
                session.prompt_tokens += fix.usage.prompt_tokens;
                session.completion_tokens += fix.usage.completion_tokens;
                session.trials_done += 1;
                session.repair_attempts += 1;
                text = fix.text;
                report = ctx.evaluator.guard_check(&text, ctx.task);
                repairs.push((attempt, report.pass()));
                // Repair-arm feedback: did the routed member's fix pass
                // stage 0? Updated here, on the sequential completion
                // path, like every other arm update.
                if let Some(member) = req.route.clone() {
                    if let Some(b) = &mut session.bandit {
                        b.update(
                            &member,
                            "repair",
                            &ctx.task.family,
                            bandit::repair_reward(report.pass()),
                        );
                    }
                }
                attempt += 1;
            }
            if initially_failed && report.pass() {
                was_repaired = true;
            }
            Some(report)
        }
    };

    // --- two-stage evaluation (stage-0-gated, cache aware) --------
    let mut eval_rng = session.rng.derive(&format!("eval/{trial_idx}"));
    let outcome = match &guard_report {
        Some(report) if !report.pass() => {
            session.guard_rejected += 1;
            ctx.evaluator.reject_stage0(&text, ctx.task, ctx.model.name, report)
        }
        _ => ctx.evaluator.evaluate_keyed(&text, ctx.task, ctx.model.name, &mut eval_rng),
    };
    if was_repaired {
        session.repaired += 1;
    }
    if outcome.compiled() {
        session.compiled += 1;
    }
    if outcome.correct() {
        session.correct += 1;
    }

    let label = outcome_label(&outcome);
    let src_hash = sha256_hex(text.as_bytes())[..16].to_string();
    // Feedback capture happens here — on the sequential completion
    // path, like the bandit updates — so the profile the *next*
    // trial's request carries is `--prefetch`-independent.
    let timing = match &outcome {
        EvalOutcome::Ok(s) => Some(s.timing.clone()),
        _ => None,
    };
    session.capture_profile(&outcome);
    let cand = session.candidate_from(text, outcome, trial_idx, Some(resp.insight.clone()));

    // --- insight recording (solution-insight pair with observed
    // delta — what EvoEngineer "explicitly leverages", Table 2) ----
    let delta = if cand.valid() {
        let parent_speed = parent.as_ref().filter(|p| p.valid()).map(|p| p.speedup);
        match parent_speed {
            Some(ps) => cand.speedup - ps,
            None => cand.speedup - 1.0,
        }
    } else {
        -0.30 // invalid outcome: the idea is recorded as harmful
    };
    session.insights.push(InsightRecord { text: resp.insight, delta });
    // Bounded store: keep the 64 most useful insights (perf: the
    // per-trial top-k selection sorts this vec — see EXPERIMENTS.md
    // §Perf — and long sessions must not grow it unboundedly).
    if session.insights.len() > 128 {
        session.insights.sort_by(|a, b| b.delta.total_cmp(&a.delta));
        session.insights.truncate(64);
    }

    // --- bookkeeping -------------------------------------------------
    // Selection is by *measured* goal fitness (the paper's noisy
    // selection); the final record cites the chosen kernel's
    // noise-free numbers (the paper's final re-timing). Under the
    // default `--goal speedup` the fitness is the identity, so this is
    // bitwise the historical `cand.speedup > best.speedup` comparison.
    let cand_rank = ctx.feedback.goal.fitness(cand.speedup, timing.as_ref());
    let new_best = cand.valid()
        && session
            .best
            .as_ref()
            .map(|_| cand_rank > session.best_rank)
            .unwrap_or(true);
    if new_best {
        session.best = Some(cand.clone());
        session.best_rank = cand_rank;
        session.best_timing = timing.clone();
        // Elite deposit (DESIGN.md §18): sequential finish path only,
        // so the bank journal is `--prefetch`-independent. A pure
        // side-write — nothing below reads it back.
        session.deposit_elite(
            &cand,
            timing.as_ref(),
            gen_routing.as_ref().map(|(m, _)| m.as_str()),
        );
    }
    if cand.valid() {
        session.best_pt = session.best_pt.max(cand.true_pytorch_speedup);
    }
    session
        .trajectory
        .push(session.best.as_ref().map(|b| b.true_speedup).unwrap_or(1.0).max(1.0));

    let speedup = if cand.valid() { cand.true_speedup } else { 0.0 };
    // Generate-arm feedback: reward the routed member from the trial's
    // final outcome (the bandit's only mutation points are this one and
    // the repair loop above — both on the sequential completion path,
    // which is what makes arm state `--prefetch`-independent).
    if let Some((member, operator)) = gen_routing {
        if let Some(b) = &mut session.bandit {
            // Arm reward is goal-fitness-shaped (identity under the
            // default objective), so the router learns toward what
            // `--goal` actually optimizes.
            let reward_rank = ctx.feedback.goal.fitness(speedup, timing.as_ref());
            b.update(
                &member,
                &operator,
                &ctx.task.family,
                bandit::trial_reward(label, if speedup > 0.0 { Some(reward_rank) } else { None }),
            );
        }
    }
    session.pop.insert(cand.clone());
    session.last = Some(cand);
    Ok(TrialReport {
        trial: trial_idx,
        guard: guard_seen,
        repairs,
        outcome: label,
        speedup,
        prompt_tokens: group_prompt,
        completion_tokens: group_completion,
        new_best,
        src_hash,
    })
}

// ---------------------------------------------------------------------
// Prefetch pool

/// Hands provider calls to a scoped worker pool keyed by request hash.
/// Results for requests never consumed (mis-speculations) are silently
/// dropped — including errors, which matters under a replay provider
/// where a mis-speculated request is a legitimate journal miss.
pub(super) struct PrefetchPool {
    pub(super) workers: usize,
    job_tx: mpsc::Sender<(String, GenerationRequest)>,
    res_rx: mpsc::Receiver<(String, Result<GenerationResponse>)>,
    inflight: HashSet<String>,
    done: HashMap<String, Result<GenerationResponse>>,
    /// Distinct requests handed to workers / consumed by the engine —
    /// the difference is the mis-speculation count the drive loop
    /// reports for honest provider-side cost accounting.
    submitted: usize,
    served: usize,
}

impl PrefetchPool {
    /// Queue a request unless an identical one is already in flight or
    /// completed.
    fn submit(&mut self, req: GenerationRequest) {
        let hash = req.hash();
        if self.inflight.contains(&hash) || self.done.contains_key(&hash) {
            return;
        }
        if self.job_tx.send((hash.clone(), req)).is_ok() {
            self.inflight.insert(hash);
            self.submitted += 1;
        }
    }

    fn drain(&mut self) {
        while let Ok((hash, resp)) = self.res_rx.try_recv() {
            self.inflight.remove(&hash);
            self.done.insert(hash, resp);
        }
    }

    /// Block until the response for `hash` is available and return it.
    fn take(&mut self, hash: &str) -> Result<GenerationResponse> {
        loop {
            self.drain();
            if let Some(resp) = self.done.remove(hash) {
                self.served += 1;
                return resp;
            }
            if !self.inflight.contains(hash) {
                return Err(crate::eyre!("prefetch pool lost request {hash}"));
            }
            match self.res_rx.recv() {
                Ok((h, resp)) => {
                    self.inflight.remove(&h);
                    self.done.insert(h, resp);
                }
                Err(_) => return Err(crate::eyre!("prefetch workers exited unexpectedly")),
            }
        }
    }
}
