//! The six optimization methods of the paper's evaluation (§5.1):
//! EvoEngineer-{Free,Insight,Full}, EvoEngineer-Solution (EoH),
//! FunSearch, and the AI CUDA Engineer replication (§A.8). Each is a
//! configuration of the same orthogonal components — traverse technique
//! (guidance + prompt) and population management — which is exactly the
//! paper's framework claim.

pub mod aicuda;
pub mod common;
pub mod engine;
pub mod eoh;
pub mod evoengineer;
pub mod funsearch;

pub use aicuda::AiCudaEngineer;
pub use common::{baseline_src, Archive, ArchiveEntry, KernelRunRecord, RepairPolicy, RunCtx, Session};
pub use engine::{
    EngineOpts, EventSink, GenerateStep, Interrupted, JournalSink, MethodState, MetricsSink,
    ProgressSink, Step, TrialGate,
};
pub use eoh::Eoh;
pub use evoengineer::{EvoEngineer, EvoVariant};
pub use funsearch::FunSearch;

use crate::population::Population;

/// A kernel-optimization method, as a resumable state machine: `start`
/// produces the population strategy and the per-run [`MethodState`]
/// that [`engine::drive`] steps through one trial at a time (DESIGN.md
/// §13). The provided `run` drives the machine to completion with
/// default engine options — the pre-redesign blocking behaviour.
/// `Err` only when the generation backend fails mid-run (HTTP failure
/// after retries, transcript miss under replay); the sim backend never
/// errors for known models.
pub trait Method: Send + Sync {
    fn name(&self) -> String;

    /// Population strategy + state machine for one
    /// (method, model, op, seed) run.
    fn start(&self, ctx: &RunCtx) -> (Box<dyn Population>, Box<dyn MethodState>);

    /// Consume the trial budget on one op and report the run record
    /// (no event sinks, no prefetch).
    fn run(&self, ctx: &RunCtx) -> crate::Result<KernelRunRecord> {
        let (pop, state) = self.start(ctx);
        engine::drive_parts(&self.name(), pop, state, ctx, &EngineOpts::default())
    }
}

/// All six methods in the paper's presentation order.
pub fn all_methods() -> Vec<Box<dyn Method>> {
    vec![
        Box::new(AiCudaEngineer::new()),
        Box::new(FunSearch::new()),
        Box::new(Eoh::new()),
        Box::new(EvoEngineer::new(EvoVariant::Free)),
        Box::new(EvoEngineer::new(EvoVariant::Insight)),
        Box::new(EvoEngineer::new(EvoVariant::Full)),
    ]
}

/// Normalized form used for method-name matching: lowercase, letters
/// and digits only ("EvoEngineer-Solution (EoH)" → "evoengineersolutioneoh").
fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

/// Look a method up by (case-insensitive) name. An exact normalized
/// match always wins; otherwise the name is treated as a fragment and
/// must match exactly one method — an ambiguous fragment (e.g.
/// "evoengineer", which matches all four EvoEngineer configurations)
/// is an error listing the candidates instead of silently resolving to
/// whichever variant happens to come first.
pub fn by_name(name: &str) -> crate::Result<Box<dyn Method>> {
    let needle = normalize(name);
    let mut methods = all_methods();
    if let Some(i) = methods.iter().position(|m| normalize(&m.name()) == needle) {
        return Ok(methods.swap_remove(i));
    }
    let mut matches: Vec<usize> = methods
        .iter()
        .enumerate()
        .filter(|(_, m)| !needle.is_empty() && normalize(&m.name()).contains(&needle))
        .map(|(i, _)| i)
        .collect();
    match matches.len() {
        1 => Ok(methods.swap_remove(matches.pop().expect("one match"))),
        0 => Err(crate::eyre!(
            "unknown method `{name}` (available: {})",
            methods.iter().map(|m| m.name()).collect::<Vec<_>>().join(", ")
        )),
        _ => Err(crate::eyre!(
            "ambiguous method `{name}`: matches {} — use the full name",
            matches
                .iter()
                .map(|&i| methods[i].name())
                .collect::<Vec<_>>()
                .join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_methods() {
        let names: Vec<String> = all_methods().iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), 6);
        assert!(names.contains(&"EvoEngineer-Free".to_string()));
        assert!(names.contains(&"EvoEngineer-Solution (EoH)".to_string()));
        assert!(names.contains(&"AI CUDA Engineer".to_string()));
    }

    #[test]
    fn lookup_exact_and_unique_fragments() {
        assert_eq!(by_name("funsearch").unwrap().name(), "FunSearch");
        assert_eq!(by_name("evoengineer-full").unwrap().name(), "EvoEngineer-Full");
        assert_eq!(by_name("EvoEngineer_Free").unwrap().name(), "EvoEngineer-Free");
        // Unique fragments still resolve.
        assert_eq!(by_name("eoh").unwrap().name(), "EvoEngineer-Solution (EoH)");
        assert_eq!(by_name("ai cuda").unwrap().name(), "AI CUDA Engineer");
        assert_eq!(by_name("insight").unwrap().name(), "EvoEngineer-Insight");
    }

    #[test]
    fn lookup_rejects_unknown_with_candidates() {
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("unknown method `nope`"), "{err}");
        assert!(err.contains("FunSearch"), "{err}");
        let empty = by_name("").unwrap_err().to_string();
        assert!(empty.contains("unknown method"), "{empty}");
    }

    #[test]
    fn lookup_rejects_ambiguous_fragment_listing_candidates() {
        // Regression: "evoengineer" silently resolved to the first
        // variant in presentation order; it must now error and name
        // every matching configuration.
        let err = by_name("evoengineer").unwrap_err().to_string();
        assert!(err.contains("ambiguous method `evoengineer`"), "{err}");
        for candidate in [
            "EvoEngineer-Free",
            "EvoEngineer-Insight",
            "EvoEngineer-Full",
            "EvoEngineer-Solution (EoH)",
        ] {
            assert!(err.contains(candidate), "{err} missing {candidate}");
        }
    }
}
