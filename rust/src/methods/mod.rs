//! The six optimization methods of the paper's evaluation (§5.1):
//! EvoEngineer-{Free,Insight,Full}, EvoEngineer-Solution (EoH),
//! FunSearch, and the AI CUDA Engineer replication (§A.8). Each is a
//! configuration of the same orthogonal components — traverse technique
//! (guidance + prompt) and population management — which is exactly the
//! paper's framework claim.

pub mod aicuda;
pub mod common;
pub mod eoh;
pub mod evoengineer;
pub mod funsearch;

pub use aicuda::AiCudaEngineer;
pub use common::{Archive, ArchiveEntry, KernelRunRecord, RepairPolicy, RunCtx, Session};
pub use eoh::Eoh;
pub use evoengineer::{EvoEngineer, EvoVariant};
pub use funsearch::FunSearch;

/// A kernel-optimization method: consumes a 45-trial budget on one op
/// and reports the run record.
pub trait Method: Send + Sync {
    fn name(&self) -> String;
    fn run(&self, ctx: &RunCtx) -> KernelRunRecord;
}

/// All six methods in the paper's presentation order.
pub fn all_methods() -> Vec<Box<dyn Method>> {
    vec![
        Box::new(AiCudaEngineer::new()),
        Box::new(FunSearch::new()),
        Box::new(Eoh::new()),
        Box::new(EvoEngineer::new(EvoVariant::Free)),
        Box::new(EvoEngineer::new(EvoVariant::Insight)),
        Box::new(EvoEngineer::new(EvoVariant::Full)),
    ]
}

/// Look a method up by (case-insensitive) name fragment.
pub fn by_name(name: &str) -> Option<Box<dyn Method>> {
    let needle = name.to_ascii_lowercase().replace(['-', '_'], "");
    all_methods()
        .into_iter()
        .find(|m| m.name().to_ascii_lowercase().replace(['-', '_'], "").contains(&needle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_methods() {
        let names: Vec<String> = all_methods().iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), 6);
        assert!(names.contains(&"EvoEngineer-Free".to_string()));
        assert!(names.contains(&"EvoEngineer-Solution (EoH)".to_string()));
        assert!(names.contains(&"AI CUDA Engineer".to_string()));
    }

    #[test]
    fn lookup() {
        assert!(by_name("funsearch").is_some());
        assert!(by_name("evoengineer-full").is_some());
        assert!(by_name("nope").is_none());
    }
}
