//! Shared per-run state: the [`Session`] every method's state machine
//! is driven against, plus the run-level types ([`RunCtx`],
//! [`KernelRunRecord`], [`Archive`], [`RepairPolicy`]).
//!
//! One `Session` = one (method, model, op, seed) optimization run with
//! the paper's 45-trial budget. Since the trial-engine redesign
//! (DESIGN.md §13) the Session no longer *sequences* trials — the
//! generate → guard/repair → evaluate loop is owned by
//! [`engine::drive`](super::engine::drive), which calls back into the
//! Session for guidance assembly, insight recording, population
//! updates and token accounting. The Session owns the method's
//! [`Population`] and exposes the read view
//! ([`Session::budget_left`], [`Session::last`], [`Session::pop`])
//! that method state machines decide their next [`Step`] from.
//!
//! [`Step`]: super::engine::Step

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::costmodel::Timing;
use crate::dsl;
use crate::evals::{EvalOutcome, Evaluator};
use crate::feedback::{FeedbackConfig, Objective, ProfileReport};
use crate::llm::{ArmWeight, Bandit, ModelProfile, Provider};
use crate::population::{Candidate, Population};
use crate::tasks::OpTask;
use crate::traverse::InsightRecord;
use crate::util::json::Json;
use crate::util::Rng;

/// Cross-op archive of best kernels (the AI CUDA Engineer Compose
/// stage's RAG source; paper §A.8: "select top 5 kernels from other
/// kernels in the dataset").
#[derive(Debug, Clone, Default)]
pub struct Archive {
    inner: Arc<RwLock<HashMap<String, ArchiveEntry>>>,
}

#[derive(Debug, Clone)]
pub struct ArchiveEntry {
    pub op: String,
    pub family: String,
    pub src: String,
    pub speedup: f64,
    /// Goal-fitness rank (DESIGN.md §17) the archive selects on.
    /// Equals `speedup` under the default `--goal speedup`, so default
    /// archive behaviour is bit-identical to pre-feedback builds.
    pub rank: f64,
}

impl Archive {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, entry: ArchiveEntry) {
        let mut g = self.inner.write().unwrap();
        let slot = g.entry(entry.op.clone()).or_insert_with(|| entry.clone());
        // Goal-fitness rank, not raw speedup (identical under the
        // default objective, where rank == speedup).
        if entry.rank > slot.rank {
            *slot = entry;
        }
    }

    /// Top-k entries for other ops, same family first (the embedding
    /// search stand-in: family identity is our similarity metric).
    pub fn similar(&self, op: &str, family: &str, k: usize) -> Vec<ArchiveEntry> {
        let g = self.inner.read().unwrap();
        let mut entries: Vec<&ArchiveEntry> = g.values().filter(|e| e.op != op).collect();
        // total_cmp, not partial_cmp().unwrap(): a NaN speedup (e.g.
        // from a degenerate benchmark) must rank last, not panic the
        // sort; mapping NaN below every finite value keeps it out of
        // the top-k regardless of NaN sign.
        let rank = |x: f64| if x.is_nan() { f64::NEG_INFINITY } else { x };
        entries.sort_by(|a, b| {
            let fa = (a.family == family) as u8;
            let fb = (b.family == family) as u8;
            fb.cmp(&fa).then(rank(b.speedup).total_cmp(&rank(a.speedup)))
        });
        entries.into_iter().take(k).cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Stage-0 guard policy for a run (DESIGN.md §11) — the new ablation
/// axis every method inherits through [`RunCtx`]:
///
/// * `Off` — the historical pipeline: every emission goes straight to
///   the compile gate (byte-identical behaviour to pre-guard runs).
/// * `Diagnose` — the static guard runs before any compile; failing
///   candidates are rejected at stage 0 with structured diagnostics
///   (saving the compile) but the trial is spent.
/// * `Repair { max_attempts }` — failing candidates get up to
///   `max_attempts` LLM repair calls fed by the diagnostics; **each
///   repair attempt consumes one unit of the paper's 45-trial budget**
///   (a repair call is an LLM call), so repaired runs stay comparable
///   under the paper's accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RepairPolicy {
    #[default]
    Off,
    Diagnose,
    Repair {
        max_attempts: usize,
    },
}

impl RepairPolicy {
    /// Default repair attempts per trial for `--repair repair`.
    pub const DEFAULT_ATTEMPTS: usize = 2;

    /// Parse a `--repair` CLI value: `off` | `diagnose` | `repair` |
    /// `repair:K`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "" | "off" => Ok(RepairPolicy::Off),
            "diagnose" => Ok(RepairPolicy::Diagnose),
            "repair" => Ok(RepairPolicy::Repair { max_attempts: Self::DEFAULT_ATTEMPTS }),
            other => {
                if let Some(k) = other.strip_prefix("repair:") {
                    let max_attempts: usize = k
                        .parse()
                        .map_err(|_| crate::eyre!("bad repair attempt count `{k}`"))?;
                    if max_attempts == 0 {
                        return Err(crate::eyre!("repair:K needs K >= 1"));
                    }
                    Ok(RepairPolicy::Repair { max_attempts })
                } else {
                    Err(crate::eyre!(
                        "unknown --repair policy `{other}` (off|diagnose|repair|repair:K)"
                    ))
                }
            }
        }
    }

    /// Stable label recorded with every run (the ablation key).
    pub fn label(&self) -> String {
        match self {
            RepairPolicy::Off => "off".into(),
            RepairPolicy::Diagnose => "diagnose".into(),
            RepairPolicy::Repair { max_attempts } => format!("repair:{max_attempts}"),
        }
    }
}

/// Inputs shared by every method run.
pub struct RunCtx<'a> {
    pub evaluator: &'a Evaluator,
    pub task: &'a OpTask,
    pub model: &'a ModelProfile,
    pub seed: u64,
    pub archive: &'a Archive,
    /// Trial budget (the paper's 45).
    pub budget: usize,
    /// Stage-0 guard / repair policy (method ablation axis).
    pub repair: RepairPolicy,
    /// Profile-guided feedback configuration (`--goal`, DESIGN.md
    /// §17): the search objective plus whether measured performance
    /// profiles are attached to generation requests. The default is
    /// byte-identical to pre-feedback behaviour.
    pub feedback: FeedbackConfig,
    /// The generation backend every trial's `Generate`/`Repair` call
    /// goes through (DESIGN.md §12).
    pub provider: &'a dyn Provider,
    /// Deposit-side kernel bank (`--bank`, DESIGN.md §18): every new
    /// per-cell best is journaled here. Write-only from the engine's
    /// perspective — attaching it never changes records or events.
    pub bank: Option<Arc<crate::bank::KernelBank>>,
    /// Consumption-side bank snapshot (`--warm-start`, DESIGN.md §18):
    /// the immutable elite set that seeds populations and the
    /// `## PRIOR ELITES` prompt section. An empty snapshot behaves
    /// byte-identically to `None`.
    pub warm: Option<Arc<crate::bank::KernelBank>>,
}

/// Final record of one (method, model, op, seed) run — the unit the
/// metrics layer aggregates into every table and figure.
#[derive(Debug, Clone)]
pub struct KernelRunRecord {
    pub method: String,
    pub model: String,
    pub op: String,
    pub category: u8,
    pub seed: u64,
    pub trials: usize,
    /// Trial budget the run was configured with. `trials <= budget`
    /// (methods may stop early); recorded so a resumed campaign can
    /// tell a journaled cell was produced under the same `--budget`.
    pub budget: usize,
    pub compiled_trials: usize,
    pub correct_trials: usize,
    /// Trials whose final candidate was rejected at stage 0 by the
    /// static guard (after any repair attempts were exhausted).
    pub guard_rejected_trials: usize,
    /// Trials whose emission initially failed the guard but passed
    /// after LLM repair (overlay on the other outcome buckets).
    pub repaired_trials: usize,
    /// Extra LLM repair calls made (each consumed one budget unit);
    /// `trials - repair_attempts` = number of evaluated trial groups.
    pub repair_attempts: usize,
    /// The [`RepairPolicy`] label the run executed under.
    pub repair_policy: String,
    /// The [`FeedbackConfig`] label the run executed under
    /// (`"speedup"` | `"speedup+profile"` | `"memory"` | `"balanced"`).
    /// Serialized only when non-default, so legacy record files — and
    /// default-goal records — are byte-identical to pre-feedback ones.
    pub goal: String,
    /// Label of the generation backend ("sim", "http"; a replayed run
    /// carries the label of the backend that recorded its transcript,
    /// so record/replay runs are byte-identical).
    pub provider: String,
    /// Best valid speedup vs baseline; 1.0 when no valid improvement
    /// was found (the paper's failure convention, §5.1).
    pub best_speedup: f64,
    /// Best valid speedup vs the modeled PyTorch kernel (0.0 if none
    /// valid).
    pub best_pytorch_speedup: f64,
    pub any_valid: bool,
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
    /// Best-so-far speedup after each trial (convergence curves).
    pub trajectory: Vec<f64>,
    pub best_src: Option<String>,
    /// Learned bandit arm state at run end (multi-member ensemble runs
    /// only; empty — and absent from the JSON — otherwise, so
    /// single-backend records are byte-identical to historical ones).
    pub arms: Vec<ArmWeight>,
}

impl KernelRunRecord {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.completion_tokens
    }

    /// JSON serialization (offline environment: no serde; see
    /// util::json).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("method", Json::Str(self.method.clone())),
            ("model", Json::Str(self.model.clone())),
            ("op", Json::Str(self.op.clone())),
            ("category", Json::Num(self.category as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("trials", Json::Num(self.trials as f64)),
            ("budget", Json::Num(self.budget as f64)),
            ("compiled_trials", Json::Num(self.compiled_trials as f64)),
            ("correct_trials", Json::Num(self.correct_trials as f64)),
            ("guard_rejected_trials", Json::Num(self.guard_rejected_trials as f64)),
            ("repaired_trials", Json::Num(self.repaired_trials as f64)),
            ("repair_attempts", Json::Num(self.repair_attempts as f64)),
            ("repair_policy", Json::Str(self.repair_policy.clone())),
            ("provider", Json::Str(self.provider.clone())),
            ("best_speedup", Json::Num(self.best_speedup)),
            ("best_pytorch_speedup", Json::Num(self.best_pytorch_speedup)),
            ("any_valid", Json::Bool(self.any_valid)),
            ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
            ("completion_tokens", Json::Num(self.completion_tokens as f64)),
            (
                "trajectory",
                Json::Arr(self.trajectory.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "best_src",
                self.best_src
                    .as_ref()
                    .map(|s| Json::Str(s.clone()))
                    .unwrap_or(Json::Null),
            ),
        ];
        // Conditional, like the pre-ensemble fields' absence in old
        // files: a record without bandit activity serializes exactly
        // as it always did.
        if !self.arms.is_empty() {
            pairs.push((
                "arms",
                Json::Arr(
                    self.arms
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("member", Json::Str(a.member.clone())),
                                ("operator", Json::Str(a.operator.clone())),
                                ("category", Json::Str(a.category.clone())),
                                ("pulls", Json::Num(a.pulls as f64)),
                                ("mean_reward", Json::Num(a.mean_reward)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        // Same convention for the feedback goal: the default label is
        // omitted so default-goal records match historical bytes.
        if self.goal != "speedup" {
            pairs.push(("goal", Json::Str(self.goal.clone())));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let s = |k: &str| -> crate::Result<String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(String::from)
                .ok_or_else(|| crate::eyre!("record missing `{k}`"))
        };
        let n = |k: &str| -> crate::Result<f64> {
            v.get(k)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| crate::eyre!("record missing `{k}`"))
        };
        Ok(KernelRunRecord {
            method: s("method")?,
            model: s("model")?,
            op: s("op")?,
            category: n("category")? as u8,
            seed: n("seed")? as u64,
            trials: n("trials")? as usize,
            // Absent in pre-checkpoint record files: assume the run
            // consumed its whole budget.
            budget: v
                .get("budget")
                .and_then(|x| x.as_usize())
                .unwrap_or(n("trials")? as usize),
            compiled_trials: n("compiled_trials")? as usize,
            correct_trials: n("correct_trials")? as usize,
            // Absent in pre-guard record files: no stage-0 activity.
            guard_rejected_trials: v
                .get("guard_rejected_trials")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            repaired_trials: v
                .get("repaired_trials")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            repair_attempts: v
                .get("repair_attempts")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            repair_policy: v
                .get("repair_policy")
                .and_then(|x| x.as_str())
                .unwrap_or("off")
                .to_string(),
            // Absent in pre-feedback record files and in default-goal
            // runs: the objective was plain speedup.
            goal: v
                .get("goal")
                .and_then(|x| x.as_str())
                .unwrap_or("speedup")
                .to_string(),
            // Absent in pre-provider record files: every historical
            // run was generated by the SimLLM.
            provider: v
                .get("provider")
                .and_then(|x| x.as_str())
                .unwrap_or("sim")
                .to_string(),
            best_speedup: n("best_speedup")?,
            best_pytorch_speedup: n("best_pytorch_speedup")?,
            any_valid: v.get("any_valid").and_then(|x| x.as_bool()).unwrap_or(false),
            prompt_tokens: n("prompt_tokens")? as u64,
            completion_tokens: n("completion_tokens")? as u64,
            trajectory: v
                .get("trajectory")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default(),
            best_src: v.get("best_src").and_then(|x| x.as_str()).map(String::from),
            // Absent in single-backend record files: no bandit ran.
            arms: v
                .get("arms")
                .and_then(|x| x.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| {
                            Some(ArmWeight {
                                member: x.get("member")?.as_str()?.to_string(),
                                operator: x.get("operator")?.as_str()?.to_string(),
                                category: x.get("category")?.as_str()?.to_string(),
                                pulls: x.get("pulls")?.as_f64()? as u64,
                                mean_reward: x.get("mean_reward")?.as_f64()?,
                            })
                        })
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

/// One live optimization session. Created by
/// [`engine::drive`](super::engine::drive); method state machines see
/// it read-only, the engine mutates it as trials execute.
pub struct Session<'a> {
    pub ctx: &'a RunCtx<'a>,
    pub(super) method_name: String,
    pub(super) rng: Rng,
    pub insights: Vec<InsightRecord>,
    /// The method's population strategy (owned here so the engine's
    /// speculative prefetch can snapshot it).
    pub(super) pop: Box<dyn Population>,
    /// The most recent trial's final candidate (what AI CUDA
    /// Engineer's Convert stage inspects to decide its next step).
    pub(super) last: Option<Candidate>,
    pub(super) prompt_tokens: u64,
    pub(super) completion_tokens: u64,
    pub(super) trials_done: usize,
    pub(super) compiled: usize,
    pub(super) correct: usize,
    pub(super) guard_rejected: usize,
    pub(super) repaired: usize,
    pub(super) repair_attempts: usize,
    pub(super) best: Option<Candidate>,
    pub(super) best_pt: f64,
    /// Goal-fitness rank of `best` (DESIGN.md §17). Under the default
    /// `--goal speedup` this is exactly `best.true_speedup`, so the
    /// best-so-far comparison is bitwise-identical to historical runs.
    pub(super) best_rank: f64,
    /// Roofline timing of `best` (needed to re-rank it at `finish`).
    pub(super) best_timing: Option<Timing>,
    /// Performance profile of the most recent completed trial —
    /// attached to the *next* trial's generation request when
    /// `ctx.feedback.profile` is on. Updated only on the sequential
    /// finish path, so speculative prefetch sees a stale value and
    /// simply hash-misses (throughput cost, never a correctness one).
    pub(super) last_profile: Option<ProfileReport>,
    pub(super) trajectory: Vec<f64>,
    /// Per-cell routing bandit — `Some` only when the provider is a
    /// multi-member ensemble (DESIGN.md §16). Lives here, not in the
    /// shared provider, so arm state is scoped to one run and updated
    /// only on the sequential trial-completion path.
    pub(super) bandit: Option<Bandit>,
    /// Rendered `## PRIOR ELITES` section body (DESIGN.md §18) —
    /// retrieved once from the immutable warm-start snapshot at
    /// session start, so every generation request in this cell carries
    /// the same refs and speculative prefetch hashes stay exact. `None`
    /// when no snapshot is attached or retrieval came back empty.
    pub(super) bank_refs: Option<String>,
}

/// The op's starting kernel source (the dataset's "initial C++/CUDA
/// implementation" — quality-tiered per op, see
/// `costmodel::baseline_schedule`).
pub fn baseline_src(ctx: &RunCtx) -> String {
    dsl::print(&dsl::KernelSpec {
        op: ctx.task.name.clone(),
        semantics: "opt".into(),
        schedule: crate::costmodel::baseline_schedule(ctx.task),
    })
}

/// Flattened argument dims of an op — the bank retriever's shape axis
/// (DESIGN.md §18).
pub fn task_shape(task: &OpTask) -> Vec<usize> {
    task.args.iter().flat_map(|a| a.shape.iter().copied()).collect()
}

/// Distill a one-line profile summary for a bank deposit: the
/// captured [`ProfileReport`] findings when profile feedback is on,
/// otherwise a fixed-format roofline line from the elite's timing.
/// Deterministic and bounded — it rides in retrieval-seeded prompts.
fn distill_profile(profile: Option<&ProfileReport>, timing: Option<&Timing>) -> String {
    if let Some(p) = profile {
        if !p.findings.is_empty() {
            let mut line = p.findings[..p.findings.len().min(2)].join("; ");
            if line.len() > 200 {
                let mut cut = 200;
                while !line.is_char_boundary(cut) {
                    cut -= 1;
                }
                line.truncate(cut);
            }
            return line;
        }
    }
    match timing {
        Some(t) => {
            let bound = match t.bound {
                crate::costmodel::BoundKind::Compute => "compute",
                crate::costmodel::BoundKind::Memory => "memory",
                crate::costmodel::BoundKind::Launch => "launch",
            };
            format!(
                "{bound}-bound; occupancy {:.2}; eff_bw {:.2}; launches {}",
                t.occupancy, t.eff_bw, t.launches
            )
        }
        None => String::new(),
    }
}

/// Top-k insights by recorded benefit (for the I3 prompt section).
pub(super) fn top_insights(insights: &[InsightRecord], k: usize) -> Vec<&InsightRecord> {
    let mut v: Vec<&InsightRecord> = insights.iter().collect();
    v.sort_by(|a, b| b.delta.total_cmp(&a.delta));
    v.truncate(k);
    v
}

impl<'a> Session<'a> {
    /// Start a session for one run; `pop` is the method's population
    /// strategy (from [`Method::start`](super::Method::start)).
    pub fn start(ctx: &'a RunCtx<'a>, method_name: &str, pop: Box<dyn Population>) -> Self {
        let rng = Rng::new(ctx.seed).derive(&format!(
            "{method_name}/{}/{}/{}",
            ctx.model.name, ctx.task.name, ctx.seed
        ));
        let bank_refs = ctx.warm.as_ref().and_then(|bank| {
            let hits = bank.retrieve(
                &ctx.task.name,
                &ctx.task.family,
                ctx.task.category,
                &task_shape(ctx.task),
                crate::bank::RETRIEVE_K,
            );
            if hits.is_empty() {
                None
            } else {
                Some(crate::bank::render_refs(&hits))
            }
        });
        Session {
            ctx,
            method_name: method_name.to_string(),
            rng,
            insights: Vec::new(),
            pop,
            last: None,
            prompt_tokens: 0,
            completion_tokens: 0,
            trials_done: 0,
            compiled: 0,
            correct: 0,
            guard_rejected: 0,
            repaired: 0,
            repair_attempts: 0,
            best: None,
            best_pt: 0.0,
            best_rank: 0.0,
            best_timing: None,
            last_profile: None,
            trajectory: Vec::new(),
            bandit: ctx.provider.routing().map(|spec| Bandit::new(&spec)),
            bank_refs,
        }
    }

    /// Seed the population from warm-start bank elites for this op
    /// (before trial 0; the engine calls this once when `ctx.warm` is
    /// set). Elites enter with their noise-free deposited speedups at
    /// trial 0 and consume no budget and no RNG. An empty snapshot
    /// seeds nothing, so bank-off and empty-bank runs stay
    /// byte-identical.
    pub(super) fn warm_seed(&mut self) {
        let Some(warm) = &self.ctx.warm else { return };
        for e in warm
            .entries_for_op(&self.ctx.task.name)
            .into_iter()
            .take(crate::bank::WARM_SEED_K)
        {
            let spec = dsl::parse(&e.src).ok();
            self.pop.insert(Candidate {
                src: e.src,
                spec,
                compiled: true,
                correct: true,
                speedup: e.speedup,
                pytorch_speedup: 0.0,
                true_speedup: e.speedup,
                true_pytorch_speedup: 0.0,
                insight: if e.insight.is_empty() { None } else { Some(e.insight) },
                trial: 0,
            });
        }
    }

    /// Journal a new per-cell best into the deposit bank (DESIGN.md
    /// §18). A pure side-write on the sequential finish path: dedup'd
    /// by content key, advisory on error, and never read back during
    /// this run — records and events are byte-identical with or
    /// without a bank attached.
    pub(super) fn deposit_elite(
        &self,
        cand: &Candidate,
        timing: Option<&Timing>,
        route: Option<&str>,
    ) {
        let Some(bank) = &self.ctx.bank else { return };
        let Ok(spec) = dsl::parse(&cand.src) else { return };
        let canonical = dsl::print(&spec);
        let task = self.ctx.task;
        let entry = crate::bank::BankEntry {
            key: crate::bank::entry_key(&task.name, &canonical),
            op: task.name.clone(),
            family: task.family.clone(),
            category: task.category,
            goal: self.ctx.feedback.goal.name().to_string(),
            src: canonical,
            speedup: cand.true_speedup,
            rank: self.ctx.feedback.goal.fitness(cand.true_speedup, timing),
            shape: task_shape(task),
            profile: distill_profile(self.last_profile.as_ref(), timing),
            provider: self.ctx.provider.label().to_string(),
            model: self.ctx.model.name.to_string(),
            method: self.method_name.clone(),
            route: route.unwrap_or("").to_string(),
            insight: cand.insight.clone().unwrap_or_default(),
        };
        if let Err(e) = bank.deposit(entry) {
            eprintln!("warning: bank deposit failed: {e:#}");
        }
    }

    pub fn budget_left(&self) -> usize {
        self.ctx.budget.saturating_sub(self.trials_done)
    }

    /// Budget units consumed so far (generate + repair calls).
    pub fn trials_done(&self) -> usize {
        self.trials_done
    }

    /// The most recent trial's final candidate.
    pub fn last(&self) -> Option<&Candidate> {
        self.last.as_ref()
    }

    /// Best valid candidate found so far (by measured speedup).
    pub fn best(&self) -> Option<&Candidate> {
        self.best.as_ref()
    }

    /// Read view of the method's population (state machines use this
    /// to pin parents, e.g. EoH's M1/M2 operate on `pop().best()`).
    pub fn pop(&self) -> &dyn Population {
        self.pop.as_ref()
    }

    /// Evaluate a known kernel source and seed the population with it
    /// (the engine's handler for [`Step::Evaluate`]). Does not consume
    /// budget, and is exempt from the stage-0 guard: the baseline
    /// kernel is dataset ground truth, not an untrusted LLM emission.
    ///
    /// [`Step::Evaluate`]: super::engine::Step::Evaluate
    pub fn seed(&mut self, src: String) {
        let mut rng = self.rng.derive("bootstrap");
        let outcome =
            self.ctx.evaluator.evaluate_keyed(&src, self.ctx.task, self.ctx.model.name, &mut rng);
        self.capture_profile(&outcome);
        let cand = self.candidate_from(src, outcome, 0, None);
        self.pop.insert(cand);
    }

    /// Record the just-measured outcome as the profile the next
    /// generation request will carry (no-op unless `--goal` enables
    /// profiles, keeping default requests byte-identical).
    pub(super) fn capture_profile(&mut self, outcome: &EvalOutcome) {
        if self.ctx.feedback.profile {
            self.last_profile = Some(ProfileReport::from_outcome(
                self.ctx.task,
                outcome,
                &self.ctx.evaluator.gpu,
            ));
        }
    }

    pub(super) fn candidate_from(
        &mut self,
        src: String,
        outcome: EvalOutcome,
        trial: usize,
        insight: Option<String>,
    ) -> Candidate {
        let spec = dsl::parse(&src).ok();
        let (speedup, pt, true_speedup, true_pt) = match &outcome {
            EvalOutcome::Ok(s) => {
                (s.speedup, s.pytorch_speedup, s.true_speedup, s.true_pytorch_speedup)
            }
            _ => (1.0, 0.0, 1.0, 0.0),
        };
        Candidate {
            src,
            spec,
            compiled: outcome.compiled(),
            correct: outcome.correct(),
            speedup,
            pytorch_speedup: pt,
            true_speedup,
            true_pytorch_speedup: true_pt,
            insight,
            trial,
        }
    }

    /// Run one full trial through the engine (assembly → provider →
    /// guard/repair → evaluate → bookkeeping), with no event sinks and
    /// no prefetch. Returns `Ok(None)` when the budget is spent; `Err`
    /// only when the generation backend fails. This is the
    /// single-trial entry point benches and tests drive directly; the
    /// normal caller is [`engine::drive`](super::engine::drive).
    pub fn run_trial(
        &mut self,
        step: &super::engine::GenerateStep,
    ) -> crate::Result<Option<Candidate>> {
        Ok(super::engine::run_trial(self, step, None, None)?.map(|_| {
            self.last.clone().expect("a completed trial sets `last`")
        }))
    }

    /// Close the session: publish to the archive, emit the record.
    pub fn finish(self) -> KernelRunRecord {
        let method_name = self.method_name.clone();
        if let Some(best) = &self.best {
            self.ctx.archive.record(ArchiveEntry {
                op: self.ctx.task.name.clone(),
                family: self.ctx.task.family.clone(),
                src: best.src.clone(),
                speedup: best.true_speedup,
                // Noise-free rank (replay-stable): fitness over the
                // *true* speedup, == true_speedup under the default.
                rank: self
                    .ctx
                    .feedback
                    .goal
                    .fitness(best.true_speedup, self.best_timing.as_ref()),
            });
        }
        KernelRunRecord {
            method: method_name.to_string(),
            model: self.ctx.model.name.to_string(),
            op: self.ctx.task.name.clone(),
            category: self.ctx.task.category,
            seed: self.ctx.seed,
            trials: self.trials_done,
            budget: self.ctx.budget,
            compiled_trials: self.compiled,
            correct_trials: self.correct,
            guard_rejected_trials: self.guard_rejected,
            repaired_trials: self.repaired,
            repair_attempts: self.repair_attempts,
            repair_policy: self.ctx.repair.label(),
            goal: self.ctx.feedback.label(),
            provider: self.ctx.provider.label().to_string(),
            best_speedup: self.best.as_ref().map(|b| b.true_speedup).unwrap_or(1.0).max(1.0),
            best_pytorch_speedup: self.best_pt,
            any_valid: self.best.is_some(),
            prompt_tokens: self.prompt_tokens,
            completion_tokens: self.completion_tokens,
            trajectory: self.trajectory,
            arms: self.bandit.as_ref().map(|b| b.arms()).unwrap_or_default(),
            best_src: self.best.map(|b| b.src),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(op: &str, family: &str, speedup: f64) -> ArchiveEntry {
        ArchiveEntry {
            op: op.into(),
            family: family.into(),
            src: format!("kernel {op}"),
            speedup,
            rank: speedup,
        }
    }

    #[test]
    fn archive_similar_orders_by_family_then_speedup() {
        let a = Archive::new();
        a.record(entry("m1", "matmul", 2.0));
        a.record(entry("m2", "matmul", 3.0));
        a.record(entry("r1", "reduce", 9.0));
        let sim = a.similar("self", "matmul", 3);
        assert_eq!(sim.len(), 3);
        assert_eq!(sim[0].op, "m2"); // same family, fastest first
        assert_eq!(sim[1].op, "m1");
        assert_eq!(sim[2].op, "r1"); // other family last despite 9.0x
    }

    #[test]
    fn archive_similar_survives_nan_speedups() {
        // Regression: partial_cmp().unwrap() panicked on NaN entries
        // (a degenerate benchmark can produce a NaN speedup); the sort
        // must instead rank NaN last and never panic.
        let a = Archive::new();
        a.record(entry("nan_op", "matmul", f64::NAN));
        a.record(entry("m1", "matmul", 2.0));
        a.record(entry("m2", "matmul", 1.5));
        a.record(entry("nan_op2", "matmul", f64::NAN));
        let sim = a.similar("self", "matmul", 4);
        assert_eq!(sim.len(), 4);
        assert_eq!(sim[0].op, "m1");
        assert_eq!(sim[1].op, "m2");
        assert!(sim[2].speedup.is_nan());
        assert!(sim[3].speedup.is_nan());
        // NaN entries never displace finite ones from a tight top-k.
        let top2 = a.similar("self", "matmul", 2);
        assert_eq!(top2.len(), 2);
        assert!(top2.iter().all(|e| !e.speedup.is_nan()), "{top2:?}");
    }

    #[test]
    fn repair_policy_parse_roundtrip() {
        assert_eq!(RepairPolicy::parse("off").unwrap(), RepairPolicy::Off);
        assert_eq!(RepairPolicy::parse("diagnose").unwrap(), RepairPolicy::Diagnose);
        assert_eq!(
            RepairPolicy::parse("repair:3").unwrap(),
            RepairPolicy::Repair { max_attempts: 3 }
        );
        assert!(RepairPolicy::parse("repair:0").is_err());
        assert!(RepairPolicy::parse("mend").is_err());
    }
}
