//! FunSearch (Romera-Paredes et al., 2024) as configured in §A.4:
//! 5 islands, sampling until the 45-trial budget is exhausted. The
//! prompt contains only the task context and two historical solutions
//! from the current island (Table 2: minimal information usage) — the
//! "best-shot" prompting style of the original system, which is also
//! the core technique behind AlphaEvolve.

use crate::population::{Islands, Population};
use crate::traverse::GuidanceConfig;

use super::common::{baseline_src, RunCtx, Session};
use super::engine::{GenerateStep, MethodState, Step};
use super::Method;

pub struct FunSearch;

impl FunSearch {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FunSearch
    }
}

const IMPROVE: &str = "Here are prior kernel versions ordered by quality. Write an improved \
next version of the kernel.";

/// Bootstrap, then sample until the budget is exhausted. The constant
/// instruction makes `peek` exact; prompts still change as islands
/// fill, so speculative prefetch validates per-trial (module docs of
/// [`super::engine`]).
struct FunSearchState {
    seeded: bool,
}

impl MethodState for FunSearchState {
    fn next(&mut self, session: &Session) -> Step {
        if !self.seeded {
            self.seeded = true;
            return Step::Evaluate(baseline_src(session.ctx));
        }
        if session.budget_left() == 0 {
            return Step::Done;
        }
        Step::Generate(GenerateStep::new(GuidanceConfig::funsearch(), IMPROVE))
    }

    fn peek(&self, _session: &Session, n: usize) -> Vec<GenerateStep> {
        (0..n)
            .map(|_| GenerateStep::new(GuidanceConfig::funsearch(), IMPROVE))
            .collect()
    }
}

impl Method for FunSearch {
    fn name(&self) -> String {
        "FunSearch".into()
    }

    fn start(&self, _ctx: &RunCtx) -> (Box<dyn Population>, Box<dyn MethodState>) {
        (Box::new(Islands::funsearch()), Box::new(FunSearchState { seeded: false }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::Archive;
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    #[test]
    fn funsearch_runs_budget() {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        let evaluator = Evaluator::new(reg, Runtime::new().unwrap());
        let task = evaluator.registry.get("cumsum_rows_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 5,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = FunSearch::new().run(&ctx).unwrap();
        assert_eq!(rec.trials, 45);
        assert!(rec.best_speedup >= 1.0);
    }
}
