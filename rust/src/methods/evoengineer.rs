//! The three EvoEngineer configurations (paper §4.2, Table 3):
//!
//! | Variant  | I1 | I2 | I3 | Population   |
//! |----------|----|----|----|--------------|
//! | Free     | ✓  | ✗  | ✗  | single best  |
//! | Insight  | ✓  | ✗  | ✓  | single best  |
//! | Full     | ✓  | ✓  | ✓  | elite (4)    |
//!
//! Free and Insight run a flat 45-trial improvement loop; Full uses
//! EoH-style generational structure (5 init + 10 generations × 4
//! offspring, §A.4).

use crate::population::{Elite, Population, SingleBest};
use crate::traverse::GuidanceConfig;

use super::common::{baseline_src, RunCtx, Session};
use super::engine::{GenerateStep, MethodState, Step};
use super::Method;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvoVariant {
    Free,
    Insight,
    Full,
}

pub struct EvoEngineer {
    pub variant: EvoVariant,
}

impl EvoEngineer {
    pub fn new(variant: EvoVariant) -> Self {
        Self { variant }
    }

    fn config(&self) -> GuidanceConfig {
        match self.variant {
            EvoVariant::Free => GuidanceConfig::free(),
            EvoVariant::Insight => GuidanceConfig::insight(),
            EvoVariant::Full => GuidanceConfig::full(),
        }
    }
}

const IMPROVE: &str = "Improve the current kernel: propose a modified schedule that reduces \
execution time while preserving exact output semantics.";
const INIT: &str = "Design a new kernel from scratch for this operation, optimized for the \
target device.";

/// The state machine: bootstrap, then a flat improvement loop (Free /
/// Insight) or the generational 5-init + 10×4-offspring schedule
/// (Full, §A.4). The instruction sequence is outcome-independent, so
/// `peek` predicts it exactly and speculative prefetch hits whenever
/// the pending trial leaves the population/insight state unchanged.
struct EvoState {
    variant: EvoVariant,
    cfg: GuidanceConfig,
    seeded: bool,
    /// `Generate` steps yielded so far (the Full schedule cursor).
    steps: usize,
}

impl EvoState {
    /// Instruction of schedule slot `s`, `None` when the schedule is
    /// over (Full stops after 5 + 10×4 = 45 proposals).
    fn instruction_at(&self, s: usize) -> Option<&'static str> {
        match self.variant {
            EvoVariant::Free | EvoVariant::Insight => Some(IMPROVE),
            EvoVariant::Full => {
                if s >= 45 {
                    None
                } else if s < 5 {
                    Some(INIT)
                } else {
                    Some(IMPROVE)
                }
            }
        }
    }
}

impl MethodState for EvoState {
    fn next(&mut self, session: &Session) -> Step {
        if !self.seeded {
            self.seeded = true;
            return Step::Evaluate(baseline_src(session.ctx));
        }
        if session.budget_left() == 0 {
            return Step::Done;
        }
        match self.instruction_at(self.steps) {
            Some(instruction) => {
                self.steps += 1;
                Step::Generate(GenerateStep::new(self.cfg, instruction))
            }
            None => Step::Done,
        }
    }

    fn peek(&self, _session: &Session, n: usize) -> Vec<GenerateStep> {
        (0..n)
            .filter_map(|j| self.instruction_at(self.steps + j))
            .map(|instruction| GenerateStep::new(self.cfg, instruction))
            .collect()
    }
}

impl Method for EvoEngineer {
    fn name(&self) -> String {
        match self.variant {
            EvoVariant::Free => "EvoEngineer-Free".into(),
            EvoVariant::Insight => "EvoEngineer-Insight".into(),
            EvoVariant::Full => "EvoEngineer-Full".into(),
        }
    }

    fn start(&self, _ctx: &RunCtx) -> (Box<dyn Population>, Box<dyn MethodState>) {
        let pop: Box<dyn Population> = match self.variant {
            EvoVariant::Free | EvoVariant::Insight => Box::new(SingleBest::new()),
            EvoVariant::Full => Box::new(Elite::new(4)),
        };
        let state = EvoState {
            variant: self.variant,
            cfg: self.config(),
            seeded: false,
            steps: 0,
        };
        (pop, Box::new(state))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::{Archive, RepairPolicy};
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    fn eval() -> Evaluator {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        Evaluator::new(reg, Runtime::new().unwrap())
    }

    #[test]
    fn free_consumes_exactly_the_budget() {
        let evaluator = eval();
        let task = evaluator.registry.get("relu_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 1,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = EvoEngineer::new(EvoVariant::Free).run(&ctx).unwrap();
        assert_eq!(rec.trials, 45);
        assert_eq!(rec.trajectory.len(), 45);
        assert!(rec.best_speedup >= 1.0);
        assert!(rec.compiled_trials <= rec.trials);
        assert!(rec.correct_trials <= rec.compiled_trials);
        assert!(rec.prompt_tokens > 0 && rec.completion_tokens > 0);
    }

    #[test]
    fn runs_are_seed_deterministic() {
        let evaluator = eval();
        let task = evaluator.registry.get("softmax_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let run = |seed| {
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[2],
                seed,
                archive: &archive,
                provider: &provider,
                budget: 20,
                repair: RepairPolicy::Off,
                feedback: Default::default(),
                bank: None,
                warm: None,
            };
            EvoEngineer::new(EvoVariant::Full).run(&ctx).unwrap()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.trajectory, b.trajectory);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        // different seed should (almost surely) differ somewhere
        assert!(
            a.trajectory != c.trajectory || a.prompt_tokens != c.prompt_tokens,
            "seeds produced identical runs"
        );
    }

    #[test]
    fn repair_policy_is_deterministic_and_budget_accounted() {
        // Category 6 + GPT has the highest defect rates, so the guard
        // and repair loop both fire within a 45-trial run.
        let evaluator = eval();
        let task = evaluator.registry.get("cumsum_rows_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let run = |repair| {
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[0],
                seed: 0,
                archive: &archive,
                provider: &provider,
                budget: 45,
                repair,
                feedback: Default::default(),
                bank: None,
                warm: None,
            };
            EvoEngineer::new(EvoVariant::Free).run(&ctx).unwrap()
        };
        let off = run(RepairPolicy::Off);
        assert_eq!(off.repair_policy, "off");
        assert_eq!(off.guard_rejected_trials, 0);
        assert_eq!(off.repair_attempts, 0);
        assert_eq!(off.trials, 45);

        let diagnose = run(RepairPolicy::Diagnose);
        assert_eq!(diagnose.repair_policy, "diagnose");
        assert_eq!(diagnose.trials, 45);
        assert_eq!(diagnose.repair_attempts, 0);
        assert!(
            diagnose.guard_rejected_trials > 0,
            "45 cat-6 trials must trip the stage-0 guard at least once"
        );
        // Stage-0 rejections are a subset of what stage 1 would have
        // rejected plus the guard's stricter static discipline; either
        // way they never count as compiled.
        assert!(diagnose.compiled_trials + diagnose.guard_rejected_trials <= 45);

        let repaired = run(RepairPolicy::Repair { max_attempts: 2 });
        assert_eq!(repaired.repair_policy, "repair:2");
        // Repair attempts consume budget: 45 units total, split between
        // generate calls and repair calls.
        assert_eq!(repaired.trials, 45);
        assert!(repaired.repair_attempts > 0, "no repairs fired in 45 trials");
        assert!(repaired.repaired_trials > 0, "no repair ever succeeded");
        assert!(repaired.repair_attempts < 45);
        // The evaluated trial groups: one terminal outcome each.
        let groups = repaired.trials - repaired.repair_attempts;
        assert!(repaired.guard_rejected_trials + repaired.compiled_trials <= groups);
        // Repair lowers stage-0 rejections vs diagnose (same stream of
        // emissions, some now mended).
        assert!(
            repaired.guard_rejected_trials < diagnose.guard_rejected_trials,
            "repair={} diagnose={}",
            repaired.guard_rejected_trials,
            diagnose.guard_rejected_trials
        );

        // Seed-determinism of the full repair loop.
        let again = run(RepairPolicy::Repair { max_attempts: 2 });
        assert_eq!(repaired.trajectory, again.trajectory);
        assert_eq!(repaired.prompt_tokens, again.prompt_tokens);
        assert_eq!(repaired.completion_tokens, again.completion_tokens);
        assert_eq!(repaired.guard_rejected_trials, again.guard_rejected_trials);
        assert_eq!(repaired.repaired_trials, again.repaired_trials);
    }

    #[test]
    fn insight_uses_more_prompt_tokens_than_free() {
        let evaluator = eval();
        let task = evaluator.registry.get("matmul_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let mk = |variant| {
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[0],
                seed: 3,
                archive: &archive,
                provider: &provider,
                budget: 30,
                repair: RepairPolicy::Off,
                feedback: Default::default(),
                bank: None,
                warm: None,
            };
            EvoEngineer::new(variant).run(&ctx).unwrap()
        };
        let free = mk(EvoVariant::Free);
        let full = mk(EvoVariant::Full);
        assert!(
            full.prompt_tokens > free.prompt_tokens,
            "full={} free={}",
            full.prompt_tokens,
            free.prompt_tokens
        );
    }
}
