//! The three EvoEngineer configurations (paper §4.2, Table 3):
//!
//! | Variant  | I1 | I2 | I3 | Population   |
//! |----------|----|----|----|--------------|
//! | Free     | ✓  | ✗  | ✗  | single best  |
//! | Insight  | ✓  | ✗  | ✓  | single best  |
//! | Full     | ✓  | ✓  | ✓  | elite (4)    |
//!
//! Free and Insight run a flat 45-trial improvement loop; Full uses
//! EoH-style generational structure (5 init + 10 generations × 4
//! offspring, §A.4).

use crate::population::{Elite, SingleBest};
use crate::traverse::GuidanceConfig;

use super::common::{KernelRunRecord, RunCtx, Session};
use super::Method;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvoVariant {
    Free,
    Insight,
    Full,
}

pub struct EvoEngineer {
    pub variant: EvoVariant,
}

impl EvoEngineer {
    pub fn new(variant: EvoVariant) -> Self {
        Self { variant }
    }

    fn config(&self) -> GuidanceConfig {
        match self.variant {
            EvoVariant::Free => GuidanceConfig::free(),
            EvoVariant::Insight => GuidanceConfig::insight(),
            EvoVariant::Full => GuidanceConfig::full(),
        }
    }
}

const IMPROVE: &str = "Improve the current kernel: propose a modified schedule that reduces \
execution time while preserving exact output semantics.";
const INIT: &str = "Design a new kernel from scratch for this operation, optimized for the \
target device.";

impl Method for EvoEngineer {
    fn name(&self) -> String {
        match self.variant {
            EvoVariant::Free => "EvoEngineer-Free".into(),
            EvoVariant::Insight => "EvoEngineer-Insight".into(),
            EvoVariant::Full => "EvoEngineer-Full".into(),
        }
    }

    fn run(&self, ctx: &RunCtx) -> KernelRunRecord {
        let name = self.name();
        let cfg = self.config();
        let mut session = Session::new(ctx, &name);

        match self.variant {
            EvoVariant::Free | EvoVariant::Insight => {
                let mut pop = SingleBest::new();
                session.bootstrap(&mut pop);
                while session
                    .trial(&cfg, &mut pop, IMPROVE, None, None)
                    .is_some()
                {}
            }
            EvoVariant::Full => {
                let mut pop = Elite::new(4);
                session.bootstrap(&mut pop);
                // Initialization: 5 from-scratch proposals (§A.4).
                for _ in 0..5 {
                    if session.trial(&cfg, &mut pop, INIT, None, None).is_none() {
                        break;
                    }
                }
                // 10 generations × 4 offspring = 40 trials.
                'gens: for _gen in 0..10 {
                    for _off in 0..4 {
                        if session.trial(&cfg, &mut pop, IMPROVE, None, None).is_none() {
                            break 'gens;
                        }
                    }
                }
            }
        }
        session.finish(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::MODELS;
    use crate::methods::common::Archive;
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    fn eval() -> Evaluator {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        Evaluator::new(reg, Runtime::new().unwrap())
    }

    #[test]
    fn free_consumes_exactly_the_budget() {
        let evaluator = eval();
        let task = evaluator.registry.get("relu_64").unwrap().clone();
        let archive = Archive::new();
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[0],
            seed: 1,
            archive: &archive,
            budget: 45,
        };
        let rec = EvoEngineer::new(EvoVariant::Free).run(&ctx);
        assert_eq!(rec.trials, 45);
        assert_eq!(rec.trajectory.len(), 45);
        assert!(rec.best_speedup >= 1.0);
        assert!(rec.compiled_trials <= rec.trials);
        assert!(rec.correct_trials <= rec.compiled_trials);
        assert!(rec.prompt_tokens > 0 && rec.completion_tokens > 0);
    }

    #[test]
    fn runs_are_seed_deterministic() {
        let evaluator = eval();
        let task = evaluator.registry.get("softmax_64").unwrap().clone();
        let archive = Archive::new();
        let run = |seed| {
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[2],
                seed,
                archive: &archive,
                budget: 20,
            };
            EvoEngineer::new(EvoVariant::Full).run(&ctx)
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.trajectory, b.trajectory);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        // different seed should (almost surely) differ somewhere
        assert!(
            a.trajectory != c.trajectory || a.prompt_tokens != c.prompt_tokens,
            "seeds produced identical runs"
        );
    }

    #[test]
    fn insight_uses_more_prompt_tokens_than_free() {
        let evaluator = eval();
        let task = evaluator.registry.get("matmul_64").unwrap().clone();
        let archive = Archive::new();
        let mk = |variant| {
            let ctx = RunCtx {
                evaluator: &evaluator,
                task: &task,
                model: &MODELS[0],
                seed: 3,
                archive: &archive,
                budget: 30,
            };
            EvoEngineer::new(variant).run(&ctx)
        };
        let free = mk(EvoVariant::Free);
        let full = mk(EvoVariant::Full);
        assert!(
            full.prompt_tokens > free.prompt_tokens,
            "full={} free={}",
            full.prompt_tokens,
            free.prompt_tokens
        );
    }
}
