//! EvoEngineer-Solution (EoH) — Evolution of Heuristics (Liu et al.,
//! 2024) as configured in the paper's §A.4: population 4, 5
//! initialization trials, then 10 generations in which the E1, E2, M1,
//! M2 operators each produce one offspring, after which the top 4
//! survive (elite truncation). EoH generates solution-insight pairs but
//! does **not** feed insights back (Table 2: I3 marked "generate but
//! don't leverage") — so `n_insights` is 0 here.

use crate::population::{Candidate, Elite, Population};
use crate::traverse::GuidanceConfig;

use super::common::{baseline_src, RunCtx, Session};
use super::engine::{GenerateStep, MethodState, Step};
use super::Method;

pub struct Eoh;

impl Eoh {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Eoh
    }
}

/// The four EoH operators, as prompt directives. E1/E2 are
/// exploration operators over multiple parents; M1/M2 mutate the
/// current elite.
const E1: &str = "Design a new kernel from scratch for this operation. You may draw \
inspiration from the historical solutions, but produce a structurally different schedule.";
const E2: &str = "Combine the historical solutions: crossover their schedule decisions into \
a single kernel that inherits the best choices of each.";
const M1: &str = "Mutate the current kernel: change part of its schedule to explore a \
neighbouring design.";
const M2: &str = "Tune the numeric parameters of the current kernel only (tile sizes, \
unroll factor, block size, register budget); keep its structure fixed.";

/// Schedule slot `s` (0-based over yielded `Generate` steps):
/// 5 × E1 initialization, then 10 generations × (E1, E2, M1, M2);
/// `None` past the 45-proposal schedule. The bool is whether the
/// operator acts on the current best explicitly (M1/M2).
fn slot(s: usize) -> Option<(&'static str, bool)> {
    if s < 5 {
        return Some((E1, false));
    }
    let g = s - 5;
    if g >= 40 {
        return None;
    }
    match g % 4 {
        0 => Some((E1, false)),
        1 => Some((E2, false)),
        2 => Some((M1, true)),
        _ => Some((M2, true)),
    }
}

/// Bootstrap, then walk the E1/E2/M1/M2 schedule. The operator
/// sequence is outcome-independent; M1/M2 pin the population's
/// current best as the parent at yield time, exactly like the
/// pre-redesign loop pinned `pop.best()` at trial time.
struct EohState {
    seeded: bool,
    idx: usize,
}

impl EohState {
    fn step_at(&self, session: &Session, s: usize) -> Option<GenerateStep> {
        let (op, pin_best) = slot(s)?;
        let parent: Option<Candidate> = if pin_best { session.pop().best() } else { None };
        Some(GenerateStep::new(GuidanceConfig::eoh(), op).with_parent(parent))
    }
}

impl MethodState for EohState {
    fn next(&mut self, session: &Session) -> Step {
        if !self.seeded {
            self.seeded = true;
            return Step::Evaluate(baseline_src(session.ctx));
        }
        if session.budget_left() == 0 {
            return Step::Done;
        }
        match self.step_at(session, self.idx) {
            Some(step) => {
                self.idx += 1;
                Step::Generate(step)
            }
            None => Step::Done,
        }
    }

    fn peek(&self, session: &Session, n: usize) -> Vec<GenerateStep> {
        (0..n).filter_map(|j| self.step_at(session, self.idx + j)).collect()
    }
}

impl Method for Eoh {
    fn name(&self) -> String {
        "EvoEngineer-Solution (EoH)".into()
    }

    fn start(&self, _ctx: &RunCtx) -> (Box<dyn Population>, Box<dyn MethodState>) {
        (Box::new(Elite::new(4)), Box::new(EohState { seeded: false, idx: 0 }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::Archive;
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    #[test]
    fn eoh_uses_45_trials() {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        let evaluator = Evaluator::new(reg, Runtime::new().unwrap());
        let task = evaluator.registry.get("gelu_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[1],
            seed: 2,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
            feedback: Default::default(),
            bank: None,
            warm: None,
        };
        let rec = Eoh::new().run(&ctx).unwrap();
        assert_eq!(rec.trials, 45); // 5 + 10*4
        assert!(rec.best_speedup >= 1.0);
    }
}
