//! EvoEngineer-Solution (EoH) — Evolution of Heuristics (Liu et al.,
//! 2024) as configured in the paper's §A.4: population 4, 5
//! initialization trials, then 10 generations in which the E1, E2, M1,
//! M2 operators each produce one offspring, after which the top 4
//! survive (elite truncation). EoH generates solution-insight pairs but
//! does **not** feed insights back (Table 2: I3 marked "generate but
//! don't leverage") — so `n_insights` is 0 here.

use crate::population::{Elite, Population};
use crate::traverse::GuidanceConfig;

use super::common::{KernelRunRecord, RunCtx, Session};
use super::Method;

pub struct Eoh;

impl Eoh {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Eoh
    }
}

/// The four EoH operators, as prompt directives. E1/E2 are
/// exploration operators over multiple parents; M1/M2 mutate the
/// current elite.
const E1: &str = "Design a new kernel from scratch for this operation. You may draw \
inspiration from the historical solutions, but produce a structurally different schedule.";
const E2: &str = "Combine the historical solutions: crossover their schedule decisions into \
a single kernel that inherits the best choices of each.";
const M1: &str = "Mutate the current kernel: change part of its schedule to explore a \
neighbouring design.";
const M2: &str = "Tune the numeric parameters of the current kernel only (tile sizes, \
unroll factor, block size, register budget); keep its structure fixed.";

impl Method for Eoh {
    fn name(&self) -> String {
        "EvoEngineer-Solution (EoH)".into()
    }

    fn run(&self, ctx: &RunCtx) -> crate::Result<KernelRunRecord> {
        let name = self.name();
        let cfg = GuidanceConfig::eoh();
        let mut session = Session::new(ctx, &name);
        let mut pop = Elite::new(4);
        session.bootstrap(&mut pop);

        // Initialization: 5 trials (§A.4).
        for _ in 0..5 {
            if session.trial(&cfg, &mut pop, E1, None, None)?.is_none() {
                return Ok(session.finish(&name));
            }
        }

        // 10 generations × (E1, E2, M1, M2).
        'gens: for _gen in 0..10 {
            for op in [E1, E2, M1, M2] {
                // M1/M2 act on the current best explicitly.
                let parent = if std::ptr::eq(op, M1) || std::ptr::eq(op, M2) {
                    pop.best()
                } else {
                    None
                };
                if session.trial(&cfg, &mut pop, op, parent, None)?.is_none() {
                    break 'gens;
                }
            }
        }
        Ok(session.finish(&name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evals::Evaluator;
    use crate::llm::{SimProvider, MODELS};
    use crate::methods::common::Archive;
    use crate::runtime::Runtime;
    use crate::tasks::TaskRegistry;
    use std::sync::Arc;

    #[test]
    fn eoh_uses_45_trials() {
        let reg = Arc::new(
            TaskRegistry::load(
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            )
            .unwrap(),
        );
        let evaluator = Evaluator::new(reg, Runtime::new().unwrap());
        let task = evaluator.registry.get("gelu_64").unwrap().clone();
        let archive = Archive::new();
        let provider = SimProvider::new();
        let ctx = RunCtx {
            evaluator: &evaluator,
            task: &task,
            model: &MODELS[1],
            seed: 2,
            archive: &archive,
            provider: &provider,
            budget: 45,
            repair: crate::methods::RepairPolicy::Off,
        };
        let rec = Eoh::new().run(&ctx).unwrap();
        assert_eq!(rec.trials, 45); // 5 + 10*4
        assert!(rec.best_speedup >= 1.0);
    }
}
