//! Run-record persistence: JSON-lines store under `results/`, so every
//! table/figure regenerator can work from a saved campaign instead of
//! re-running it. The same line format backs the campaign checkpoint
//! journal (DESIGN.md §8): each record carries its own
//! (method, model, op, seed) cell key, so a checkpoint is just a
//! records file written incrementally via [`Appender`] and read back
//! kill-tolerantly via [`load_lenient`].

use std::io::{BufRead, Write};
use std::path::Path;

use crate::methods::KernelRunRecord;
use crate::util::json;
use crate::{eyre, Result, WrapErr as _};

/// Write records as JSONL (one record per line).
pub fn save(path: impl AsRef<Path>, records: &[KernelRunRecord]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).context("creating results dir")?;
        }
    }
    let f = std::fs::File::create(&path).context("creating results file")?;
    let mut w = std::io::BufWriter::new(f);
    for r in records {
        w.write_all(r.to_json().to_string().as_bytes())?;
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Load a JSONL record file.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<KernelRunRecord>> {
    let f = std::fs::File::open(&path)
        .with_context(|| format!("opening {:?} — run `repro campaign` first", path.as_ref()))?;
    let r = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(&line).map_err(|e| eyre!("line {}: {e}", i + 1))?;
        out.push(KernelRunRecord::from_json(&v)?);
    }
    Ok(out)
}

/// Load a records/checkpoint file that may end in a torn line (the
/// process was killed mid-append). A missing file is an empty journal;
/// a corrupt *final* line is skipped with a warning; corruption
/// anywhere else is real damage and stays an error.
pub fn load_lenient(path: impl AsRef<Path>) -> Result<Vec<KernelRunRecord>> {
    let path = path.as_ref();
    if !path.exists() {
        return Ok(Vec::new());
    }
    let f = std::fs::File::open(path).context("opening records")?;
    let lines: Vec<String> = std::io::BufReader::new(f)
        .lines()
        .collect::<std::io::Result<_>>()?;
    let last_nonempty = lines.iter().rposition(|l| !l.trim().is_empty());
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = json::parse(line)
            .map_err(|e| eyre!("line {}: {e}", i + 1))
            .and_then(|v| KernelRunRecord::from_json(&v));
        match parsed {
            Ok(rec) => out.push(rec),
            Err(e) if Some(i) == last_nonempty => {
                eprintln!(
                    "warning: {}: dropping torn final line {} ({e:#})",
                    path.display(),
                    i + 1
                );
            }
            Err(e) => return Err(e).with_context(|| format!("{}: line {}", path.display(), i + 1)),
        }
    }
    Ok(out)
}

/// Incremental record writer: one flushed JSONL line per record, so a
/// killed campaign loses at most the line being written.
pub struct Appender {
    w: std::io::BufWriter<std::fs::File>,
}

impl Appender {
    /// Open `path` for appending, creating parent dirs as needed. A
    /// torn final line (killed mid-append) is truncated first, so the
    /// next record cannot concatenate onto partial bytes.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating checkpoint dir")?;
            }
        }
        let torn = crate::util::truncate_torn_tail(path.as_ref())
            .context("repairing checkpoint tail")?;
        if torn > 0 {
            eprintln!(
                "warning: {}: truncated {torn} bytes of torn final line",
                path.as_ref().display()
            );
        }
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .context("opening checkpoint for append")?;
        Ok(Self { w: std::io::BufWriter::new(f) })
    }

    /// Start a fresh journal at `path`, discarding any previous
    /// contents (a new, non-resumed campaign must not inherit cells
    /// from an older sweep).
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).context("creating checkpoint dir")?;
            }
        }
        let f = std::fs::File::create(&path).context("creating checkpoint")?;
        Ok(Self { w: std::io::BufWriter::new(f) })
    }

    pub fn append(&mut self, rec: &KernelRunRecord) -> Result<()> {
        self.w.write_all(rec.to_json().to_string().as_bytes())?;
        self.w.write_all(b"\n")?;
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: &str, seed: u64) -> KernelRunRecord {
        KernelRunRecord {
            method: "EvoEngineer-Free".into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            category: 1,
            seed,
            trials: 45,
            budget: 45,
            compiled_trials: 40,
            correct_trials: 30,
            guard_rejected_trials: 3,
            repaired_trials: 1,
            repair_attempts: 2,
            repair_policy: "repair:2".into(),
            goal: "speedup".into(),
            provider: "sim".into(),
            best_speedup: 2.5,
            best_pytorch_speedup: 1.2,
            any_valid: true,
            prompt_tokens: 1000,
            completion_tokens: 500,
            trajectory: vec![1.0, 2.0, 2.5],
            arms: vec![],
            best_src: Some("kernel x {\n  semantics: opt;\n}".into()),
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("evo_results_{}", std::process::id()));
        let path = dir.join("records.jsonl");
        let records = vec![rec("matmul_64", 0), rec("relu_64", 1)];
        save(&path, &records).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].op, "matmul_64");
        assert_eq!(back[1].seed, 1);
        assert_eq!(back[0].trajectory, vec![1.0, 2.0, 2.5]);
        assert_eq!(back[0].best_src, records[0].best_src);
        assert_eq!(back[0].best_speedup, 2.5);
        // Stage-0 bookkeeping survives the round-trip.
        assert_eq!(back[0].guard_rejected_trials, 3);
        assert_eq!(back[0].repaired_trials, 1);
        assert_eq!(back[0].repair_attempts, 2);
        assert_eq!(back[0].repair_policy, "repair:2");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_missing_is_helpful() {
        let err = load("/nonexistent/records.jsonl").unwrap_err();
        assert!(format!("{err:#}").contains("repro campaign"));
    }

    #[test]
    fn appender_matches_save_and_lenient_load_drops_torn_tail() {
        let dir = std::env::temp_dir().join(format!("evo_ckpt_{}", std::process::id()));
        let saved = dir.join("saved.jsonl");
        let appended = dir.join("appended.jsonl");
        let records = vec![rec("matmul_64", 0), rec("relu_64", 1)];
        save(&saved, &records).unwrap();
        {
            let mut a = Appender::open(&appended).unwrap();
            for r in &records {
                a.append(r).unwrap();
            }
        }
        assert_eq!(
            std::fs::read(&saved).unwrap(),
            std::fs::read(&appended).unwrap(),
            "incremental and batch writers must produce identical bytes"
        );

        // Torn final line: lenient load drops it, strict load errors.
        {
            use std::io::Write as _;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&appended).unwrap();
            write!(f, "{{\"method\":\"EvoEng").unwrap();
        }
        assert!(load(&appended).is_err());
        let back = load_lenient(&appended).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].op, "relu_64");

        // Re-opening for append repairs the tail first: the next
        // record lands on its own line, strict load works again, and
        // no merged-garbage interior line is left behind.
        {
            let mut a = Appender::open(&appended).unwrap();
            a.append(&rec("softmax_64", 2)).unwrap();
        }
        let back = load(&appended).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[2].op, "softmax_64");

        // Appender::create starts the journal over.
        {
            let mut a = Appender::create(&appended).unwrap();
            a.append(&rec("matmul_64", 9)).unwrap();
        }
        let back = load(&appended).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].seed, 9);

        // Missing file is an empty journal.
        assert!(load_lenient(dir.join("nope.jsonl")).unwrap().is_empty());

        // Interior corruption is real damage, not leniently skipped.
        let broken = dir.join("broken.jsonl");
        std::fs::write(&broken, "garbage\n").unwrap();
        {
            let mut a = Appender::open(&broken).unwrap();
            a.append(&rec("matmul_64", 0)).unwrap();
        }
        assert!(load_lenient(&broken).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
