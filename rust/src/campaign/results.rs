//! Run-record persistence: JSON-lines store under `results/`, so every
//! table/figure regenerator can work from a saved campaign instead of
//! re-running it.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::methods::KernelRunRecord;
use crate::util::json;
use crate::{eyre, Result, WrapErr as _};

/// Write records as JSONL (one record per line).
pub fn save(path: impl AsRef<Path>, records: &[KernelRunRecord]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).context("creating results dir")?;
        }
    }
    let f = std::fs::File::create(&path).context("creating results file")?;
    let mut w = std::io::BufWriter::new(f);
    for r in records {
        w.write_all(r.to_json().to_string().as_bytes())?;
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Load a JSONL record file.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<KernelRunRecord>> {
    let f = std::fs::File::open(&path)
        .with_context(|| format!("opening {:?} — run `repro campaign` first", path.as_ref()))?;
    let r = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(&line).map_err(|e| eyre!("line {}: {e}", i + 1))?;
        out.push(KernelRunRecord::from_json(&v)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: &str, seed: u64) -> KernelRunRecord {
        KernelRunRecord {
            method: "EvoEngineer-Free".into(),
            model: "GPT-4.1".into(),
            op: op.into(),
            category: 1,
            seed,
            trials: 45,
            compiled_trials: 40,
            correct_trials: 30,
            best_speedup: 2.5,
            best_pytorch_speedup: 1.2,
            any_valid: true,
            prompt_tokens: 1000,
            completion_tokens: 500,
            trajectory: vec![1.0, 2.0, 2.5],
            best_src: Some("kernel x {\n  semantics: opt;\n}".into()),
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("evo_results_{}", std::process::id()));
        let path = dir.join("records.jsonl");
        let records = vec![rec("matmul_64", 0), rec("relu_64", 1)];
        save(&path, &records).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].op, "matmul_64");
        assert_eq!(back[1].seed, 1);
        assert_eq!(back[0].trajectory, vec![1.0, 2.0, 2.5]);
        assert_eq!(back[0].best_src, records[0].best_src);
        assert_eq!(back[0].best_speedup, 2.5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_missing_is_helpful() {
        let err = load("/nonexistent/records.jsonl").unwrap_err();
        assert!(format!("{err:#}").contains("repro campaign"));
    }
}
