//! `campaign work`: the worker side of the wire-backed work plane
//! (DESIGN.md §15).
//!
//! A worker owns the whole per-process engine stack — evaluator,
//! provider, worker threads — and gets *only* its cells from the
//! coordinator: it mirrors the sweep knobs from `GET /config`, claims
//! cells one at a time, streams each cell's trial events back at every
//! flush boundary, uploads the new lines its local eval-cache /
//! transcript journals accrue, and posts the finished record. The
//! shared [`worker_loop`] drives cells exactly as the in-process plane
//! does — [`WirePlane`] only swaps the transport.
//!
//! **Failure stance.** Event/record delivery is what the coordinator's
//! byte-identity contract rests on, so a sink whose uploads ultimately
//! fail poisons the cell: `complete` turns into `release` (the cell is
//! re-offered) and the worker stops with an error instead of letting a
//! gap into the journal. A coordinator that stops answering after the
//! sweep has been reachable is the normal end-of-sweep race — another
//! worker finished the last cell and the coordinator exited — so the
//! worker drains quietly instead of failing.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::evals::Evaluator;
use crate::llm::{profile, provider, ProviderConfig, ProviderSpec, ReusePolicy};
use crate::methods::engine::{EventSink, TrialGate};
use crate::methods::{self, Archive, KernelRunRecord, RepairPolicy};
use crate::store::events::{self, TrialEvent};
use crate::store::{EvalStore, TranscriptStore};
use crate::tasks::TaskRegistry;
use crate::util::httpwire::{request_json, split_url, Url};
use crate::util::json::{self, Json};
use crate::{eyre, Result, WrapErr as _};

use super::plane::{lock_tolerant, worker_loop, ClaimedCell, WorkPlane, WorkerEnv};

/// How a `campaign work` process is parameterized (everything else is
/// mirrored from the coordinator's `/config`).
#[derive(Debug, Clone, Default)]
pub struct WorkOpts {
    /// Optional startup assertion: the raw `--provider` string the
    /// worker was launched with, if any. The worker *always* runs the
    /// coordinator-resolved spec from `/config`; a locally-passed spec
    /// that parses to anything different is a startup error (silently
    /// running a different backend than the operator asked for would
    /// poison the sweep's byte-identity).
    pub provider: Option<String>,
    /// Local transcript journal: records this worker's live provider
    /// calls, serves warm replays, and is delta-uploaded to the
    /// coordinator for merging.
    pub transcripts: Option<PathBuf>,
    /// The local eval-cache journal backing the caller's evaluator
    /// (delta-uploaded for merging); `None` = no cache, no uploads.
    pub cache: Option<PathBuf>,
    /// Local deposit-side kernel bank (`--bank`, DESIGN.md §18): this
    /// worker's elites are journaled here. Deposits are per-process
    /// (merge banks later with `bank import`); the *consumption* side
    /// — the warm-start snapshot — always comes from the coordinator.
    pub bank: Option<PathBuf>,
    /// Worker threads (0 = number of CPUs).
    pub concurrency: usize,
    pub quiet: bool,
    /// Simulated mid-cell kill (test hook, same semantics as the
    /// in-process `--stop-after-trials`): the gate trips, claimed
    /// cells are released back to the coordinator, the process exits.
    pub stop_after_trials: usize,
}

/// What a drained worker did.
#[derive(Debug, Clone, Copy)]
pub struct WorkSummary {
    pub cells_completed: usize,
    /// The trial gate tripped (simulated kill); released cells await
    /// the next claimant.
    pub interrupted: bool,
}

// ---------------------------------------------------------------------
// Wire client

const CLAIM_IDLE_POLL: Duration = Duration::from_millis(200);
const RPC_TIMEOUT: Duration = Duration::from_secs(30);
const RPC_ATTEMPTS: u32 = 3;

/// Thin JSON-RPC-ish client over [`crate::util::httpwire`].
struct WireClient {
    base: Url,
}

impl WireClient {
    fn new(url: &str) -> Result<Self> {
        Ok(Self { base: split_url(url)? })
    }

    fn rpc(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let body = body.map(|b| b.to_string()).unwrap_or_default();
        let (status, text) = request_json(&self.base, method, path, &body, RPC_TIMEOUT)?;
        let v = json::parse(&text)
            .map_err(|e| eyre!("coordinator sent unparseable JSON for {path}: {e}"))?;
        Ok((status, v))
    }

    /// [`WireClient::rpc`] with retries on *transport* errors (the
    /// serial coordinator briefly saturating); HTTP error statuses are
    /// returned to the caller, they are protocol answers.
    fn rpc_retry(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let mut delay = Duration::from_millis(100);
        let mut last = None;
        for attempt in 0..RPC_ATTEMPTS {
            match self.rpc(method, path, body) {
                Ok(reply) => return Ok(reply),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < RPC_ATTEMPTS {
                        std::thread::sleep(delay);
                        delay *= 2;
                    }
                }
            }
        }
        Err(last.expect("at least one attempt"))
    }
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(String::from)
        .ok_or_else(|| eyre!("coordinator reply missing string field `{key}`"))
}

fn get_num(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| eyre!("coordinator reply missing numeric field `{key}`"))
}

// ---------------------------------------------------------------------
// Store delta uploads

/// One local journal being delta-uploaded: everything past `offset`
/// that ends in a newline is new, complete lines to ship. The offset
/// advances only after the coordinator accepts the batch, so a failed
/// upload is retried at the next boundary (the coordinator dedups).
struct UploadChannel<S> {
    store: Arc<S>,
    path: PathBuf,
    offset: Mutex<u64>,
}

impl<S> UploadChannel<S> {
    fn new(store: Arc<S>, path: PathBuf) -> Self {
        let offset = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        Self { store, path, offset: Mutex::new(offset) }
    }
}

/// Read the complete lines between `offset` and the last newline.
/// Returns the lines and the offset they advance to. Also the tailing
/// primitive behind `campaign watch` ([`super::watch`]).
pub(crate) fn read_delta(path: &Path, offset: u64) -> Result<(Vec<String>, u64)> {
    use std::os::unix::fs::FileExt as _;
    let Ok(meta) = std::fs::metadata(path) else {
        return Ok((Vec::new(), offset));
    };
    if meta.len() <= offset {
        return Ok((Vec::new(), offset));
    }
    let f = std::fs::File::open(path).context("opening journal for delta upload")?;
    let mut buf = vec![0u8; (meta.len() - offset) as usize];
    f.read_exact_at(&mut buf, offset)
        .context("reading journal delta")?;
    let Some(last_nl) = buf.iter().rposition(|&b| b == b'\n') else {
        return Ok((Vec::new(), offset)); // only a torn tail so far
    };
    let text = std::str::from_utf8(&buf[..last_nl + 1])
        .context("journal delta is not UTF-8")?;
    let lines = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(String::from)
        .collect();
    Ok((lines, offset + last_nl as u64 + 1))
}

/// Ships new local-journal lines to the coordinator at every flush
/// boundary. Shared by all of one worker's cells.
struct Uploader {
    client: Arc<WireClient>,
    evals: Option<UploadChannel<EvalStore>>,
    transcripts: Option<UploadChannel<TranscriptStore>>,
}

impl Uploader {
    /// Flush the local stores (group-commit durability first — the
    /// engine's own store flush runs *after* the sinks), then upload
    /// whatever new complete lines appeared.
    fn upload_new(&self) -> Result<()> {
        if let Some(ch) = &self.evals {
            ch.store.flush()?;
            Self::ship(&self.client, "eval", &ch.path, &ch.offset)?;
        }
        if let Some(ch) = &self.transcripts {
            ch.store.flush()?;
            Self::ship(&self.client, "transcript", &ch.path, &ch.offset)?;
        }
        Ok(())
    }

    fn ship(
        client: &WireClient,
        kind: &str,
        path: &Path,
        offset: &Mutex<u64>,
    ) -> Result<()> {
        let mut off = lock_tolerant(offset);
        let (lines, new_off) = read_delta(path, *off)?;
        if lines.is_empty() {
            return Ok(());
        }
        let body = Json::obj(vec![
            ("kind", Json::Str(kind.into())),
            ("lines", Json::Arr(lines.into_iter().map(Json::Str).collect())),
        ]);
        let (status, reply) = client.rpc_retry("POST", "/upload", Some(&body))?;
        if status != 200 {
            return Err(eyre!("coordinator rejected {kind} upload: HTTP {status} {reply}"));
        }
        *off = new_off;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The per-cell event sink

/// Buffers a claimed cell's trial events and posts them (with the
/// store deltas) at every engine flush boundary. The sink API is
/// infallible by contract, so delivery failures latch [`broken`]
/// instead — [`WirePlane::complete`] refuses to complete a cell whose
/// event stream has a gap and releases it for a re-run.
struct WireCellSink {
    client: Arc<WireClient>,
    uploader: Arc<Uploader>,
    idx: usize,
    epoch: u64,
    buf: Mutex<Vec<TrialEvent>>,
    broken: AtomicBool,
}

impl WireCellSink {
    fn new(client: Arc<WireClient>, uploader: Arc<Uploader>, idx: usize, epoch: u64) -> Self {
        Self {
            client,
            uploader,
            idx,
            epoch,
            buf: Mutex::new(Vec::new()),
            broken: AtomicBool::new(false),
        }
    }

    fn try_flush(&self) -> Result<()> {
        self.uploader.upload_new()?;
        let staged: Vec<TrialEvent> = {
            let mut g = lock_tolerant(&self.buf);
            std::mem::take(&mut *g)
        };
        if staged.is_empty() {
            return Ok(());
        }
        let body = Json::obj(vec![
            ("idx", Json::Num(self.idx as f64)),
            ("epoch", Json::Num(self.epoch as f64)),
            (
                "events",
                Json::Arr(staged.iter().map(events::event_to_json).collect()),
            ),
        ]);
        match self.client.rpc_retry("POST", "/events", Some(&body)) {
            Ok((200, _)) => Ok(()),
            Ok((status, reply)) => {
                // Put the batch back so a later flush retries it —
                // unless the epoch is stale, in which case the cell is
                // no longer ours to journal.
                if status != 409 {
                    lock_tolerant(&self.buf).splice(0..0, staged);
                }
                Err(eyre!("coordinator rejected event batch: HTTP {status} {reply}"))
            }
            Err(e) => {
                lock_tolerant(&self.buf).splice(0..0, staged);
                Err(e)
            }
        }
    }
}

impl EventSink for WireCellSink {
    fn emit(&self, ev: &TrialEvent) {
        lock_tolerant(&self.buf).push(ev.clone());
    }

    fn flush(&self) {
        if let Err(e) = self.try_flush() {
            self.broken.store(true, Ordering::Relaxed);
            eprintln!("warning: event/store upload failed: {e:#}");
        }
    }
}

// ---------------------------------------------------------------------
// The wire plane

/// [`WorkPlane`] over HTTP/JSON: cells come from `POST /claim`,
/// results go back via `/events`, `/upload`, `/complete`, `/release`,
/// `/fail`.
struct WirePlane {
    client: Arc<WireClient>,
    uploader: Arc<Uploader>,
    registry: Arc<TaskRegistry>,
    local_transcripts: Option<Arc<TranscriptStore>>,
    quiet: bool,
    /// Coordinator became unreachable after the sweep had been healthy:
    /// the end-of-sweep drain, not an error.
    gone: AtomicBool,
    failed: AtomicBool,
    interrupted: AtomicBool,
    warmed: AtomicBool,
    completed: AtomicUsize,
    first_error: Mutex<Option<anyhow::Error>>,
    /// Sinks of currently-claimed cells, by grid index.
    active: Mutex<HashMap<usize, Arc<WireCellSink>>>,
}

impl WirePlane {
    fn drained(&self, why: &str) -> Option<ClaimedCell> {
        if !self.gone.swap(true, Ordering::Relaxed) && !self.quiet {
            eprintln!("work: coordinator unreachable ({why}); treating sweep as drained");
        }
        None
    }

    /// Pull the coordinator's merged transcript journal into the local
    /// store, so a re-claimed cell's completed trials replay from the
    /// dead claimant's recorded calls instead of re-generating live.
    fn warm_from_coordinator(&self) -> Result<()> {
        let Some(store) = &self.local_transcripts else {
            return Ok(()); // deterministic provider: replay regenerates
        };
        if self.warmed.swap(true, Ordering::Relaxed) {
            return Ok(());
        }
        let (status, v) = self.client.rpc_retry("GET", "/warm", None)?;
        if status != 200 {
            return Err(eyre!("warm-state fetch failed: HTTP {status}"));
        }
        let Some(lines) = v.get("lines").and_then(|l| l.as_arr()) else {
            return Err(eyre!("warm-state reply missing `lines`"));
        };
        let mut merged = 0usize;
        for line in lines {
            if let Some(text) = line.as_str() {
                if store.ingest_line(text)? {
                    merged += 1;
                }
            }
        }
        if merged > 0 && !self.quiet {
            eprintln!("work: warmed {merged} transcript line(s) from the coordinator");
        }
        Ok(())
    }

    fn post_cell(&self, path: &str, cell: &ClaimedCell, extra: Vec<(&str, Json)>) -> Result<()> {
        let mut pairs = vec![
            ("idx", Json::Num(cell.idx as f64)),
            ("epoch", Json::Num(cell.epoch as f64)),
        ];
        pairs.extend(extra);
        let (status, reply) = self.client.rpc_retry("POST", path, Some(&Json::obj(pairs)))?;
        if status != 200 {
            return Err(eyre!("coordinator rejected {path}: HTTP {status} {reply}"));
        }
        Ok(())
    }

    fn transport_error(&self, err: anyhow::Error) {
        self.failed.store(true, Ordering::Relaxed);
        let mut g = lock_tolerant(&self.first_error);
        if g.is_none() {
            *g = Some(err);
        }
    }
}

impl WorkPlane for WirePlane {
    fn claim(&self) -> Result<Option<ClaimedCell>> {
        loop {
            if self.gone.load(Ordering::Relaxed)
                || self.failed.load(Ordering::Relaxed)
                || self.interrupted.load(Ordering::Relaxed)
            {
                return Ok(None);
            }
            let reply = self.client.rpc_retry("POST", "/claim", Some(&Json::obj(vec![])));
            let (status, v) = match reply {
                Ok(r) => r,
                // /config succeeded earlier, so unreachable now is the
                // end-of-sweep shutdown race.
                Err(_) => return Ok(self.drained("claim")),
            };
            if status != 200 {
                return Err(eyre!("claim failed: HTTP {status} {v}"));
            }
            match get_str(&v, "status")?.as_str() {
                "idle" => {
                    std::thread::sleep(CLAIM_IDLE_POLL);
                    continue;
                }
                "done" => return Ok(None),
                "failed" => {
                    return Err(eyre!(
                        "coordinator reported sweep failure: {}",
                        get_str(&v, "error").unwrap_or_else(|_| "unknown".into())
                    ));
                }
                "cell" => {}
                other => return Err(eyre!("unknown claim status `{other}`")),
            }

            let idx = get_num(&v, "idx")? as usize;
            let epoch = get_num(&v, "epoch")?;
            let method_name = get_str(&v, "method")?;
            let model_name = get_str(&v, "model")?;
            let op_name = get_str(&v, "op")?;
            let seed: u64 = get_str(&v, "seed")?
                .parse()
                .map_err(|e| eyre!("bad seed in claim: {e}"))?;
            let resumed = v.get("resumed").and_then(|b| b.as_bool()).unwrap_or(false);
            let mut verify = Vec::new();
            if let Some(pairs) = v.get("verify").and_then(|p| p.as_arr()) {
                for pair in pairs {
                    let items = pair
                        .as_arr()
                        .ok_or_else(|| eyre!("bad verify pair in claim"))?;
                    match items {
                        [t, h] => verify.push((
                            t.as_usize().ok_or_else(|| eyre!("bad verify trial"))?,
                            h.as_str().ok_or_else(|| eyre!("bad verify hash"))?.to_string(),
                        )),
                        _ => return Err(eyre!("bad verify pair in claim")),
                    }
                }
            }

            let method = methods::by_name(&method_name).map(Arc::from)?;
            let model = profile::by_name(&model_name)
                .ok_or_else(|| eyre!("coordinator offered unknown model `{model_name}`"))?;
            let op = self
                .registry
                .get(&op_name)
                .ok_or_else(|| {
                    eyre!("coordinator offered op `{op_name}` missing from local artifacts")
                })?
                .clone();
            if resumed {
                self.warm_from_coordinator()?;
            }
            let sink = Arc::new(WireCellSink::new(
                self.client.clone(),
                self.uploader.clone(),
                idx,
                epoch,
            ));
            lock_tolerant(&self.active).insert(idx, sink.clone());
            if !self.quiet {
                eprintln!(
                    "work: claimed cell {idx} (epoch {epoch}): {method_name} / \
                     {model_name} / {op_name} / seed {seed}{}",
                    if resumed { " [resumed]" } else { "" }
                );
            }
            return Ok(Some(ClaimedCell {
                idx,
                epoch,
                method,
                model,
                op,
                seed,
                resumed,
                verify_replay: verify,
                sinks: vec![sink],
            }));
        }
    }

    fn complete(&self, cell: &ClaimedCell, rec: KernelRunRecord) -> Result<()> {
        let sink = lock_tolerant(&self.active).remove(&cell.idx);
        if let Some(sink) = &sink {
            // Catch anything staged since the engine's final boundary.
            sink.flush();
            if sink.broken.load(Ordering::Relaxed) {
                // The event stream has a gap: completing would
                // finalize a journal missing events. Hand the cell
                // back instead.
                if let Err(e) = self.post_cell("/release", cell, vec![]) {
                    eprintln!("warning: releasing broken cell failed: {e:#}");
                }
                return Err(eyre!(
                    "{}: event uploads failed; cell released for re-run",
                    cell.describe()
                ));
            }
        }
        match self.post_cell("/complete", cell, vec![("record", rec.to_json())]) {
            Ok(()) => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                if self.gone.load(Ordering::Relaxed) {
                    return Ok(());
                }
                // Transport death at the very end of the sweep is the
                // shutdown race; a protocol rejection (stale epoch,
                // duplicate) means another claimant finished the cell.
                // Neither is this worker's failure.
                if !self.quiet {
                    eprintln!("work: completion of cell {} not accepted: {e:#}", cell.idx);
                }
                self.drained("complete");
                Ok(())
            }
        }
    }

    fn interrupt(&self, cell: &ClaimedCell) {
        self.interrupted.store(true, Ordering::Relaxed);
        if let Some(sink) = lock_tolerant(&self.active).remove(&cell.idx) {
            sink.flush(); // ship the completed trials' events first
        }
        if let Err(e) = self.post_cell("/release", cell, vec![]) {
            eprintln!("warning: releasing interrupted cell failed: {e:#}");
        } else if !self.quiet {
            eprintln!(
                "work: released cell {} after simulated kill; next claimant resumes it",
                cell.idx
            );
        }
    }

    fn fail(&self, cell: &ClaimedCell, err: anyhow::Error) {
        self.failed.store(true, Ordering::Relaxed);
        lock_tolerant(&self.active).remove(&cell.idx);
        let msg = format!("{}: {:#}", cell.describe(), err);
        if let Err(e) = self.post_cell("/fail", cell, vec![("error", Json::Str(msg.clone()))]) {
            eprintln!("warning: reporting failure to coordinator failed: {e:#}");
        }
        let mut g = lock_tolerant(&self.first_error);
        if g.is_none() {
            *g = Some(err.context(cell.describe()));
        }
    }
}

// ---------------------------------------------------------------------
// Entry point

/// Run a worker against a coordinator at `url` until the sweep drains.
///
/// The caller supplies the evaluator (with any local cache already
/// attached — pass the same path in [`WorkOpts::cache`] so its new
/// lines are uploaded); everything sweep-defining (budget, repair
/// policy, provider, prefetch) is mirrored from the coordinator.
pub fn work(url: &str, evaluator: Evaluator, opts: &WorkOpts) -> Result<WorkSummary> {
    let client = Arc::new(WireClient::new(url)?);

    // The coordinator may still be binding (CI starts both at once):
    // patiently retry the initial config fetch.
    let mut config = None;
    for _ in 0..50 {
        match client.rpc("GET", "/config", None) {
            Ok((200, v)) => {
                config = Some(v);
                break;
            }
            Ok((status, v)) => return Err(eyre!("config fetch failed: HTTP {status} {v}")),
            Err(_) => std::thread::sleep(CLAIM_IDLE_POLL),
        }
    }
    let config = config.ok_or_else(|| eyre!("coordinator at {url} is not answering"))?;
    let budget = get_num(&config, "budget")? as usize;
    let prefetch = get_num(&config, "prefetch")? as usize;
    let repair = RepairPolicy::parse(&get_str(&config, "repair")?)?;
    // Absent on pre-goal coordinators: default (== plain speedup).
    let feedback = match config.get("goal").and_then(|g| g.as_str()) {
        Some(label) => crate::feedback::FeedbackConfig::parse(label)?,
        None => crate::feedback::FeedbackConfig::default(),
    };
    // The coordinator-resolved spec is authoritative (it already
    // resolved any `ensemble:@file.json` form, so workers need no local
    // config file). A locally-passed `--provider` is only an assertion.
    let spec = ProviderSpec::parse(&get_str(&config, "provider")?)?;
    if let Some(local) = &opts.provider {
        let local_spec = ProviderSpec::parse(local)
            .context("parsing this worker's --provider assertion")?;
        if local_spec != spec {
            return Err(eyre!(
                "provider mismatch: this worker was launched with --provider {} but the \
                 coordinator's sweep runs {} — drop the flag (the coordinator's /config is \
                 authoritative) or point the worker at the right coordinator",
                local_spec.label(),
                spec.label()
            ));
        }
    }

    // The provider stack mirrors the in-process campaign's, built by
    // the same typed builder: base backend, wrapped in a recording
    // provider over the local transcript journal with reuse on — a
    // re-claimed cell's completed trials replay from journaled calls
    // (warmed from the coordinator) with zero live generation.
    let (llm_provider, local_transcripts) = provider::build_with_journal(
        &ProviderConfig::new(spec.clone())
            .transcripts(opts.transcripts.clone())
            .reuse(ReusePolicy::Resume),
    )?;

    let uploader = Arc::new(Uploader {
        client: client.clone(),
        evals: match (&opts.cache, evaluator.store()) {
            (Some(path), Some(store)) => {
                Some(UploadChannel::new(store.clone(), path.clone()))
            }
            _ => None,
        },
        transcripts: local_transcripts
            .as_ref()
            .zip(opts.transcripts.as_ref())
            .map(|(store, path)| UploadChannel::new(store.clone(), path.clone())),
    });

    let concurrency = if opts.concurrency == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        opts.concurrency
    };
    if !opts.quiet {
        eprintln!(
            "work: joined {url} ({concurrency} workers, budget {budget}, repair {}, \
             provider {})",
            repair.label(),
            spec.label()
        );
    }

    // Warm-start snapshot (DESIGN.md §18): the coordinator ships its
    // bank's canonical lines, so every worker consumes the identical
    // elite set a local `--warm-start` run would. Absent key =
    // pre-bank coordinator = cold start.
    let warm = match config.get("warm_start").and_then(|w| w.as_bool()) {
        Some(true) => {
            let (status, v) = client.rpc_retry("GET", "/bank", None)?;
            if status != 200 {
                return Err(eyre!("bank snapshot fetch failed: HTTP {status}"));
            }
            let Some(lines) = v.get("lines").and_then(|l| l.as_arr()) else {
                return Err(eyre!("bank snapshot reply missing `lines`"));
            };
            let lines: Vec<String> = lines
                .iter()
                .filter_map(|l| l.as_str().map(String::from))
                .collect();
            let warm = crate::bank::KernelBank::from_lines(&lines);
            if !opts.quiet {
                eprintln!("work: warm-starting from {} bank elite(s)", warm.len());
            }
            Some(warm)
        }
        _ => None,
    };
    let bank = match &opts.bank {
        Some(path) => Some(crate::bank::KernelBank::open(path)?),
        None => None,
    };

    let plane = WirePlane {
        client,
        uploader,
        registry: evaluator.registry.clone(),
        local_transcripts,
        quiet: opts.quiet,
        gone: AtomicBool::new(false),
        failed: AtomicBool::new(false),
        interrupted: AtomicBool::new(false),
        warmed: AtomicBool::new(false),
        completed: AtomicUsize::new(0),
        first_error: Mutex::new(None),
        active: Mutex::new(HashMap::new()),
    };
    let archive = Archive::new();
    if let Some(warm) = &warm {
        // Same trial-0 archive view as a local warm-started run.
        super::seed_archive_from_bank(&archive, warm);
    }
    let trial_gate =
        (opts.stop_after_trials > 0).then(|| Arc::new(TrialGate::new(opts.stop_after_trials)));
    let env = WorkerEnv {
        evaluator: &evaluator,
        archive: &archive,
        provider: llm_provider,
        budget,
        repair,
        feedback,
        prefetch,
        trial_gate,
        bank: bank.clone(),
        warm,
    };
    std::thread::scope(|scope| {
        for _ in 0..concurrency {
            let plane = &plane;
            let env = &env;
            scope.spawn(move || {
                if let Err(e) = worker_loop(plane, env) {
                    plane.transport_error(e);
                }
            });
        }
    });

    if let Some(e) = lock_tolerant(&plane.first_error).take() {
        return Err(e);
    }

    // Persist this process's cache hit/miss counters for `cache stats`.
    if let Some(store) = evaluator.store() {
        if let Err(e) = store.flush_session_stats() {
            eprintln!("warning: eval-cache stats flush failed: {e:#}");
        }
    }
    if let Some(bank) = &bank {
        if let Err(e) = bank.flush() {
            eprintln!("warning: kernel-bank flush failed: {e:#}");
        }
        if !opts.quiet && bank.deposits() > 0 {
            eprintln!("work: deposited {} new elite(s) into the local bank", bank.deposits());
        }
    }

    let summary = WorkSummary {
        cells_completed: plane.completed.load(Ordering::Relaxed),
        interrupted: plane.interrupted.load(Ordering::Relaxed),
    };
    if !opts.quiet {
        eprintln!(
            "work: drained after {} cell(s){}",
            summary.cells_completed,
            if summary.interrupted { " (interrupted by the trial gate)" } else { "" }
        );
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_delta_ships_only_complete_new_lines() {
        let dir = std::env::temp_dir().join(format!("evo_delta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("j.jsonl");

        // Missing file: nothing to ship, offset unchanged.
        let (lines, off) = read_delta(&p, 0).unwrap();
        assert!(lines.is_empty());
        assert_eq!(off, 0);

        std::fs::write(&p, "{\"a\":1}\n{\"b\":2}\n{\"c\":").unwrap();
        let (lines, off) = read_delta(&p, 0).unwrap();
        assert_eq!(lines, vec!["{\"a\":1}".to_string(), "{\"b\":2}".to_string()]);
        assert_eq!(off as usize, "{\"a\":1}\n{\"b\":2}\n".len(), "torn tail must not advance");

        // Nothing new past the offset until the torn line completes.
        let (lines, off2) = read_delta(&p, off).unwrap();
        assert!(lines.is_empty());
        assert_eq!(off2, off);
        std::fs::write(&p, "{\"a\":1}\n{\"b\":2}\n{\"c\":3}\n").unwrap();
        let (lines, off3) = read_delta(&p, off).unwrap();
        assert_eq!(lines, vec!["{\"c\":3}".to_string()]);
        assert_eq!(off3 as usize, std::fs::metadata(&p).unwrap().len() as usize);
        std::fs::remove_dir_all(dir).ok();
    }
}
