//! Campaign orchestrator: the worker pool that sweeps the
//! method × model × op × seed grid (the paper's experimental matrix:
//! 6 methods × 3 LLMs × 91 ops × 3 independent runs, 45 trials each)
//! and persists run records.
//!
//! Each (method, model, op, seed) run is independent CPU-bound work
//! (SimLLM sampling + compile pipeline + cost model; the PJRT
//! functional verdicts are memoized inside the shared [`Evaluator`]).
//! The environment is offline (no tokio), so the pool is a fixed set of
//! std::thread workers draining a shared job queue — the runs are
//! uniform enough that work stealing buys nothing.

pub mod results;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::evals::Evaluator;
use crate::llm::{profile, ModelProfile};
use crate::methods::{self, Archive, KernelRunRecord, RunCtx};
use crate::tasks::OpTask;
use crate::{eyre, Result};

/// Campaign sweep description.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Method names (see [`methods::all_methods`]); empty = all six.
    pub methods: Vec<String>,
    /// Model names; empty = all three.
    pub models: Vec<String>,
    /// Independent runs (the paper uses seeds {0,1,2}).
    pub seeds: Vec<u64>,
    /// Substring filter on op names; empty = all 91.
    pub op_filter: String,
    /// Cap on number of ops after filtering (0 = no cap).
    pub max_ops: usize,
    /// Trial budget per run (the paper's 45).
    pub budget: usize,
    /// Worker parallelism (0 = number of CPUs).
    pub concurrency: usize,
    /// Progress lines to stderr.
    pub quiet: bool,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            methods: vec![],
            models: vec![],
            seeds: vec![0, 1, 2],
            op_filter: String::new(),
            max_ops: 0,
            budget: crate::TRIAL_BUDGET,
            concurrency: 0,
            quiet: false,
        }
    }
}

fn resolve_models(names: &[String]) -> Result<Vec<&'static ModelProfile>> {
    if names.is_empty() {
        return Ok(profile::MODELS.iter().collect());
    }
    names
        .iter()
        .map(|n| profile::by_name(n).ok_or_else(|| eyre!("unknown model `{n}`")))
        .collect()
}

fn resolve_method_names(names: &[String]) -> Result<Vec<String>> {
    if names.is_empty() {
        return Ok(methods::all_methods().iter().map(|m| m.name()).collect());
    }
    names
        .iter()
        .map(|n| {
            methods::by_name(n)
                .map(|m| m.name())
                .ok_or_else(|| eyre!("unknown method `{n}`"))
        })
        .collect()
}

/// One grid point.
#[derive(Clone)]
struct Job {
    method: String,
    model: &'static ModelProfile,
    op: OpTask,
    seed: u64,
}

/// Run the sweep; returns records sorted by (method, model, op, seed)
/// for deterministic output regardless of scheduling.
pub fn run(cfg: &CampaignConfig, evaluator: Evaluator) -> Result<Vec<KernelRunRecord>> {
    let models = resolve_models(&cfg.models)?;
    let method_names = resolve_method_names(&cfg.methods)?;
    let mut ops: Vec<OpTask> = evaluator
        .registry
        .ops
        .iter()
        .filter(|o| cfg.op_filter.is_empty() || o.name.contains(&cfg.op_filter))
        .cloned()
        .collect();
    if cfg.max_ops > 0 && ops.len() > cfg.max_ops {
        // Keep the category mix representative: stable stratified cut.
        ops = stratified_cut(ops, cfg.max_ops);
    }
    anyhow::ensure!(!ops.is_empty(), "no ops match the filter");

    let mut jobs = Vec::new();
    for method in &method_names {
        for model in &models {
            for op in &ops {
                for &seed in &cfg.seeds {
                    jobs.push(Job {
                        method: method.clone(),
                        model,
                        op: op.clone(),
                        seed,
                    });
                }
            }
        }
    }
    let total = jobs.len();
    let concurrency = if cfg.concurrency == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.concurrency
    }
    .min(total.max(1));
    if !cfg.quiet {
        eprintln!(
            "campaign: {} methods x {} models x {} ops x {} seeds = {} runs ({} workers)",
            method_names.len(),
            models.len(),
            ops.len(),
            cfg.seeds.len(),
            total,
            concurrency
        );
    }

    let archive = Archive::new();
    let budget = cfg.budget;
    let quiet = cfg.quiet;
    let jobs = Arc::new(jobs);
    let next = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicUsize::new(0));
    let out: Arc<Mutex<Vec<Option<KernelRunRecord>>>> =
        Arc::new(Mutex::new(vec![None; total]));

    std::thread::scope(|scope| {
        for _ in 0..concurrency {
            let jobs = jobs.clone();
            let next = next.clone();
            let done = done.clone();
            let out = out.clone();
            let evaluator = evaluator.clone();
            let archive = archive.clone();
            scope.spawn(move || loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= jobs.len() {
                    break;
                }
                let job = &jobs[idx];
                let method = methods::by_name(&job.method).expect("method resolved above");
                let ctx = RunCtx {
                    evaluator: &evaluator,
                    task: &job.op,
                    model: job.model,
                    seed: job.seed,
                    archive: &archive,
                    budget,
                };
                let rec = method.run(&ctx);
                out.lock().unwrap()[idx] = Some(rec);
                let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                if !quiet && (d % 200 == 0 || d == jobs.len()) {
                    eprintln!("  {d}/{} runs complete", jobs.len());
                }
            });
        }
    });

    let mut records: Vec<KernelRunRecord> = Arc::try_unwrap(out)
        .map_err(|_| eyre!("worker leak"))?
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job produced a record"))
        .collect();
    records.sort_by(|a, b| {
        (&a.method, &a.model, &a.op, a.seed).cmp(&(&b.method, &b.model, &b.op, b.seed))
    });
    Ok(records)
}

/// Stratified cut preserving category proportions (used by quick runs).
fn stratified_cut(ops: Vec<OpTask>, max: usize) -> Vec<OpTask> {
    let mut by_cat: Vec<Vec<OpTask>> = vec![Vec::new(); 7];
    let total = ops.len();
    for op in ops {
        by_cat[op.category as usize].push(op);
    }
    let mut out = Vec::with_capacity(max);
    for bucket in by_cat.iter() {
        if bucket.is_empty() {
            continue;
        }
        let want = ((bucket.len() * max) as f64 / total as f64).round().max(1.0) as usize;
        out.extend(bucket.iter().take(want).cloned());
    }
    out.truncate(max);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_defaults() {
        assert_eq!(resolve_models(&[]).unwrap().len(), 3);
        assert_eq!(resolve_method_names(&[]).unwrap().len(), 6);
        assert!(resolve_models(&["martian".into()]).is_err());
    }

    #[test]
    fn stratified_cut_keeps_mix() {
        let reg = crate::tasks::TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        let cut = stratified_cut(reg.ops.clone(), 12);
        assert!(cut.len() <= 12);
        let cats: std::collections::HashSet<u8> = cut.iter().map(|o| o.category).collect();
        assert!(cats.len() >= 5, "{cats:?}");
    }
}
