//! Campaign orchestrator: the worker pool that sweeps the
//! method × model × op × seed grid (the paper's experimental matrix:
//! 6 methods × 3 LLMs × 91 ops × 3 independent runs, 45 trials each)
//! and persists run records.
//!
//! Each (method, model, op, seed) run is independent CPU-bound work
//! (SimLLM sampling + compile pipeline + cost model; the PJRT
//! functional verdicts are memoized inside the shared [`Evaluator`]).
//! The environment is offline (no tokio), so the pool is a fixed set of
//! std::thread workers draining a shared job queue — the runs are
//! uniform enough that work stealing buys nothing.
//!
//! Long sweeps survive interruption (DESIGN.md §8): with a
//! [`CampaignConfig::checkpoint`] journal, every completed cell is
//! appended as it finishes, and [`CampaignConfig::resume`] skips
//! journaled cells on restart (cells journaled under a different
//! trial budget are re-run, not merged). For methods whose cells are
//! pure functions of (method, model, op, seed) — every RNG stream is
//! derived from that key, and persistent-cache replay is bit-identical
//! to cold evaluation — a resumed campaign produces byte-identical
//! records and reports to an uninterrupted one; that is all methods
//! except the AI CUDA Engineer, whose Compose stage reads the shared
//! cross-op [`Archive`] and therefore depends on cell *completion
//! order* in any run, resumed or not. On resume the archive is
//! re-seeded from the journaled cells' best kernels so it sees what an
//! uninterrupted run would have published by that point.

//!
//! Execution is factored through the [`plane::WorkPlane`] seam
//! (DESIGN.md §15): [`run`] drives the in-process [`plane::LocalPlane`];
//! `campaign serve` ([`coordinator`]) owns the same grid behind an
//! HTTP/JSON claim API, and `campaign work` ([`wire`]) runs the
//! identical engine stack against it from separate processes.

pub mod coordinator;
pub mod plane;
pub mod results;
pub mod watch;
pub mod wire;

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::evals::Evaluator;
use crate::feedback::FeedbackConfig;
use crate::llm::{profile, provider, ModelProfile, ProviderConfig, ProviderSpec, ReusePolicy};
use crate::methods::engine::{EventSink, TrialGate};
use crate::methods::{
    self, Archive, ArchiveEntry, JournalSink, KernelRunRecord, Method, ProgressSink, RepairPolicy,
};
use crate::store::events::{self, EventJournal};
use crate::tasks::{OpTask, TaskRegistry};
use crate::{eyre, Result};

/// Campaign sweep description.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Method names (see [`methods::all_methods`]); empty = all six.
    pub methods: Vec<String>,
    /// Model names; empty = all three.
    pub models: Vec<String>,
    /// Independent runs (the paper uses seeds {0,1,2}).
    pub seeds: Vec<u64>,
    /// Substring filter on op names; empty = all 91.
    pub op_filter: String,
    /// Cap on number of ops after filtering (0 = no cap).
    pub max_ops: usize,
    /// Trial budget per run (the paper's 45).
    pub budget: usize,
    /// Stage-0 guard / repair policy applied to every cell (the
    /// campaign-level ablation axis; DESIGN.md §11).
    pub repair: RepairPolicy,
    /// Profile-guided feedback configuration applied to every cell
    /// (`--goal`, DESIGN.md §17): search objective + whether measured
    /// performance profiles are attached to generation prompts.
    pub goal: FeedbackConfig,
    /// Generation backend for every cell (DESIGN.md §12): the SimLLM,
    /// a recorded transcript journal, or a live HTTP endpoint.
    pub provider: ProviderSpec,
    /// Transcript journal: every live provider call is appended here,
    /// keyed by request hash, so the whole campaign can be re-run with
    /// `ProviderSpec::Replay` and zero live generation. `None` = no
    /// recording; ignored under replay (the journal already *is* the
    /// record).
    pub transcripts: Option<PathBuf>,
    /// Worker parallelism (0 = number of CPUs).
    pub concurrency: usize,
    /// Progress lines to stderr.
    pub quiet: bool,
    /// Checkpoint journal: completed cells are appended here as they
    /// finish (None = no checkpointing).
    pub checkpoint: Option<PathBuf>,
    /// Skip cells already present in the checkpoint journal and merge
    /// their records into the result.
    pub resume: bool,
    /// Claim at most this many cells in this process (0 = run to
    /// completion). Test hook that simulates a mid-sweep kill at a
    /// cell boundary; claim-gated, so exactly `min(stop_after, grid)`
    /// cells complete regardless of worker scheduling. Not exposed on
    /// the CLI.
    pub stop_after: usize,
    /// Simulated mid-*cell* kill: abort the sweep after this many
    /// trial groups have started across the whole process (0 = off).
    /// The interrupted cell is not checkpointed; `--resume` finishes
    /// it at trial granularity (DESIGN.md §13). Test hook, not exposed
    /// on the CLI.
    pub stop_after_trials: usize,
    /// Structured per-trial event journal (`--events`): every cell's
    /// [`TrialEvent`](crate::store::TrialEvent)s are appended here.
    pub events: Option<PathBuf>,
    /// Speculative generation-prefetch workers per cell (`--prefetch`,
    /// 0 = off): provider calls for predicted future trials overlap
    /// with compile+bench of the current one (DESIGN.md §13).
    pub prefetch: usize,
    /// Persistent kernel bank to deposit into (`--bank`, DESIGN.md
    /// §18): every candidate that beats its cell's incumbent is
    /// appended (content-addressed, deduped). Deposits never feed back
    /// into the same run — attaching a bank changes no record or event
    /// bytes. `None` = deposits off.
    pub bank: Option<PathBuf>,
    /// Warm-start snapshot (`--warm-start`): a bank journal read once
    /// at startup; its elites seed each cell's population and the
    /// shared archive, and retrieval-seeded `## PRIOR ELITES` prompt
    /// sections. Immutable for the whole campaign, so warm-started
    /// runs stay deterministic. `None` = cold start.
    pub warm_start: Option<PathBuf>,
}

impl CampaignConfig {
    /// The typed provider build input this campaign implies
    /// (DESIGN.md §12/§16): transcripts are dropped under replay (the
    /// journal already *is* the record), and a resumed campaign reuses
    /// journaled calls instead of refusing to append to an existing
    /// transcript file.
    pub fn provider_config(&self) -> ProviderConfig {
        let transcripts = match &self.provider {
            ProviderSpec::Replay(_) => None,
            _ => self.transcripts.clone(),
        };
        ProviderConfig::new(self.provider.clone()).transcripts(transcripts).reuse(
            if self.resume {
                ReusePolicy::Resume
            } else {
                ReusePolicy::Fresh
            },
        )
    }
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            methods: vec![],
            models: vec![],
            seeds: vec![0, 1, 2],
            op_filter: String::new(),
            max_ops: 0,
            budget: crate::TRIAL_BUDGET,
            repair: RepairPolicy::Off,
            goal: FeedbackConfig::default(),
            provider: ProviderSpec::Sim,
            transcripts: None,
            concurrency: 0,
            quiet: false,
            checkpoint: None,
            resume: false,
            stop_after: 0,
            stop_after_trials: 0,
            events: None,
            prefetch: 0,
            bank: None,
            warm_start: None,
        }
    }
}

fn resolve_models(names: &[String]) -> Result<Vec<&'static ModelProfile>> {
    if names.is_empty() {
        return Ok(profile::MODELS.iter().collect());
    }
    names
        .iter()
        .map(|n| profile::by_name(n).ok_or_else(|| eyre!("unknown model `{n}`")))
        .collect()
}

/// Resolve each requested method exactly once, up front — the workers
/// share the `Arc`s instead of re-running the name lookup per claimed
/// cell.
fn resolve_methods(names: &[String]) -> Result<Vec<Arc<dyn Method>>> {
    if names.is_empty() {
        return Ok(methods::all_methods().into_iter().map(Arc::from).collect());
    }
    names
        .iter()
        .map(|n| methods::by_name(n).map(Arc::from))
        .collect()
}

/// One grid point.
#[derive(Clone)]
pub(crate) struct Job {
    pub(crate) method: Arc<dyn Method>,
    pub(crate) model: &'static ModelProfile,
    pub(crate) op: OpTask,
    pub(crate) seed: u64,
}

/// A record's grid-cell identity (checkpoint key).
pub(crate) fn cell_of(r: &KernelRunRecord) -> events::CellKey {
    (r.method.clone(), r.model.clone(), r.op.clone(), r.seed)
}

/// Publish a warm-start bank's elites into the shared cross-op
/// [`Archive`] (DESIGN.md §18): archive-reading methods (the AI CUDA
/// Engineer's Compose RAG) see prior campaigns' best kernels from
/// trial 0. `Archive::record` keeps the max-rank entry per op, so
/// recording every bank entry is order-independent.
pub fn seed_archive_from_bank(archive: &Archive, bank: &crate::bank::KernelBank) {
    for e in bank.all_entries() {
        archive.record(ArchiveEntry {
            op: e.op,
            family: e.family,
            src: e.src,
            speedup: e.speedup,
            rank: e.rank,
        });
    }
}

/// A job's grid-cell identity (same key space as [`cell_of`]).
pub(crate) fn job_key(j: &Job) -> events::CellKey {
    (
        j.method.name(),
        j.model.name.to_string(),
        j.op.name.clone(),
        j.seed,
    )
}

/// The resolved sweep: the full job grid plus any prior records loaded
/// from the checkpoint on resume. Shared by the in-process plane
/// ([`run`]) and the `campaign serve` coordinator
/// ([`coordinator::serve`]), which must agree on grid order
/// cell-for-cell for resumed and distributed sweeps to line up.
pub(crate) struct GridPlan {
    /// The FULL grid in deterministic (method, model, op, seed) loop
    /// order; resume does not remove cells here, so a grid index is a
    /// stable cell identity across legs and claimants.
    pub(crate) jobs: Vec<Job>,
    /// Checkpointed records merged on resume: in-grid, budget-matched,
    /// deduped. Empty when not resuming.
    pub(crate) prior: Vec<KernelRunRecord>,
    pub(crate) n_methods: usize,
    pub(crate) n_models: usize,
    pub(crate) n_ops: usize,
}

/// Resolve the sweep grid (methods × models × ops × seeds, after
/// filters and the stratified op cut) and, on resume, load the prior
/// checkpoint records that fall inside it.
pub(crate) fn plan_grid(cfg: &CampaignConfig, registry: &TaskRegistry) -> Result<GridPlan> {
    let models = resolve_models(&cfg.models)?;
    let method_impls = resolve_methods(&cfg.methods)?;
    let mut ops: Vec<OpTask> = registry
        .ops
        .iter()
        .filter(|o| cfg.op_filter.is_empty() || o.name.contains(&cfg.op_filter))
        .cloned()
        .collect();
    if cfg.max_ops > 0 && ops.len() > cfg.max_ops {
        // Keep the category mix representative: stable stratified cut.
        ops = stratified_cut(ops, cfg.max_ops);
    }
    anyhow::ensure!(!ops.is_empty(), "no ops match the filter");

    let mut jobs = Vec::new();
    for method in &method_impls {
        for model in &models {
            for op in &ops {
                for &seed in &cfg.seeds {
                    jobs.push(Job {
                        method: method.clone(),
                        model,
                        op: op.clone(),
                        seed,
                    });
                }
            }
        }
    }

    let mut prior: Vec<KernelRunRecord> = Vec::new();
    if cfg.resume {
        let path = cfg
            .checkpoint
            .as_ref()
            .ok_or_else(|| eyre!("--resume requires a checkpoint journal"))?;
        let grid: HashSet<events::CellKey> = jobs.iter().map(job_key).collect();
        let loaded = results::load_lenient(path)?;
        let mut budget_mismatch = 0usize;
        prior = loaded
            .into_iter()
            .filter(|r| grid.contains(&cell_of(r)))
            .filter(|r| {
                // A cell journaled under a different --budget is a
                // different experiment: re-run it rather than silently
                // mixing budgets in one report.
                if r.budget == cfg.budget {
                    true
                } else {
                    budget_mismatch += 1;
                    false
                }
            })
            .collect();
        if budget_mismatch > 0 && !cfg.quiet {
            eprintln!(
                "campaign: re-running {budget_mismatch} checkpointed cells journaled \
                 under a different trial budget (want {})",
                cfg.budget
            );
        }
        // A journal may hold duplicates of a cell (e.g. two resumed
        // legs racing); records are deterministic per cell, keep one.
        let mut seen = HashSet::new();
        prior.retain(|r| seen.insert(cell_of(r)));
    }

    Ok(GridPlan {
        jobs,
        prior,
        n_methods: method_impls.len(),
        n_models: models.len(),
        n_ops: ops.len(),
    })
}

/// Run the sweep; returns records sorted by (method, model, op, seed)
/// for deterministic output regardless of scheduling.
///
/// With `cfg.checkpoint` set, completed cells are journaled as they
/// finish; with `cfg.resume`, journaled cells inside the requested
/// grid are skipped and their saved records merged into the result
/// (journaled cells *outside* the grid are ignored, so a narrower
/// re-run still reports exactly the requested sweep).
pub fn run(cfg: &CampaignConfig, evaluator: Evaluator) -> Result<Vec<KernelRunRecord>> {
    // One provider shared by every worker (they are Sync); recording
    // wraps it transparently when a transcript journal is configured.
    // On resume, already-journaled calls are served from the journal
    // (trial-granular resume: an interrupted cell's completed trials
    // replay with zero live generation).
    let llm_provider = provider::build(&cfg.provider_config())?;

    // Kernel bank (DESIGN.md §18). The deposit side is append-only and
    // never read during the run; the warm-start side is an immutable
    // snapshot read once here, so every cell (and every worker on the
    // wire plane) consumes the identical elite set.
    let bank = match &cfg.bank {
        Some(path) => Some(crate::bank::KernelBank::open(path)?),
        None => None,
    };
    let warm = match &cfg.warm_start {
        Some(path) => Some(crate::bank::KernelBank::load(path)?),
        None => None,
    };

    let GridPlan {
        mut jobs,
        prior,
        n_methods,
        n_models,
        n_ops,
    } = plan_grid(cfg, &evaluator.registry)?;
    let grid_total = jobs.len();

    // Resume: pull previously-completed cells out of the job list and
    // re-publish their best kernels so archive-reading methods (the AI
    // CUDA Engineer's Compose RAG) see what an uninterrupted run would
    // have published by this point.
    let archive = Archive::new();
    if let Some(warm) = &warm {
        seed_archive_from_bank(&archive, warm);
    }
    if !prior.is_empty() {
        let seen: HashSet<events::CellKey> = prior.iter().map(cell_of).collect();
        jobs.retain(|j| !seen.contains(&job_key(j)));
        for r in &prior {
            if let (true, Some(src)) = (r.any_valid, &r.best_src) {
                if let Some(task) = evaluator.registry.get(&r.op) {
                    archive.record(ArchiveEntry {
                        op: r.op.clone(),
                        family: task.family.clone(),
                        src: src.clone(),
                        speedup: r.best_speedup,
                        // Journaled records carry no timing; rank by
                        // raw speedup (== default-goal fitness).
                        rank: r.best_speedup,
                    });
                }
            }
        }
    }

    let total = jobs.len();
    let concurrency = if cfg.concurrency == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.concurrency
    }
    .min(total.max(1));
    if !cfg.quiet {
        eprintln!(
            "campaign: {} methods x {} models x {} ops x {} seeds = {} runs \
             ({} workers, {} runtime shards, provider {}{})",
            n_methods,
            n_models,
            n_ops,
            cfg.seeds.len(),
            grid_total,
            concurrency,
            evaluator.runtime_shards(),
            llm_provider.label(),
            if prior.is_empty() {
                String::new()
            } else {
                format!(", {} resumed from checkpoint", prior.len())
            }
        );
    }

    // Resumed legs append to the journal; a fresh campaign starts it
    // over (stale cells from an older sweep must not accumulate).
    let appender: Option<Mutex<results::Appender>> = match &cfg.checkpoint {
        Some(path) if cfg.resume => Some(Mutex::new(results::Appender::open(path)?)),
        Some(path) => Some(Mutex::new(results::Appender::create(path)?)),
        None => None,
    };

    // Engine plumbing (DESIGN.md §13): the per-trial event sinks shared
    // by every worker, the trial-granular kill gate, and — on resume —
    // the prior event journal's per-cell (trial, src_hash) index used
    // to verify that replayed trials of half-finished cells re-derive
    // bit-identical emissions.
    let mut sinks: Vec<Arc<dyn EventSink>> = Vec::new();
    let mut verify_replay: HashMap<events::CellKey, Vec<(usize, String)>> = HashMap::new();
    if let Some(path) = &cfg.events {
        if cfg.resume && path.exists() {
            verify_replay =
                events::completed_trials_at(path, crate::store::IndexMode::from_env())?;
            if !cfg.quiet && !verify_replay.is_empty() {
                eprintln!(
                    "campaign: event journal holds {} half-finished cell(s); their \
                     completed trials replay warm and are verified against it",
                    verify_replay.len()
                );
            }
        }
        let journal = if cfg.resume {
            EventJournal::open(path)?
        } else {
            EventJournal::create(path)?
        };
        sinks.push(Arc::new(JournalSink::new(journal)));
    }
    if !cfg.quiet {
        sinks.push(Arc::new(ProgressSink::campaign(total)));
    }
    let trial_gate = (cfg.stop_after_trials > 0)
        .then(|| Arc::new(TrialGate::new(cfg.stop_after_trials)));

    // First provider failure (transcript miss, HTTP outage) aborts the
    // sweep: the plane stops issuing cells, the error is surfaced to
    // the caller. Already-journaled cells stay resumable. A TrialGate
    // interruption is latched separately — a simulated kill is a
    // healthy partial sweep, not a failure.
    let local = plane::LocalPlane::new(
        &jobs,
        &verify_replay,
        sinks,
        cfg.stop_after,
        cfg.quiet,
        appender,
    );
    let env = plane::WorkerEnv {
        evaluator: &evaluator,
        archive: &archive,
        provider: llm_provider,
        budget: cfg.budget,
        repair: cfg.repair,
        feedback: cfg.goal,
        prefetch: cfg.prefetch,
        trial_gate,
        bank: bank.clone(),
        warm: warm.clone(),
    };
    std::thread::scope(|scope| {
        for _ in 0..concurrency {
            let local = &local;
            let env = &env;
            scope.spawn(move || {
                if let Err(e) = plane::worker_loop(local, env) {
                    local.transport_error(e);
                }
            });
        }
    });

    if let Some(e) = local.take_error() {
        return Err(e);
    }

    // Persist this process's cache hit/miss counters for `cache stats`.
    if let Some(store) = evaluator.store() {
        if let Err(e) = store.flush_session_stats() {
            eprintln!("warning: eval-cache stats flush failed: {e:#}");
        }
    }

    // Group-committed bank deposits must reach disk before the process
    // exits; the count summary mirrors the cache-stats line.
    if let Some(bank) = &bank {
        if let Err(e) = bank.flush() {
            eprintln!("warning: kernel-bank flush failed: {e:#}");
        }
        if !cfg.quiet && bank.deposits() > 0 {
            eprintln!(
                "campaign: deposited {} new elite(s) into {}",
                bank.deposits(),
                cfg.bank.as_deref().unwrap_or_else(|| std::path::Path::new("?")).display()
            );
        }
    }
    if let Some(warm) = &warm {
        let (hits, misses) = warm.retrieval_counts();
        if !cfg.quiet && hits + misses > 0 {
            eprintln!(
                "campaign: warm-start retrieval served {hits} cell(s), {misses} had no \
                 matching elites"
            );
        }
    }

    let was_interrupted = local.was_interrupted();
    let completed = local.into_completed();
    if was_interrupted && !cfg.quiet {
        eprintln!(
            "campaign: interrupted after {} trial groups (--stop-after-trials); \
             {} cells completed, resume to finish",
            cfg.stop_after_trials,
            completed.len()
        );
    }
    if cfg.stop_after == 0 && !was_interrupted && completed.len() != total {
        return Err(eyre!("worker pool lost records: {}/{total}", completed.len()));
    }
    let mut records = prior;
    records.extend(completed);
    records.sort_by(|a, b| {
        (&a.method, &a.model, &a.op, a.seed).cmp(&(&b.method, &b.model, &b.op, b.seed))
    });
    Ok(records)
}

/// Stratified cut preserving category proportions (used by quick runs).
///
/// Allocation starts from one op per category (every category stays
/// represented whenever `max` allows) and hands out the remaining
/// slots one at a time to the bucket that is furthest below its exact
/// proportional share — so an overshoot is trimmed from the most
/// over-represented buckets instead of truncating whole trailing
/// categories, and the result has exactly `min(max, ops.len())`
/// elements. Buckets are keyed by the actual category value, so
/// out-of-range categories (≥ 7) select fine instead of panicking.
fn stratified_cut(ops: Vec<OpTask>, max: usize) -> Vec<OpTask> {
    if ops.len() <= max {
        return ops;
    }
    let total = ops.len();
    let mut by_cat: std::collections::BTreeMap<u8, Vec<OpTask>> = std::collections::BTreeMap::new();
    for op in ops {
        by_cat.entry(op.category).or_default().push(op);
    }
    // (category, bucket, exact proportional share, allocated so far)
    let mut alloc: Vec<(u8, Vec<OpTask>, f64, usize)> = by_cat
        .into_iter()
        .map(|(cat, bucket)| {
            let exact = bucket.len() as f64 * max as f64 / total as f64;
            (cat, bucket, exact, 0)
        })
        .collect();
    let mut assigned = 0usize;
    // Seed one per category while slots last; when max < #categories
    // the largest-share categories win the scarce seeds (ties broken
    // by category order), so the proportional contract holds even for
    // tiny cuts. max >= #categories keeps every category represented.
    let mut seed_order: Vec<usize> = (0..alloc.len()).collect();
    seed_order.sort_by(|&a, &b| {
        alloc[b]
            .2
            .partial_cmp(&alloc[a].2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(alloc[a].0.cmp(&alloc[b].0))
    });
    for &i in &seed_order {
        if assigned == max {
            break;
        }
        alloc[i].3 = 1;
        assigned += 1;
    }
    // Hand out the rest by largest deficit vs the exact share
    // (ties broken by category order for determinism).
    while assigned < max {
        let next = alloc
            .iter_mut()
            .filter(|a| a.3 < a.1.len())
            .max_by(|a, b| {
                (a.2 - a.3 as f64)
                    .partial_cmp(&(b.2 - b.3 as f64))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.0.cmp(&a.0)) // lower category wins ties
            })
            .expect("max < total ops, so some bucket has spare capacity");
        next.3 += 1;
        assigned += 1;
    }
    let mut out = Vec::with_capacity(max);
    for (_, bucket, _, take) in alloc {
        out.extend(bucket.into_iter().take(take));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_defaults() {
        assert_eq!(resolve_models(&[]).unwrap().len(), 3);
        assert_eq!(resolve_methods(&[]).unwrap().len(), 6);
        assert!(resolve_models(&["martian".into()]).is_err());
    }

    #[test]
    fn ambiguous_method_filter_is_an_error() {
        // `--methods evoengineer` used to silently pick the first
        // variant; the campaign must now refuse the ambiguous filter.
        let err = resolve_methods(&["evoengineer".into()]).unwrap_err();
        assert!(err.to_string().contains("ambiguous"), "{err}");
        // Unique fragments still work for CLI ergonomics.
        let resolved = resolve_methods(&["eoh".into()]).unwrap();
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].name(), "EvoEngineer-Solution (EoH)");
    }

    #[test]
    fn stratified_cut_keeps_mix() {
        let reg = crate::tasks::TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        let cut = stratified_cut(reg.ops.clone(), 12);
        assert!(cut.len() <= 12);
        let cats: std::collections::HashSet<u8> = cut.iter().map(|o| o.category).collect();
        assert!(cats.len() >= 5, "{cats:?}");
    }

    #[test]
    fn stratified_cut_exact_size_keeps_every_category() {
        let reg = crate::tasks::TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        // The old truncate(max) dropped whole trailing categories when
        // per-bucket rounding overshot; the cut must now return exactly
        // `max` ops with all 6 categories represented whenever max >= 6.
        for max in [6, 7, 12, 20, 45, 90] {
            let cut = stratified_cut(reg.ops.clone(), max);
            assert_eq!(cut.len(), max, "max={max}");
            let cats: std::collections::HashSet<u8> =
                cut.iter().map(|o| o.category).collect();
            assert_eq!(cats.len(), 6, "max={max}: {cats:?}");
        }
        // max >= total is the identity.
        let all = stratified_cut(reg.ops.clone(), reg.ops.len());
        assert_eq!(all.len(), reg.ops.len());
    }

    #[test]
    fn stratified_cut_trims_most_over_represented_bucket() {
        let reg = crate::tasks::TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        // 91 ops -> 12: Convolution (28 ops) must keep more slots than
        // Cumulative (4 ops), i.e. the proportions survive the cut.
        let cut = stratified_cut(reg.ops.clone(), 12);
        let count = |cat: u8| cut.iter().filter(|o| o.category == cat).count();
        assert!(count(2) > count(6), "conv={} cum={}", count(2), count(6));
        assert!(count(6) >= 1, "trailing category dropped");
    }

    #[test]
    fn stratified_cut_below_category_count_favors_large_categories() {
        let reg = crate::tasks::TaskRegistry::load(
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        // max=3 < 6 categories: the three scarce seeds must go to the
        // largest categories (2: Convolution 28, 3: Act/Pool 21,
        // 1: MatMul 18), not to categories 1..=3 by index order.
        let cut = stratified_cut(reg.ops.clone(), 3);
        assert_eq!(cut.len(), 3);
        let cats: std::collections::HashSet<u8> = cut.iter().map(|o| o.category).collect();
        assert_eq!(cats, [1u8, 2, 3].into_iter().collect(), "{cats:?}");
    }

    fn synthetic_op(name: &str, category: u8) -> OpTask {
        OpTask {
            name: name.into(),
            category,
            family: "x".into(),
            args: vec![],
            out_shape: vec![1],
            flops: 1.0,
            bytes_moved: 1.0,
            pt_launches: 1,
            pt_passes: 1.0,
            pt_efficiency: 0.5,
            algo_penalty: 1.0,
            atol: 1e-4,
            rtol: 1e-3,
            artifacts: Default::default(),
        }
    }

    #[test]
    fn stratified_cut_survives_out_of_range_categories() {
        // The old fixed 7-bucket indexing panicked on category >= 7;
        // bucketing is now keyed by the actual category value.
        let ops = vec![
            synthetic_op("a", 7),
            synthetic_op("b", 200),
            synthetic_op("c", 1),
            synthetic_op("d", 7),
        ];
        let cut = stratified_cut(ops, 2);
        assert_eq!(cut.len(), 2);
    }
}
